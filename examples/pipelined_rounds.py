"""Pipelined optimistic rounds: commit-and-continue training with
asynchronous challenge windows, chained rollback on late-confirmed
fraud, and the same pipeline at batch-inference granularity.

The system commits round r and immediately proceeds to rounds
r+1..r+w on the optimistically-accepted state; audits park in a
deadline-ordered queue and drain in merged bursts (one grouped kernel
call per backlog).  When a fraud proof lands for round r AFTER its
descendants committed, the whole chain rolls back: snapshot restored,
descendants invalidated, every voided round re-executed honestly,
exactly one slash for the convicted round — all recorded as rollback
blocks in the ledger.

Run:  PYTHONPATH=src python examples/pipelined_rounds.py
"""
import numpy as np

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.data.synthetic import FMNIST, make_image_dataset
from repro.trust.protocol import RoundPhase, TrustConfig

xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=4000, n_test=800)
xtr, xte = xtr.reshape(len(xtr), -1), xte.reshape(len(xte), -1)

attack = AttackConfig(malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
system = BMoESystem(BMoEConfig(
    framework="optimistic", attack=attack, pow_difficulty=4,
    trust=TrustConfig(audit_rate=0.3, challenge_window=3,
                      scheduling="pipelined")))

print("=== pipelined optimistic training (window=3, malicious edge 2) ===")
rng = np.random.default_rng(0)
for r in range(12):
    idx = rng.integers(0, len(xtr), 256)
    m = system.train_round(xtr[idx], ytr[idx])
    backlog = system.protocol.audit_backlog()
    flag = " <- ROLLED BACK + chain replayed" if m["rolled_back"] else ""
    print(f"  round {r:2d} loss={float(m['loss']):.3f} "
          f"audit_backlog={backlog}{flag}")

system.flush_trust()
stats = system.protocol.stats
print(f"\nprotocol: {stats['committed']} committed, "
      f"{stats['finalized']} finalized, {stats['rolled_back']} rolled back, "
      f"{stats['invalidated']} invalidated (chain descendants), "
      f"{stats['audit_drains']} audit drains")
for rb in system.ledger.rollbacks():
    p = rb.payload
    print(f"rollback block: round {p['rollback_of']} convicted "
          f"(executor {p['executor']} slashed), voided chain {p['chain']}")
phases = {rid: st.phase.value for rid, st in system.protocol.rounds.items()
          if st.phase in (RoundPhase.ROLLED_BACK, RoundPhase.INVALIDATED)}
print(f"voided rounds: {phases}")
print(f"chain verifies: {system.ledger.verify_chain()}")
acc = system.evaluate(xte, yte, attack=AttackConfig())
print(f"clean accuracy after rollbacks: {acc:.3f}")

print("\n=== batch-inference pipeline (same protocol, frozen weights) ===")
for _ in range(3):
    logits, _, _ = system.infer(xte[:128], attack=AttackConfig())
    commit = [e for e in system.infer_log if e["event"] == "commit"][-1]
    print(f"  infer round {commit['round']}: committed "
          f"{commit['root']}..., pending={system.pending_inference()}")
system.flush_trust()
print(f"inference settled: pending={system.pending_inference()}, "
      f"log events={[e['event'] for e in system.infer_log]}")
