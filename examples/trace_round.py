"""Trace an attacked optimistic training run and export a Perfetto-
loadable Chrome trace (``trace.json``).

Every round is one ``round`` span with nested phase spans (``fetch ->
dispatch -> consensus -> publish -> chain``); pipelined audit bursts
appear as ``audit-drain`` spans flagged ``off_path`` (their time is
excluded from the enclosing consensus metric — the span tree is the
accounting); a fraud conviction shows up as ``court`` +
``rollback-replay`` spans, and every mined block carries the trace/span
id of the phase that minted it, so a ledger entry can be followed back
into the timeline.

The script then *checks* the trace against the legacy reports:

1. per-phase span sums reproduce ``latency_report()``'s keys within 5%
   (the report is a registry view; the trace is an independent export);
2. phase spans cover >= 95% of each round span's wall time;
3. mined blocks' span ids resolve to real spans in the trace.

Run:  PYTHONPATH=src python examples/trace_round.py
Open: https://ui.perfetto.dev -> "Open trace file" -> trace.json
"""
import json
from collections import defaultdict

import numpy as np

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.core.storage import serialize_tree
from repro.data.synthetic import FMNIST, make_image_dataset
from repro.obs import Observability
from repro.trust.protocol import TrustConfig

ROUNDS = 10

xtr, ytr, _, _ = make_image_dataset(FMNIST, n_train=4000, n_test=200)
xtr = xtr.reshape(len(xtr), -1)

attack = AttackConfig(malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
obs = Observability(enabled=True)
system = BMoESystem(BMoEConfig(
    framework="optimistic", attack=attack, pow_difficulty=4,
    trust=TrustConfig(audit_rate=0.3, challenge_window=3,
                      scheduling="pipelined")), obs=obs)

print(f"=== tracing {ROUNDS} attacked pipelined rounds ===")
rng = np.random.default_rng(0)
for r in range(ROUNDS):
    idx = rng.integers(0, len(xtr), 256)
    m = system.train_round(xtr[idx], ytr[idx])
    if m["rolled_back"]:
        print(f"  round {r:2d}: fraud confirmed -> chain rolled back")
system.flush_trust()

path = "trace.json"
obs.trace.export_chrome(path)
with open(path) as f:
    doc = json.load(f)
events = doc["traceEvents"]
print(f"wrote {path}: {len(events)} spans "
      f"({system.protocol.stats['rolled_back']} rollback(s), "
      f"{system.protocol.stats['audit_drains']} audit drain(s))")

# ---- 1. per-phase span sums vs the legacy latency report -------------
assert all(e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
           for e in events), "not a valid Chrome trace"
by_id = {e["args"]["span_id"]: e for e in events}
off_child_us = defaultdict(float)       # parent span -> off-path child us
for e in events:
    if e["args"]["off_path"] and e["args"]["parent_id"] is not None:
        off_child_us[e["args"]["parent_id"]] += e["dur"]

phase_s = defaultdict(float)            # metric -> on-path seconds
for e in events:
    metric = e["args"].get("metric")
    if metric is None:
        continue
    dur = e["dur"] if e["args"]["off_path"] \
        else e["dur"] - off_child_us[e["args"]["span_id"]]
    phase_s[metric] += dur / 1e6

expert_bytes = len(serialize_tree(system.experts)) // system.cfg.num_experts
lr = system.latency_report(expert_bytes, 256 * 10 * 4, ROUNDS)
checks = {"compute_s": "bmoe.compute_s", "consensus_s": "bmoe.consensus_s",
          "chain_s": "bmoe.chain_s", "audit_offpath_s": "bmoe.audit_s",
          "storage_s": "bmoe.storage_s"}
print("\nper-phase span sums vs latency_report (per round):")
for key, metric in checks.items():
    from_trace = phase_s[metric] / ROUNDS
    rel = abs(from_trace - lr[key]) / max(lr[key], 1e-12)
    print(f"  {key:16s} trace={from_trace * 1e3:8.2f}ms "
          f"report={lr[key] * 1e3:8.2f}ms  rel_err={rel:.4f}")
    assert rel <= 0.05, f"{key}: trace disagrees with report by {rel:.1%}"

# ---- 2. phase spans cover >= 95% of each round's wall time -----------
coverage = []
for e in events:
    if e["name"] != "round":
        continue
    child_us = sum(c["dur"] for c in events
                   if c["args"]["parent_id"] == e["args"]["span_id"])
    coverage.append(child_us / max(e["dur"], 1))
print(f"\nround coverage by phase spans: "
      f"min={min(coverage):.3f} mean={np.mean(coverage):.3f}")
assert min(coverage) >= 0.95, "phase spans cover < 95% of a round"

# ---- 3. ledger blocks resolve back into the trace --------------------
linked = [b for b in system.ledger.blocks if "span_id" in b.payload]
assert linked and all(b.payload["span_id"] in by_id for b in linked)
print(f"\n{len(linked)}/{len(system.ledger.blocks)} blocks carry a span id "
      f"(genesis is not mined); e.g. block #{linked[-1].index} "
      f"[{linked[-1].payload.get('kind')}] -> span "
      f"'{by_id[linked[-1].payload['span_id']]['name']}' "
      f"in trace {linked[-1].payload['trace_id']}")
print("\nall checks passed — load trace.json in ui.perfetto.dev")
