"""Federated B-MoE training with verified aggregation, end to end.

Six edge devices train local expert subsets on non-IID Dirichlet shards
and publish weight deltas through the chunk store.  Rounds tolerate
stragglers and dropouts; a poisoning edge is screened by the defended
aggregation rule; and a dishonest aggregator is caught by the audit ->
recompute-court -> slash -> rollback pipeline, after which the honest
lineage is replayed.

Run:  PYTHONPATH=src python examples/federated_round.py
"""
from repro.data.synthetic import FMNIST, make_image_dataset
from repro.fed import FedAttack, FedConfig, FedCoordinator

x, y, xt, yt = make_image_dataset(FMNIST, n_train=2000, n_test=500, seed=0)

# ---------------------------------------------- 1. faults + poisoning
print("=== 1. rounds under stragglers, dropouts and a poisoning edge ===")
cfg = FedConfig(num_edges=6, num_experts=6, hidden=16, local_steps=3,
                local_batch=32, seed=0,
                straggler_prob=0.2, dropout_prob=0.1,
                attack=FedAttack(malicious_edges=(2,),
                                 update_attack="sign_flip", scale=5.0))
co = FedCoordinator(cfg, x, y)
for _ in range(6):
    s = co.run_round()
    print(f"  round {s['round']} received={s['received']} "
          f"stragglers={s['stragglers']} dropouts={s['dropouts']} "
          f"rejected={s['rejected']}")
co.flush_trust()
rep = co.obs_report()
print(f"  accuracy: {co.evaluate(xt, yt):.3f}")
print(f"  fed counters: stragglers={rep['fed']['stragglers']} "
      f"dropouts={rep['fed']['dropouts']} "
      f"carried={rep['fed']['carried_deltas']} "
      f"rejected_updates={rep['fed']['rejected_updates']}")
print(f"  chain: {rep['chain']['blocks']} blocks "
      f"valid={rep['chain']['valid']}")

# ------------------------------------------- 2. dishonest aggregator
print("=== 2. dishonest aggregator: conviction + chained rollback ===")
cfg2 = FedConfig(num_edges=6, num_experts=6, hidden=16, local_steps=3,
                 local_batch=32, seed=0,
                 attack=FedAttack(malicious_edges=(1,),
                                  dishonest_aggregator=True))
co2 = FedCoordinator(cfg2, x, y)
for _ in range(5):
    co2.run_round()
co2.flush_trust()
rep2 = co2.obs_report()
rb = co2.ledger.rollbacks()[0]
print(f"  convictions={rep2['fed']['convictions']} "
      f"replayed_rounds={rep2['fed']['replayed_rounds']}")
print(f"  rollback block: round {rb.payload['rollback_of']} "
      f"slashed={rb.payload['slashed']} chain={rb.payload['chain']}")
print(f"  stakes after: {co2.protocol.stakes.stake.tolist()}")
print(f"  accuracy after honest replay: {co2.evaluate(xt, yt):.3f}")

assert rep2["fed"]["convictions"] >= 1 and co2.ledger.verify_chain()
assert rep["fed"]["rounds"] == 6
print("OK")
