"""End-to-end driver (deliverable (b)): trains the paper's two frameworks
for a few hundred rounds under a training-time data-manipulation attack
and reproduces the paper's three headline effects:

  1. traditional MoE's gate de-activates poisoned experts (Fig. 2) —
     workload imbalance;
  2. B-MoE keeps workload balanced AND accuracy near attack-free (Fig. 4a);
  3. at inference the traditional gate is blind, B-MoE tolerates any
     minority coalition (Fig. 4c shape).

Run:  PYTHONPATH=src python examples/attack_and_consensus.py [rounds]
"""
import sys

import numpy as np

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.data.synthetic import FMNIST, make_image_dataset

ROUNDS = int(sys.argv[1]) if len(sys.argv) > 1 else 200

xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=6000, n_test=1500)
xtr, xte = xtr.reshape(len(xtr), -1), xte.reshape(len(xte), -1)
attack = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=0.2,
                      noise_std=5.0)

systems = {}
for fw in ("traditional", "bmoe"):
    print(f"=== training {fw} under attack ({ROUNDS} rounds) ===")
    s = BMoESystem(BMoEConfig(framework=fw, attack=attack,
                              pow_difficulty=6))
    rng = np.random.default_rng(0)
    for r in range(ROUNDS):
        idx = rng.integers(0, len(xtr), 256)
        m = s.train_round(xtr[idx], ytr[idx])
        if r % max(ROUNDS // 5, 1) == 0:
            acc = s.evaluate(xte[:500], yte[:500], attack=AttackConfig())
            print(f"  round {r:4d} loss={float(m['loss']):.3f} "
                  f"clean_acc={acc:.3f}")
    systems[fw] = s

print("\n--- Fig. 2: activation ratios after attacked training ---")
for fw, s in systems.items():
    r = np.round(s.activation_ratio, 3)
    print(f"  {fw:12s} honest(0-6)={r[:7].mean():.3f} "
          f"malicious(7-9)={r[7:].mean():.3f}   full={r.tolist()}")

print("\n--- Fig. 4a: accuracy after attacked training ---")
for fw, s in systems.items():
    acc = s.evaluate(xte, yte, attack=attack)
    print(f"  {fw:12s} accuracy under attack: {acc:.3f}")

print("\n--- Fig. 4c: inference attack sweep on the B-MoE model ---")
for ratio in (0.0, 0.2, 0.4, 0.6):
    m = round(ratio * 10)
    atk = AttackConfig(malicious_edges=tuple(range(10 - m, 10)),
                       attack_prob=1.0, noise_std=5.0)
    accs = {fw: s.evaluate(xte[:800], yte[:800], attack=atk)
            for fw, s in systems.items()}
    marker = "  <- threshold exceeded" if ratio > 0.5 else ""
    print(f"  malicious_ratio={ratio:.1f}: traditional={accs['traditional']:.3f} "
          f"bmoe={accs['bmoe']:.3f}{marker}")

print(f"\nledger: {len(systems['bmoe'].ledger.blocks)} blocks, "
      f"valid={systems['bmoe'].ledger.verify_chain()}")
