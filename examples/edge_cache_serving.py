"""A byte-budgeted edge: fetch only the activated experts.

The paper's three-layer design keeps the expert bank in the *storage*
layer — "the edge layer employs the activated experts downloaded from
the storage layer" — and the chain records their CIDs.  This example
runs both halves of that economy:

1. A B-MoE system whose edge cache is smaller than the expert bank: the
   executor resolves each round's bank through the cache (activated
   experts pinned, LRU eviction under the byte budget), uploads only the
   *changed* experts as new chunk-manifest versions, and the storage
   report shows the transfer ledger — dedup savings, hit/miss traffic,
   and modeled seconds on the deterministic cost model.
2. A serving engine over a (smoke-sized) MoE transformer whose per-tick
   routing counts drive the same ``ExpertCache``: cold ticks fetch,
   warm ticks hit, and the EMA prefetcher warms the hottest experts.

Run: PYTHONPATH=src python examples/edge_cache_serving.py
"""
import dataclasses
import json

import numpy as np

from repro.configs import get_config
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.data.synthetic import serving_requests
from repro.serve.engine import EdgeStorageConfig, ServingEngine
from repro.train.loop import init_model
from repro.trust.protocol import TrustConfig

rng = np.random.default_rng(0)
x = rng.normal(size=(1024, 784)).astype(np.float32)
y = rng.integers(0, 10, 1024)

# ---- 1. training on a memory-constrained edge -------------------------
full_bank = BMoESystem(BMoEConfig(num_experts=8, num_edges=8, top_k=2,
                                  pow_difficulty=2, framework="optimistic",
                                  seed=0))
bank_bytes = sum(full_bank.expert_store.object_bytes(f"expert/{e}")
                 for e in range(8))

cfg = BMoEConfig(num_experts=8, num_edges=8, top_k=2, pow_difficulty=2,
                 framework="optimistic", seed=0,
                 edge_cache_bytes=bank_bytes // 2,   # half the bank fits
                 prefetch_topk=3,                    # EMA-warm 3 hottest
                 trust=TrustConfig(audit_rate=0.1, challenge_window=2))
system = BMoESystem(cfg)
for r in range(8):
    idx = rng.integers(0, len(x), 128)
    m = system.train_round(x[idx], y[idx])
system.flush_trust()

rep = system.storage_report()
print("edge budget:", cfg.edge_cache_bytes, "of", bank_bytes, "bank bytes")
print("cache:", json.dumps(rep["cache"]))
print("dedup: uploaded", rep["store"]["uploaded_bytes"], "bytes,",
      rep["store"]["chunks_deduped"], "chunks deduped")
print("modeled transfer:",
      round(rep["network"]["modeled_get_s"] + rep["network"]["modeled_put_s"],
            3), "s on the 1 Gbps cost model")
print("bank root on-chain:", system.ledger.head.payload["bank_root"])

# repeated inference against the frozen bank: a budget below the bank
# size pays exactly the evicted half back per resolve — the thrash a
# bigger budget (or prefetch of the right experts) buys away
system.infer(x[:256], commit=False)
before = system.edge_cache.stats["fetched_bytes"]
system.infer(x[:256], commit=False)
print("half-bank budget: warm re-inference refetched",
      system.edge_cache.stats["fetched_bytes"] - before,
      "bytes (the evicted half)")

# ---- 2. the serving engine's per-tick expert resolution ---------------
mcfg = dataclasses.replace(get_config("qwen2-moe-a2.7b", smoke=True),
                           padded_num_experts=0)
params = init_model(mcfg, seed=0)
engine = ServingEngine(mcfg, params, batch_slots=2, cache_len=48,
                       expert_storage=EdgeStorageConfig(prefetch_topk=2))
engine.submit(serving_requests(mcfg.vocab_size, 6, max_prompt=8,
                               max_new=6, seed=0))
done = engine.run()
erep = engine.edge.report()
print(f"served {len(done)} requests over {erep['ticks']} ticks:",
      f"{erep['cache']['misses']} cold unit fetches,",
      f"{erep['cache']['hits']} warm hits,",
      f"{erep['cache']['prefetches']} prefetches")
