"""Quickstart: the B-MoE framework in ~60 lines.

1. Build the paper's system (10 experts over 10 edges + blockchain +
   storage), train it under a data-manipulation attack, and watch the
   consensus keep the model honest.
2. Train a small MoE *language model* with the same trust machinery
   available as a config flag.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.data.synthetic import FMNIST, lm_batches, make_image_dataset

# ---------------------------------------------------------------- 1. B-MoE
print("=== 1. B-MoE (paper, Fig. 3 workflow) ===")
xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=3000, n_test=800)
xtr, xte = xtr.reshape(len(xtr), -1), xte.reshape(len(xte), -1)

attack = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=0.5,
                      noise_std=5.0)
system = BMoESystem(BMoEConfig(framework="bmoe", attack=attack,
                               pow_difficulty=6))
rng = np.random.default_rng(0)
for r in range(40):
    idx = rng.integers(0, len(xtr), 256)
    metrics = system.train_round(xtr[idx], ytr[idx])
    if r % 10 == 0:
        print(f"  round {r:3d} loss={float(metrics['loss']):.3f} "
              f"trusted_support={metrics['support'].astype(int).tolist()}")

acc = system.evaluate(xte, yte, attack=attack)
print(f"  accuracy under attack: {acc:.3f}")
print(f"  ledger: {len(system.ledger.blocks)} blocks, "
      f"chain_valid={system.ledger.verify_chain()}")
print(f"  last block: {system.ledger.head.payload['expert_hash']}... "
      f"support={system.ledger.head.payload['expert_hash_support']}/10")

# ------------------------------------------------------------- 2. MoE LM
print("\n=== 2. MoE language model (paper setup: N=10, K=3) ===")
from repro.configs import get_config
from repro.train.loop import train

cfg = get_config("bmoe-paper", smoke=True)
batches = lm_batches(cfg.vocab_size, batch=8, seq=64, seed=0)
params, history = train(cfg, batches, steps=30, log_every=10)
for h in history:
    print(f"  step {h['step']:3d} loss={h['loss']:.3f}")
print("done — see examples/attack_and_consensus.py and "
      "examples/trusted_serving.py for the full story")
