"""Serving example: batched greedy generation through the serving engine,
a verified (commit-challenge-audit) serving session that finalizes only
audited outputs, plus the LM-scale trusted-MoE consensus demonstrated on
a multi-device mesh (subprocess with virtual devices, since this
container has 1 CPU).

Run:  PYTHONPATH=src python examples/trusted_serving.py
"""
import os
import subprocess
import sys
import textwrap

from repro.configs import get_config
from repro.data.synthetic import serving_requests
from repro.serve.engine import ServingEngine
from repro.train.loop import init_model
from repro.trust.protocol import TrustConfig

# ------------------------------------------------ 1. serving engine
print("=== batched serving (smollm-360m reduced config) ===")
cfg = get_config("smollm-360m", smoke=True)
params = init_model(cfg, seed=0)
engine = ServingEngine(cfg, params, batch_slots=4, cache_len=96)
requests = list(serving_requests(cfg.vocab_size, 10, max_prompt=24,
                                 max_new=8, seed=0))
engine.submit(requests)
done = engine.run()
for rid in done:
    print(f"  request {rid}: generated {len(done[rid])} tokens "
          f"{done[rid][:6]}...")

# ------------------------- 2. verified serving (optimistic trust layer)
print("\n=== verified serving session (commit-challenge-audit) ===")
trust = TrustConfig(audit_rate=0.5, num_verifiers=2, challenge_window=6)
veng = ServingEngine(cfg, params, batch_slots=4, cache_len=96, trust=trust)
veng.submit(requests)
vdone = veng.run()
print(f"  finalized {len(vdone)}/{len(requests)} requests "
      f"(pending windows: {len(veng.pending_finalization)})")
assert {rid: toks for rid, toks in vdone.items()} == dict(done), \
    "verified session must serve the same tokens, just later"
commits = [e for e in veng.session_log if e["event"] == "commit"]
finals = [e for e in veng.session_log if e["event"] == "finalize"]
print(f"  session log: {len(commits)} commitments, {len(finals)} finalized")
print(f"  e.g. request {commits[0]['request']}: root "
      f"{commits[0]['root']}..., committed at tick {commits[0]['tick']}, "
      f"finalized at tick {finals[0]['tick']}")
# the audit pass: sampled per-tick leaves re-checked against each root
reports = veng.audit_all()
print(f"  audits: {len(reports)} passes, "
      f"{sum(len(r['sampled']) for r in reports)} leaves sampled, "
      f"revoked: {sum(r['revoked'] for r in reports)}")
# a tampered stream is caught and never finalizes
rid = requests[0]["id"]
rec = veng.records[rid]
rec.tokens = [t ^ 1 for t in rec.tokens]   # executor alters the stream
tam = [veng.audit_session(rid, v) for v in range(trust.num_verifiers)]
caught = any(t["revoked"] for t in tam)
print(f"  tampered request {rid}: revoked by audit -> {caught}; "
      f"still finalized -> {rid in veng.completed}")

# -------------------------------- 3. trusted vote on a replica mesh
print("\n=== B-MoE consensus at LM scale (r=4 replicas, 1 malicious) ===")
code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.trusted_moe import make_trust, LMAttack
from repro.models.config import RedundancyConfig
mesh = jax.make_mesh((1, 4, 2), ("data", "replica", "model"))
y = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 8, 32))  # (B,E,C,d)
for mode in ("faithful", "digest"):
    trust = make_trust(mesh, RedundancyConfig(4, mode), True,
                       LMAttack(malicious_replicas=(2,), noise_std=4.0))
    with mesh:
        out = jax.jit(trust)(y)
    ok = np.allclose(np.asarray(out), np.asarray(y), atol=1e-6)
    print(f"  mode={mode}: attack repaired by consensus -> {ok}")
"""
env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src") \
    + os.pathsep + env.get("PYTHONPATH", "")
out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                     capture_output=True, text=True, env=env)
print(out.stdout, end="")
if out.returncode:
    print(out.stderr)
print("done")
