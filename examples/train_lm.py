"""End-to-end LM training driver (deliverable (b)): train a ~100M-param
decoder on the synthetic bigram stream for a few hundred steps and show
the loss dropping toward the structure's entropy floor.

Run:  PYTHONPATH=src python examples/train_lm.py [steps] [--arch ID]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.data.synthetic import lm_batches
from repro.optim.adamw import AdamWConfig
from repro.train.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("steps", nargs="?", type=int, default=300)
ap.add_argument("--arch", default="smollm-360m")
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

# ~100M-scale variant of the chosen family that trains on CPU
cfg = get_config(args.arch)
cfg = dataclasses.replace(
    cfg, num_layers=4, num_blocks=4 // len(cfg.block_pattern) or 1,
    remainder=(), d_model=512,
    num_heads=8, num_kv_heads=4,   # GQA 2:1 (kv must divide heads)
    head_dim=64, d_ff=1536, vocab_size=8192, train_microbatches=1,
    num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
    moe_d_ff=min(cfg.moe_d_ff, 512) if cfg.moe_d_ff else 0).validate()
from repro.launch.costmodel import param_counts
print(f"arch={cfg.name} params={param_counts(cfg)['total']/1e6:.1f}M "
      f"steps={args.steps}")

batches = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0,
                     p_structured=0.9)
params, history = train(
    cfg, batches, steps=args.steps,
    opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    log_every=max(args.steps // 15, 1),
    callback=lambda m: print(f"  step {m['step']:4d} loss={m['loss']:.4f} "
                             f"lr={m['lr']:.2e} "
                             f"({m['wall_s']:.0f}s)"))
first, last = history[0]["loss"], history[-1]["loss"]
print(f"loss {first:.3f} -> {last:.3f} "
      f"({'DECREASED' if last < first - 0.5 else 'check hyperparams'})")
