"""CI link check: every relative link/path reference in the repo's
markdown must resolve.

Checks, over all tracked ``*.md`` files:

- inline markdown links ``[text](target)`` whose target is not a URL or
  a pure ``#anchor`` — the file (or directory) must exist relative to
  the markdown file (targets may carry a ``#fragment``, which is
  stripped; fragments themselves are not validated);
- backticked repo paths like ``src/repro/trust/README.md`` — any
  backticked token that looks like a path (contains ``/``) AND ends in
  a known source extension must exist relative to the repo root, the
  markdown file, or ``src/repro/`` (the docs' shorthand convention:
  ``core/bmoe.py`` means ``src/repro/core/bmoe.py``).  This is what
  catches stale prose references (e.g. docs pointing at a module that
  was renamed) that the link syntax check cannot see.

``SNIPPETS.md`` is skipped: it quotes exemplar files from *other*
repositories verbatim, links and all.

Exit 1 with a ``file:line`` listing on any miss.

Run:  python tools/check_md_links.py  (from the repo root)
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backticked repo-relative path with a recognizable source suffix
CODEPATH = re.compile(
    r"`([A-Za-z0-9_.\-]+(?:/[A-Za-z0-9_.\-]+)+"
    r"\.(?:py|md|json|yml|yaml|toml|txt))`")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")
SKIP_FILES = {"SNIPPETS.md"}         # verbatim exemplar content
# docs shorthand: `trust/protocol.py` means src/repro/trust/protocol.py
PREFIXES = ("", "src/repro/")


def md_files() -> list[Path]:
    try:
        out = subprocess.run(["git", "ls-files", "*.md", "**/*.md"],
                             cwd=ROOT, capture_output=True, text=True,
                             check=True).stdout.split()
        if out:
            return [ROOT / p for p in out]
    except (subprocess.CalledProcessError, FileNotFoundError):
        pass
    return [p for p in ROOT.rglob("*.md")
            if ".git" not in p.parts and "__pycache__" not in p.parts]


def check(path: Path) -> list[str]:
    errs = []
    for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), 1):
        for target in LINK.findall(line):
            if target.startswith(SKIP_SCHEMES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                errs.append(f"{path.relative_to(ROOT)}:{lineno}: "
                            f"broken link -> {target}")
        for target in CODEPATH.findall(line):
            if not any((base / pre / target).exists()
                       for base in (ROOT, path.parent)
                       for pre in PREFIXES):
                errs.append(f"{path.relative_to(ROOT)}:{lineno}: "
                            f"stale path reference -> {target}")
    return errs


def main() -> int:
    errors = [e for p in md_files() if p.name not in SKIP_FILES
              for e in check(p)]
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"[md-links] {len(errors)} broken reference(s)",
              file=sys.stderr)
        return 1
    print(f"[md-links] ok: {len(md_files())} markdown files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
