"""Slot scheduler for the serving engine: request queue, slot lifecycle,
and the admission policy.

Two policies:

- ``"continuous"`` (default): continuous batching.  Every tick, finished
  slots are evicted and free slots admit from the queue immediately —
  a request never waits for the rest of its batch to drain.  Admitted
  requests enter the PREFILL phase (their prompt is chunk-consumed by
  the engine's fused serve step while co-batched slots keep decoding)
  and hand off to DECODE at the prompt boundary.
- ``"fixed"``: the legacy fixed-slot baseline.  Requests are admitted
  batch-synchronously — only when every slot is idle — and prompts are
  fed token-by-token through the decode step (no chunk prefill), which
  is exactly the engine this repo shipped before continuous batching.
  Kept as the benchmark baseline and the trust-equivalence oracle.

A slot's request lifecycle (see ``src/repro/serve/README.md``):

    queued -> prefill -> decode -> finished -> challenge window
                                                -> finalized | revoked

The scheduler owns everything up to "finished"; the trust layer
(challenge windows, audits, revocation) lives in the engine.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, Iterable, List, Optional, Tuple

import numpy as np

POLICIES = ("continuous", "fixed")


@dataclasses.dataclass
class SlotState:
    """One batch slot.  ``request_id < 0`` means the slot is free."""
    request_id: int = -1
    pos: int = 0                         # tokens written into this slot's cache
    prompt: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    cursor: int = 0                      # next prompt token to consume
    to_generate: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    admitted_tick: int = -1
    first_token_tick: int = -1

    @property
    def active(self) -> bool:
        return self.request_id >= 0

    @property
    def prefilling(self) -> bool:
        return self.active and self.cursor < len(self.prompt)

    @property
    def decoding(self) -> bool:
        return self.active and not self.prefilling


class SlotScheduler:
    """Admission/eviction over a fixed set of batch slots.

    The engine drives it once per tick: ``admit(tick)`` fills free slots
    from the queue (policy-dependent), the engine runs its prefill and
    decode steps against ``slots``, and ``release(i)`` evicts a finished
    slot so the *next* tick can admit into it."""

    def __init__(self, num_slots: int, policy: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.policy = policy
        self.slots = [SlotState() for _ in range(num_slots)]
        self.queue: Deque[dict] = deque()
        self.submit_order: List[int] = []
        self.meta: Dict[int, Dict[str, int]] = {}   # rid -> tick milestones

    # ------------------------------------------------------------ intake
    def submit(self, requests: Iterable[dict], tick: int = 0) -> None:
        for r in requests:
            if r["id"] < 0:
                raise ValueError(f"request id {r['id']} < 0 "
                                 "(negative ids mark free slots)")
            self.queue.append(r)
            self.submit_order.append(r["id"])
            self.meta[r["id"]] = {"submitted_tick": tick,
                                  "admitted_tick": -1,
                                  "first_token_tick": -1,
                                  "finished_tick": -1}

    # --------------------------------------------------------- admission
    def admit(self, tick: int) -> List[Tuple[int, SlotState]]:
        """Admit queued requests into free slots; returns the newly
        filled ``(slot_index, slot)`` pairs (whose caches the engine must
        reset).  Continuous policy admits whenever a slot is free; fixed
        policy only refills a fully drained batch."""
        if not self.queue:
            return []
        if self.policy == "fixed" and any(s.active for s in self.slots):
            return []
        admitted = []
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            r = self.queue.popleft()
            slot.request_id = r["id"]
            slot.pos = 0
            slot.prompt = np.asarray(r["prompt"], np.int32).reshape(-1)
            slot.cursor = 0
            slot.to_generate = int(r["max_new_tokens"])
            slot.generated = []
            slot.admitted_tick = tick
            slot.first_token_tick = -1
            self.meta[r["id"]]["admitted_tick"] = tick
            admitted.append((i, slot))
        return admitted

    def release(self, index: int, tick: int) -> int:
        """Evict a finished slot; returns the request id it held."""
        slot = self.slots[index]
        rid = slot.request_id
        self.meta[rid]["finished_tick"] = tick
        slot.request_id = -1
        return rid

    def preempt(self, index: int, tick: int) -> int:
        """Page a RUNNING slot out: free the slot and requeue its
        request at the queue FRONT, so it resumes before newly queued
        work.  The engine owns the resume state (cache rows sealed to
        the KV store, generated tokens, positions) — the scheduler only
        re-enqueues the original request.  Returns the request id."""
        slot = self.slots[index]
        if not slot.active:
            raise ValueError(f"slot {index} is not active")
        rid = slot.request_id
        self.queue.appendleft({"id": rid, "prompt": slot.prompt,
                               "max_new_tokens": slot.to_generate})
        meta = self.meta[rid]
        meta["preemptions"] = meta.get("preemptions", 0) + 1
        slot.request_id = -1
        return rid

    # ------------------------------------------------------------- views
    @property
    def num_slots(self) -> int:
        return len(self.slots)

    @property
    def any_active(self) -> bool:
        return any(s.active for s in self.slots)

    @property
    def num_active(self) -> int:
        return sum(1 for s in self.slots if s.active)

    def active_requests(self) -> List[int]:
        return [s.request_id for s in self.slots if s.active]

    def occupancy(self) -> float:
        return self.num_active / max(self.num_slots, 1)

    def depth(self) -> int:
        """Requests waiting in the queue (not yet admitted)."""
        return len(self.queue)

    def prefill_lengths(self, chunk: int, cache_len: int,
                        fresh: Optional[set] = None) -> np.ndarray:
        """Per-slot prompt tokens to consume this tick, capped by the
        chunk size, the remaining prompt, and the slot's cache headroom.
        ``fresh``: slot indices admitted *this* tick (continuous policy
        prefills them immediately); 0 for slots not prefilling."""
        n = np.zeros(self.num_slots, np.int32)
        for i, s in enumerate(self.slots):
            if not s.prefilling:
                continue
            room = cache_len - 1 - s.pos
            n[i] = max(0, min(chunk, len(s.prompt) - s.cursor, room))
        return n
