"""Batched serving engine: continuous-batching-style slot manager over the
single-token ``decode_step`` with a fixed-capacity KV cache.

Requests (prompt + max_new_tokens) are packed into batch slots; prompts
are prefilled token-by-token through the decode path (CPU-scale; on TPU
the prefill_step handles whole prompts), generation is greedy, and
finished slots are refilled from the queue — the serving analogue of the
paper's edge-layer inference (Steps 1-3, no updates).

Verified sessions (``trust=TrustConfig(...)``): the optimistic
commit-challenge-audit protocol from ``repro.trust`` applied to
streaming inference.  Every engine tick appends a leaf digest of the
slot's emitted token to the request's session commitment; when the
request finishes, the Merkle root over its per-tick leaves is recorded
in the session log and the request enters an asynchronous challenge
window (measured in engine ticks).  ``completed`` exposes only
*finalized* requests — window closed with no revocation — and auditors
can spot-check sampled leaves against the committed root at any time
(``audit_session``); a mismatch revokes the request instead of
finalizing it.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.builder import materialize
from repro.models.config import ModelConfig
from repro.obs import Observability
from repro.obs.metrics import MetricsRegistry
from repro.storage import (ExpertCache, ExpertStore, GateEMA,
                           StorageNetwork)
from repro.train.step import make_decode_step
from repro.trust.audit import VerifierPool
from repro.trust.commitments import MerkleTree, RoundCommitment, leaf_digest
from repro.trust.protocol import ChallengeWindow, TrustConfig


@dataclasses.dataclass
class SlotState:
    request_id: int = -1
    pos: int = 0
    prompt: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int32))
    cursor: int = 0                      # next prompt token to consume
    to_generate: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.request_id >= 0

    @property
    def prefilling(self) -> bool:
        return self.cursor < len(self.prompt)


@dataclasses.dataclass(frozen=True)
class EdgeStorageConfig:
    """Serving-edge expert storage (paper: the edge layer "employs the
    activated experts downloaded from the storage layer").

    With this config the engine registers every MoE layer's per-expert
    weights as chunked content-addressed objects in a ``StorageNetwork``
    and resolves, each tick, exactly the experts that tick routed to
    through a bounded ``ExpertCache`` — cold ticks fetch, warm ticks hit
    (serving params are frozen, so the manifests never go stale).  A
    ``GateEMA`` over the per-tick routing counts drives prefetch of the
    hottest experts into spare cache capacity."""
    cache_bytes: Optional[int] = None      # None: unbounded
    chunk_bytes: int = 1 << 15
    prefetch_topk: int = 0
    ema_decay: float = 0.8
    num_nodes: int = 4
    replication: int = 2
    seed: int = 0


class _EdgeExpertRuntime:
    """The engine's storage-layer sidecar: per-(MoE layer, expert) units
    registered once at startup, resolved per tick from the decode step's
    routing counts (layer order identical to
    ``transformer.forward_decode(expert_stats=True)``: scanned blocks
    block-major, then the remainder)."""

    def __init__(self, cfg: ModelConfig, params, scfg: EdgeStorageConfig,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.network = StorageNetwork(num_nodes=scfg.num_nodes,
                                      replication=scfg.replication,
                                      seed=scfg.seed, metrics=metrics,
                                      namespace="edge.network")
        self.store = ExpertStore(self.network, chunk_bytes=scfg.chunk_bytes,
                                 metrics=metrics, namespace="edge.store")
        self.cache = ExpertCache(self.store, scfg.cache_bytes,
                                 metrics=metrics, namespace="edge.cache")
        self._like: List[Dict] = []           # per layer: one unit template
        self._n_real = cfg.num_experts
        self._register(params)
        self.ema = GateEMA(len(self._like) * self._n_real,
                           decay=scfg.ema_decay)
        self.ticks = 0

    @property
    def num_layers(self) -> int:
        return len(self._like)

    def _unit_id(self, layer: int, expert: int) -> str:
        return f"moe/{layer}/{expert}"

    def _register(self, params) -> None:
        """Chunk every (layer, expert) unit into the storage network
        (version 0 — serving weights are frozen).  Router and shared-
        expert weights stay gate-side resident: they run every tick."""
        def units_of(moe_params):
            # routed-expert weights only: (E, ...) leading expert axis
            routed = {k: np.asarray(moe_params[k])
                      for k in ("w_gate", "w_up", "w_down")}
            layer = len(self._like)
            self._like.append({k: a[0] for k, a in routed.items()})
            for e in range(self._n_real):
                self.store.put_version(self._unit_id(layer, e),
                                       {k: a[e] for k, a in routed.items()},
                                       0)

        nb = self.cfg.resolved_num_blocks
        blocks = params.get("blocks", {})
        for b in range(nb):
            for i, spec in enumerate(self.cfg.block_pattern):
                if spec.mlp == "moe":
                    units_of(jax.tree_util.tree_map(
                        lambda a: a[b], blocks[str(i)]["moe"]))
        for i, spec in enumerate(self.cfg.remainder):
            if spec.mlp == "moe":
                units_of(params["remainder"][i]["moe"])

    def on_tick(self, stats: np.ndarray) -> None:
        """Resolve the experts this tick activated (pinned during the
        resolve), feed the EMA, and prefetch the hottest units into
        spare capacity."""
        stats = np.asarray(stats)[:, :self._n_real]
        flat = stats.reshape(-1).astype(np.float64)
        active = [(int(l), int(e)) for l, e in zip(*np.nonzero(stats))]
        ids = [self._unit_id(l, e) for l, e in active]
        self.cache.pin(ids)
        try:
            for (layer, e), oid in zip(active, ids):
                self.cache.get(oid, 0, self._like[layer])
            self.ema.update(flat)
            if self.scfg.prefetch_topk:
                ranked = [self._unit_id(u // self._n_real, u % self._n_real)
                          for u in self.ema.ranking()[:self.scfg.prefetch_topk]]
                self.cache.prefetch(
                    ranked, 0,
                    lambda oid: self._like[int(oid.split("/")[1])])
        finally:
            self.cache.unpin(ids)
        self.ticks += 1

    def report(self) -> Dict:
        # same keys as pre-obs; with a registry the stats dicts are live
        # views over the edge.{cache,store,network}.* metrics
        return {"cache": dict(self.cache.stats),
                "store": dict(self.store.stats),
                "network": dict(self.network.stats),
                "units": len(self._like) * self._n_real,
                "ticks": self.ticks}


def _tick_leaf(request_id: int, tick: int, token: int) -> str:
    """Leaf digest of one committed engine tick.  The (1, 3) row layout
    matches ``RoundCommitment.leaf_chunk`` for a one-tick-per-leaf
    commitment, so session audits run through the same batched
    ``VerifierPool`` path as training audits."""
    return leaf_digest(np.array([[request_id, tick, token]], np.int64))


@dataclasses.dataclass
class SessionRecord:
    """Per-request commitment stream: one leaf per generated token."""
    request_id: int
    leaves: List[str] = dataclasses.field(default_factory=list)
    ticks: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    root: str = ""
    finalized: bool = False
    revoked: bool = False
    audited: bool = False              # at least one spot-check pass ran

    def append(self, tick: int, token: int) -> None:
        self.leaves.append(_tick_leaf(self.request_id, tick, token))
        self.ticks.append(tick)
        self.tokens.append(token)

    def seal(self) -> str:
        self.root = MerkleTree(self.leaves).root
        return self.root

    def commitment(self) -> RoundCommitment:
        """The sealed session as a RoundCommitment: one (pseudo-)expert,
        one tick per leaf — what lets ``VerifierPool.audit_batched``
        audit a serving session and a training round through one code
        path.  ``claimed`` holds the *current* stream records; the
        sealed ``leaf_digests`` are what they are checked against."""
        t = len(self.leaves)
        claimed = np.array(
            [[[self.request_id, self.ticks[i], self.tokens[i]]
              for i in range(t)]], np.int64)
        return RoundCommitment(
            round_id=self.request_id, executor=-1, root=self.root,
            num_experts=1, chunks_per_expert=t, bounds=list(range(t + 1)),
            leaf_digests=list(self.leaves), claimed=claimed)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 cache_len: int = 256, mesh=None,
                 trust: Optional[TrustConfig] = None,
                 expert_storage: Optional[EdgeStorageConfig] = None,
                 obs: Optional[Observability] = None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError("engine drives decoder-only archs")
        self.cfg = cfg
        self.params = params
        self.obs = obs if obs is not None else Observability()
        self.batch = batch_slots
        self.cache_len = cache_len
        self.caches = materialize(
            tfm.cache_decl(cfg, batch_slots, cache_len),
            jax.random.PRNGKey(0))
        # ---- edge expert storage (MoE models): per-tick resolution of
        # the activated experts through a bounded ExpertCache, fed by
        # the decode step's routing counts
        self.edge = None
        if expert_storage is not None:
            has_moe = any(s.mlp == "moe"
                          for s in list(cfg.block_pattern)
                          + list(cfg.remainder))
            if not has_moe:
                raise ValueError("expert_storage needs a MoE model")
            self.edge = _EdgeExpertRuntime(cfg, params, expert_storage,
                                           metrics=self.obs.metrics)
        self._decode = jax.jit(make_decode_step(
            cfg, mesh, expert_stats=self.edge is not None))
        self.slots = [SlotState() for _ in range(batch_slots)]
        self.queue: deque = deque()
        self.tick = 0
        self._tick_lat_s = 0.0          # decode latency of the last tick
        self._submit_order: List[int] = []
        self._done: Dict[int, List[int]] = {}
        # ---- verified-session state (optimistic trust layer)
        self.trust = trust
        self.records: Dict[int, SessionRecord] = {}
        self.session_log: List[Dict] = []       # commit/finalize/revoke events
        self._window = (ChallengeWindow(trust.challenge_window)
                        if trust is not None else None)
        # audit_rate is the pool-wide sampled fraction (same contract as
        # OptimisticProtocol): each verifier draws its stake-weighted
        # share, and session re-audits catch rubber-stampers too
        self._auditors = (VerifierPool(
            trust.num_verifiers,
            trust.audit_rate / max(trust.num_verifiers, 1),
            trust.lazy_verifier_prob, trust.seed,
            stakes=trust.verifier_stakes, reaudit_rate=trust.reaudit_rate,
            verifier_slash_fraction=trust.verifier_slash_fraction,
            metrics=self.obs.metrics, namespace="serve.verifiers")
            if trust is not None else None)
        self._finalized: set = set()
        # deadline-ordered auto-audit queue: a sealed session's audit is
        # parked off the critical path and drained (whole backlog at
        # once, mirroring OptimisticProtocol.pop_audit_jobs) when the
        # oldest challenge window is about to close — so a tampered
        # stream is caught *before* it can finalize
        self._audit_queue: List[Tuple[int, int]] = []   # (deadline, rid)
        # sessions neither finalized nor revoked: the only ones the
        # finality-deferral and chained-revocation scans must touch —
        # O(open), not O(all sessions ever served)
        self._open_sessions: set = set()

    @property
    def verified(self) -> bool:
        return self.trust is not None

    @property
    def completed(self) -> Dict[int, List[int]]:
        """Finished — and, in verified mode, *finalized* — requests, in
        request-submission order (deterministic output)."""
        if not self.verified:
            return {rid: self._done[rid] for rid in self._submit_order
                    if rid in self._done}
        return {rid: self._done[rid] for rid in self._submit_order
                if rid in self._finalized}

    @property
    def pending_finalization(self) -> List[int]:
        """Finished requests still inside their challenge window."""
        if not self.verified:
            return []
        return [rid for rid in self._submit_order
                if rid in self._done and rid not in self._finalized
                and not self.records[rid].revoked]

    def submit(self, requests: Iterable[dict]):
        for r in requests:
            self.queue.append(r)
            self._submit_order.append(r["id"])

    def _fill_slots(self):
        # batch-synchronous refill: new requests enter only when the whole
        # batch drained, so every slot shares one decode position and no
        # slot attends a predecessor's stale cache rows
        if any(s.active for s in self.slots):
            return
        if not self.queue:
            return
        self.caches = jax.tree_util.tree_map(jnp.zeros_like, self.caches)
        for slot in self.slots:
            if self.queue:
                r = self.queue.popleft()
                slot.request_id = r["id"]
                slot.pos = 0
                slot.prompt = np.asarray(r["prompt"], np.int32).reshape(-1)
                slot.cursor = 0
                slot.to_generate = int(r["max_new_tokens"])
                slot.generated = []
                if self.verified:
                    self.records[r["id"]] = SessionRecord(request_id=r["id"])
                    self._open_sessions.add(r["id"])

    def _emit(self, slot: SlotState, token: int) -> None:
        slot.generated.append(token)
        m = self.obs.metrics
        m.counter("serve.tokens").add(1)
        m.histogram("serve.token_latency_s").observe(self._tick_lat_s)
        m.histogram("serve.token_latency_s",
                    session=slot.request_id).observe(self._tick_lat_s)
        if self.verified:
            self.records[slot.request_id].append(self.tick, token)

    def _finish(self, slot: SlotState) -> None:
        rid = slot.request_id
        self._done[rid] = slot.generated[:slot.to_generate]
        slot.request_id = -1
        if not self.verified:
            return
        rec = self.records[rid]
        root = rec.seal() if rec.leaves else ""
        self.session_log.append({"event": "commit", "request": rid,
                                 "root": root[:16], "tick": self.tick,
                                 "leaves": len(rec.leaves)})
        self._window.enter(rid, self.tick)
        if rec.leaves:
            heapq.heappush(self._audit_queue,
                           (self.tick + self.trust.challenge_window, rid))

    def _audit_full(self, rid: int) -> None:
        """One spot-check pass per verifier (stopping early once a fraud
        revokes the session)."""
        for v in range(self._auditors.num_verifiers):
            self.audit_session(rid, v)
            if self.records[rid].revoked:
                break

    def _drain_session_audits(self) -> None:
        """Run queued session audits once the oldest deadline is due —
        and then the whole backlog, so audits burst off the critical
        path instead of blocking every tick."""
        if not self._audit_queue or self._audit_queue[0][0] > self.tick:
            return
        # burst drains off the critical path: booked to serve.audit_s and
        # excluded from the enclosing tick span's serve.tick_s
        drained = [rid for _, rid in self._audit_queue]
        with self.obs.span("audit-drain", metric="serve.audit_s",
                           off_path=True, tick=self.tick, drained=drained):
            while self._audit_queue:
                _, rid = heapq.heappop(self._audit_queue)
                rec = self.records[rid]
                if rec.revoked or not rec.root:
                    continue
                self._audit_full(rid)

    @staticmethod
    def _overlaps(a: SessionRecord, b: SessionRecord) -> bool:
        return (bool(a.ticks) and bool(b.ticks)
                and b.ticks[0] <= a.ticks[-1] and a.ticks[0] <= b.ticks[-1])

    def _expire_windows(self) -> None:
        self._drain_session_audits()
        for rid in self._window.expire(self.tick):
            rec = self.records[rid]
            if rec.revoked:
                continue
            # serving-side sequential finality: a stream cannot finalize
            # while a tick-overlapping co-batched stream is still being
            # produced (its later-confirmed fraud would void this one) or
            # is sealed but unchecked — spot-check the neighbour first,
            # which revokes this stream too if the neighbour was altered
            deferred = False
            for rid2 in list(self._open_sessions):
                dep = self.records[rid2]
                if rid2 == rid or dep.revoked \
                        or not self._overlaps(rec, dep):
                    continue
                if not dep.root:
                    if rid2 not in self._done:   # neighbour still streaming
                        self._window.hold(rid, self.tick + 1)
                        deferred = True
                        break
                    continue                     # empty session: no leaves
                if not dep.audited:
                    self._audit_full(rid2)
            if deferred or rec.revoked:
                continue
            rec.finalized = True
            self._finalized.add(rid)
            self._open_sessions.discard(rid)
            self.session_log.append({"event": "finalize", "request": rid,
                                     "tick": self.tick})

    def step(self):
        """One engine tick: each active slot consumes one prompt token or
        generates one token.  (All slots share one decode position per
        tick; a per-slot position mask keeps semantics correct.)  In
        verified mode, ticks keep running after the queue drains until
        every challenge window has closed."""
        with self.obs.span("tick", metric="serve.tick_s", tick=self.tick):
            return self._step_inner()

    def _step_inner(self):
        self._fill_slots()
        if not any(s.active for s in self.slots):
            if self.verified and len(self._window):
                self.tick += 1               # idle tick: windows still age
                self._expire_windows()
                return bool(len(self._window))
            return False
        tokens = np.zeros((self.batch, 1), np.int32)
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            if s.prefilling:
                tokens[i, 0] = s.prompt[s.cursor]
            elif s.generated:
                tokens[i, 0] = s.generated[-1]
        pos = max((s.pos for s in self.slots if s.active), default=0)
        batch = {"tokens": jnp.asarray(tokens), "pos": jnp.int32(pos)}
        with self.obs.span("decode", metric="serve.decode_s",
                           tick=self.tick) as dsp:
            if self.edge is not None:
                nxt, self.caches, stats = self._decode(self.params,
                                                       self.caches, batch)
                # resolve THIS tick's activated experts through the edge
                # cache (cold: chunk fetches; warm: hits) + EMA prefetch
                self.edge.on_tick(np.asarray(stats))
            else:
                nxt, self.caches = self._decode(self.params, self.caches,
                                                batch)
            nxt = np.asarray(nxt)
        # every token emitted this tick shares the tick's decode latency
        self._tick_lat_s = dsp.dur_s
        self.tick += 1
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            if s.prefilling:
                s.cursor += 1
                if not s.prefilling:
                    self._emit(s, int(nxt[i]))   # first generated token
            else:
                self._emit(s, int(nxt[i]))
            s.pos += 1
            done = (not s.prefilling
                    and len(s.generated) >= s.to_generate)
            if done or s.pos >= self.cache_len - 1:
                self._finish(s)
        if self.verified:
            self._expire_windows()
        return True

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        ticks = 0
        while self.step() and ticks < max_ticks:
            ticks += 1
        return self.completed

    def obs_report(self) -> Dict:
        """Serving-side view over the metrics registry: tick/token
        throughput, wall-clock totals, token-latency percentiles
        (aggregate and per session), plus the edge storage section when
        edge expert storage is on."""
        m = self.obs.metrics
        out = {
            "ticks": self.tick,
            "tokens": int(m.value("serve.tokens")),
            "tick_s": float(m.value("serve.tick_s")),
            "decode_s": float(m.value("serve.decode_s")),
            "audit_offpath_s": float(m.value("serve.audit_s")),
            "token_latency": m.histogram("serve.token_latency_s").snapshot(),
            "sessions": {
                name.split("session=", 1)[1].rstrip("}"): snap
                for name, snap in
                m.snapshot("serve.token_latency_s{").items()},
        }
        if self.edge is not None:
            out["edge"] = self.edge.report()
        return out

    def report(self) -> Dict:
        return self.obs_report()

    # ------------------------------------------------ audits (verified)
    def audit_session(self, request_id: int, verifier: int = 0) -> Dict:
        """Spot-check sampled leaves of a session commitment through the
        same batched auditor as training rounds: the sampled (tick,
        token) records are re-digested in one ``leaf_digest_batch`` pass
        and compared against the sealed leaves.  A mismatch (the served
        stream was altered after commitment) revokes the request: it
        will never finalize."""
        if not self.verified:
            raise ValueError("engine was not started with a TrustConfig")
        rec = self.records[request_id]
        if not rec.root:
            raise ValueError(f"request {request_id} not sealed yet")
        com = rec.commitment()

        def batch_recompute(experts, slices):
            # honest recompute of a session leaf = re-encoding the served
            # (tick, token) record; leaf i covers batch row i
            rows = [[request_id, rec.ticks[sl.start], rec.tokens[sl.start]]
                    for sl in slices]
            return np.asarray(rows, np.int64)[:, None, :]

        [report] = self._auditors.audit_batched(com, batch_recompute,
                                                verifiers=[verifier])

        def recompute(e: int, sl: slice):
            return np.array([[request_id, rec.ticks[sl.start],
                              rec.tokens[sl.start]]], np.int64)

        # second-layer lottery (reaudit_rate > 0): spot-check this
        # verifier's salted recompute attestations — a rubber-stamping
        # session auditor is slashed out of future lotteries just like a
        # training-round one
        self._auditors.reaudit(com, [report], recompute)
        sampled = report.sampled_leaves
        mismatches = [p.leaf_index for p in report.fraud_proofs]
        # Merkle-path check against the SEALED root: catches a consistent
        # post-seal rewrite of both the record and its leaf digest, which
        # the digest comparison alone (recompute vs current leaf list)
        # cannot see
        tree = MerkleTree(rec.leaves)
        if tree.root != rec.root:
            mismatches = sorted(set(mismatches) | {
                leaf for leaf in sampled
                if not MerkleTree.verify(rec.root, rec.leaves[leaf],
                                         tree.prove(leaf))})
        rec.audited = True
        if mismatches:
            self._revoke_session(request_id, mismatches)
        return {"request": request_id, "sampled": sampled,
                "mismatches": mismatches, "revoked": rec.revoked}

    def _revoke_session(self, request_id: int, mismatches: List[int]) -> None:
        """Revoke a session, then chain the revocation: every session
        whose ticks overlap the revoked stream's and whose window is
        still open is revoked with it — those tokens came out of the
        same batched decode calls as the fraudulent ones, so their
        provenance is void (the per-tick analogue of the training
        pipeline's INVALIDATED descendants; no separate fraud is booked
        for them).  Already-finalized sessions are immune: their windows
        closed clean before the fraud was confirmed."""
        rec = self.records[request_id]
        rec.revoked = True
        rec.finalized = False            # a revoked record is never final
        self._finalized.discard(request_id)
        self._open_sessions.discard(request_id)
        self._window.revoke(request_id)
        self.session_log.append({"event": "revoke", "request": request_id,
                                 "leaves": mismatches})
        for rid in list(self._open_sessions):
            dep = self.records[rid]
            if dep.revoked or dep.finalized or not self._overlaps(rec, dep):
                continue
            dep.revoked = True
            self._finalized.discard(rid)
            self._open_sessions.discard(rid)
            self._window.revoke(rid)
            self.session_log.append({"event": "revoke_dependent",
                                     "request": rid,
                                     "cause": request_id})

    def audit_all(self) -> List[Dict]:
        return [self.audit_session(rid, v)
                for rid in list(self.records)
                if self.records[rid].root
                for v in range(self._auditors.num_verifiers)]
