"""Continuous-batching serving engine with prefill/decode disaggregation.

The engine serves requests (prompt + max_new_tokens) from a fixed set of
batch slots, but — unlike the fixed-slot engine it replaces — the batch
composition changes **every decode step**: finished requests are evicted
and queued requests admitted each tick (``scheduler.SlotScheduler``),
so a short request never waits for a long co-batched one to drain.
Prefill is disaggregated from decode inside ONE fused compiled step
(``train.step.make_serve_chunk_step``): each call runs C engine ticks
as a ``lax.scan`` in which prefilling slots consume up to C prompt
tokens while decoding slots keep generating autoregressively — so a
long prompt costs ceil(len/C) dispatches instead of len, and in-flight
decode never stalls behind a token-by-token prompt feed.  Shapes are
fixed per pow2 width bucket (per-slot positions, per-row write masks),
so occupancy changes never recompile and there is no per-token Python
dispatch inside a chunk.  ``scheduling="fixed"`` keeps the legacy
batch-synchronous engine (admit only into a drained batch, prompts fed
token-by-token through the decode step) as the benchmark baseline and
trust-equivalence oracle.

Verified sessions (``trust=TrustConfig(...)``): the optimistic
commit-challenge-audit protocol from ``repro.trust`` applied to
streaming inference.  Every emitted token is digested into a session
leaf, and the engine appends **one Merkle root per batch tick** — a
single tree over all slots' leaves for that tick
(``trust.session.commit_tick``), with per-session inclusion paths
derived from it — instead of one append per stream.  Per-session leaf
digests and sealed roots are unchanged, so ``audit_session`` verdicts
are bit-identical to the per-stream scheme on the same trace; the tick
tree adds an inclusion check that catches post-hoc rewrites of a
session's leaf list.  Finished requests enter an asynchronous challenge
window (engine ticks); ``completed`` exposes only *finalized* requests,
and a mismatching audit revokes a request instead of finalizing it.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.builder import materialize
from repro.models.config import ModelConfig
from repro.obs import Observability
from repro.obs.metrics import MetricsRegistry
from repro.serve.scheduler import SlotScheduler, SlotState
from repro.storage import (ExpertCache, ExpertStore, GateEMA,
                           StorageNetwork)
from repro.storage.kv import (KV_GENESIS, KVBlockStore, KVStorageConfig,
                              prefix_chain, prefix_cid)
from repro.train.step import make_serve_chunk_step
from repro.trust.audit import VerifierPool
from repro.trust.commitments import MerkleTree, RoundCommitment, leaf_digest
from repro.trust.da import DataAvailabilityAuditor
from repro.trust.protocol import ChallengeWindow, TrustConfig
from repro.trust.session import (SessionLeafRef, TickCommitment, commit_tick,
                                 verify_session_inclusion)

__all__ = ["EdgeStorageConfig", "KVStorageConfig", "ServingEngine",
           "SessionRecord", "SlotState"]


@dataclasses.dataclass(frozen=True)
class EdgeStorageConfig:
    """Serving-edge expert storage (paper: the edge layer "employs the
    activated experts downloaded from the storage layer").

    With this config the engine registers every MoE layer's per-expert
    weights as chunked content-addressed objects in a ``StorageNetwork``
    and resolves, each tick, exactly the experts that tick routed to
    through a bounded ``ExpertCache`` — cold ticks fetch, warm ticks hit
    (serving params are frozen, so the manifests never go stale).  A
    ``GateEMA`` over the per-tick routing counts drives prefetch of the
    hottest experts into spare cache capacity."""
    cache_bytes: Optional[int] = None      # None: unbounded
    chunk_bytes: int = 1 << 15
    prefetch_topk: int = 0
    ema_decay: float = 0.8
    num_nodes: int = 4
    replication: int = 2
    seed: int = 0


class _EdgeExpertRuntime:
    """The engine's storage-layer sidecar: per-(MoE layer, expert) units
    registered once at startup, resolved per tick from the routing
    counts of that tick's prefill + decode steps (layer order identical
    to ``transformer.forward_decode(expert_stats=True)``: scanned blocks
    block-major, then the remainder)."""

    def __init__(self, cfg: ModelConfig, params, scfg: EdgeStorageConfig,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.scfg = scfg
        self.network = StorageNetwork(num_nodes=scfg.num_nodes,
                                      replication=scfg.replication,
                                      seed=scfg.seed, metrics=metrics,
                                      namespace="edge.network")
        self.store = ExpertStore(self.network, chunk_bytes=scfg.chunk_bytes,
                                 metrics=metrics, namespace="edge.store")
        self.cache = ExpertCache(self.store, scfg.cache_bytes,
                                 metrics=metrics, namespace="edge.cache")
        self._like: List[Dict] = []           # per layer: one unit template
        self._n_real = cfg.num_experts
        self._register(params)
        self.ema = GateEMA(len(self._like) * self._n_real,
                           decay=scfg.ema_decay)
        self.ticks = 0

    @property
    def num_layers(self) -> int:
        return len(self._like)

    def _unit_id(self, layer: int, expert: int) -> str:
        return f"moe/{layer}/{expert}"

    def _register(self, params) -> None:
        """Chunk every (layer, expert) unit into the storage network
        (version 0 — serving weights are frozen).  Router and shared-
        expert weights stay gate-side resident: they run every tick."""
        def units_of(moe_params):
            # routed-expert weights only: (E, ...) leading expert axis
            routed = {k: np.asarray(moe_params[k])
                      for k in ("w_gate", "w_up", "w_down")}
            layer = len(self._like)
            self._like.append({k: a[0] for k, a in routed.items()})
            for e in range(self._n_real):
                self.store.put_version(self._unit_id(layer, e),
                                       {k: a[e] for k, a in routed.items()},
                                       0)

        nb = self.cfg.resolved_num_blocks
        blocks = params.get("blocks", {})
        for b in range(nb):
            for i, spec in enumerate(self.cfg.block_pattern):
                if spec.mlp == "moe":
                    units_of(jax.tree_util.tree_map(
                        lambda a: a[b], blocks[str(i)]["moe"]))
        for i, spec in enumerate(self.cfg.remainder):
            if spec.mlp == "moe":
                units_of(params["remainder"][i]["moe"])

    def on_tick(self, stats: np.ndarray) -> None:
        """Resolve the experts this tick activated (pinned during the
        resolve), feed the EMA, and prefetch the hottest units into
        spare capacity."""
        stats = np.asarray(stats)[:, :self._n_real]
        flat = stats.reshape(-1).astype(np.float64)
        active = [(int(l), int(e)) for l, e in zip(*np.nonzero(stats))]
        ids = [self._unit_id(l, e) for l, e in active]
        self.cache.pin(ids)
        try:
            for (layer, e), oid in zip(active, ids):
                self.cache.get(oid, 0, self._like[layer])
            self.ema.update(flat)
            if self.scfg.prefetch_topk:
                ranked = [self._unit_id(u // self._n_real, u % self._n_real)
                          for u in self.ema.ranking()[:self.scfg.prefetch_topk]]
                self.cache.prefetch(
                    ranked, 0,
                    lambda oid: self._like[int(oid.split("/")[1])])
        finally:
            self.cache.unpin(ids)
        self.ticks += 1

    def report(self) -> Dict:
        # same keys as pre-obs; with a registry the stats dicts are live
        # views over the edge.{cache,store,network}.* metrics
        return {"cache": dict(self.cache.stats),
                "store": dict(self.store.stats),
                "network": dict(self.network.stats),
                "units": len(self._like) * self._n_real,
                "ticks": self.ticks}


class _KVRuntime:
    """The engine's KV-paging sidecar: a ``KVBlockStore`` over either
    its own storage network or — when the edge expert runtime is also
    configured — the SAME store and cache as the expert weights, so KV
    blocks and experts compete under one byte budget and one LRU
    (experts are pinned while activated; cold KV evicts first).

    ``da_rate > 0`` adds data-availability challenges over the sealed
    KV chunks: the same corrupt-slash-repair / withhold-window-slash
    machinery that audits expert chunks (``repro.trust.da``)."""

    def __init__(self, kcfg: KVStorageConfig, shared=None,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = kcfg
        self.T = int(kcfg.block_tokens)
        if self.T < 1:
            raise ValueError(f"block_tokens {self.T} < 1")
        if shared is not None:
            self.store, self.cache = shared
            self.network = self.store.network
        else:
            self.network = StorageNetwork(num_nodes=kcfg.num_nodes,
                                          replication=kcfg.replication,
                                          seed=kcfg.seed, metrics=metrics,
                                          namespace="kv.network")
            self.store = ExpertStore(self.network,
                                     chunk_bytes=kcfg.chunk_bytes,
                                     metrics=metrics, namespace="kv.store")
            self.cache = ExpertCache(self.store, kcfg.cache_bytes,
                                     metrics=metrics, namespace="kv.cache")
        self.kv = KVBlockStore(self.store, self.cache, metrics=metrics)
        self.da = (DataAvailabilityAuditor(
            self.network, len(self.network.nodes), window=kcfg.da_window,
            sample_rate=kcfg.da_rate, seed=kcfg.seed, metrics=metrics,
            namespace="kv.da") if kcfg.da_rate > 0 else None)
        self.like = None                # block-structure template (lazy)

    def report(self) -> Dict:
        out = {**dict(self.kv.stats),
               "cache": dict(self.cache.stats),
               "store": dict(self.store.stats)}
        if self.da is not None:
            out["da"] = dict(self.da.stats)
        return out


def _tick_leaf(request_id: int, tick: int, token: int) -> str:
    """Leaf digest of one committed engine tick.  The (1, 3) row layout
    matches ``RoundCommitment.leaf_chunk`` for a one-tick-per-leaf
    commitment, so session audits run through the same batched
    ``VerifierPool`` path as training audits."""
    return leaf_digest(np.array([[request_id, tick, token]], np.int64))


@dataclasses.dataclass
class SessionRecord:
    """Per-request commitment stream: one leaf per generated token, plus
    (in the batched-commitment engine) one inclusion reference per leaf
    into the batch tick tree it was committed under."""
    request_id: int
    leaves: List[str] = dataclasses.field(default_factory=list)
    ticks: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    refs: List[SessionLeafRef] = dataclasses.field(default_factory=list)
    root: str = ""
    finalized: bool = False
    revoked: bool = False
    audited: bool = False              # at least one spot-check pass ran

    def append(self, tick: int, token: int) -> None:
        self.leaves.append(_tick_leaf(self.request_id, tick, token))
        self.ticks.append(tick)
        self.tokens.append(token)

    def seal(self) -> str:
        self.root = MerkleTree(self.leaves).root
        return self.root

    def commitment(self) -> RoundCommitment:
        """The sealed session as a RoundCommitment: one (pseudo-)expert,
        one tick per leaf — what lets ``VerifierPool.audit_batched``
        audit a serving session and a training round through one code
        path.  ``claimed`` holds the *current* stream records; the
        sealed ``leaf_digests`` are what they are checked against."""
        t = len(self.leaves)
        claimed = np.array(
            [[[self.request_id, self.ticks[i], self.tokens[i]]
              for i in range(t)]], np.int64)
        return RoundCommitment(
            round_id=self.request_id, executor=-1, root=self.root,
            num_experts=1, chunks_per_expert=t, bounds=list(range(t + 1)),
            leaf_digests=list(self.leaves), claimed=claimed)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 cache_len: int = 256, mesh=None,
                 scheduling: str = "continuous", prefill_chunk: int = 16,
                 trust: Optional[TrustConfig] = None,
                 expert_storage: Optional[EdgeStorageConfig] = None,
                 kv_storage: Optional[KVStorageConfig] = None,
                 obs: Optional[Observability] = None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError("engine drives decoder-only archs")
        self.cfg = cfg
        self.params = params
        self.obs = obs if obs is not None else Observability()
        self.batch = batch_slots
        self.cache_len = cache_len
        self.prefill_chunk = max(1, int(prefill_chunk))
        self.caches = materialize(
            tfm.cache_decl(cfg, batch_slots, cache_len),
            jax.random.PRNGKey(0))
        self.sched = SlotScheduler(batch_slots, policy=scheduling)
        # ---- edge expert storage (MoE models): per-tick resolution of
        # the activated experts through a bounded ExpertCache, fed by
        # the prefill/decode steps' routing counts
        self.edge = None
        if expert_storage is not None:
            has_moe = any(s.mlp == "moe"
                          for s in list(cfg.block_pattern)
                          + list(cfg.remainder))
            if not has_moe:
                raise ValueError("expert_storage needs a MoE model")
            self.edge = _EdgeExpertRuntime(cfg, params, expert_storage,
                                           metrics=self.obs.metrics)
        # ---- KV paging through the chunked store: sealed prefix-CID
        # blocks, warm-prefix restore on admission, page-out/resume.
        # With BOTH runtimes on, KV shares the edge cache+store — the
        # single-byte-budget competition between KV and expert weights.
        self.kvrt = None
        if kv_storage is not None:
            tfm.check_kv_pageable(cfg)
            if cache_len - 1 < kv_storage.block_tokens:
                raise ValueError(
                    f"block_tokens {kv_storage.block_tokens} cannot fit "
                    f"cache_len {cache_len} (need <= cache_len - 1)")
            shared = ((self.edge.store, self.edge.cache)
                      if self.edge is not None else None)
            self.kvrt = _KVRuntime(kv_storage, shared=shared,
                                   metrics=self.obs.metrics)
        # per-slot prefix-chain cursor: {"prev": cid, "sealed": nblocks}
        self._kv_chain: List[Optional[Dict]] = [None] * batch_slots
        # paged-out requests awaiting readmission: rid -> resume state
        self._kv_resume: Dict[int, Dict] = {}
        self._pending_kv_roots: List[str] = []   # sealed, not yet committed
        self._kv_macro_cids: List[str] = []      # sealed this macro-step
        # ONE compiled fused step: C engine ticks per call (C=1 pure
        # decode up to C=prefill_chunk while prompts are chunking), fixed
        # (B, C) shapes per pow2 width bucket (jax.jit's shape cache) —
        # occupancy changes never recompile, and there is no per-token
        # Python dispatch inside a chunk
        self._step_fn = jax.jit(make_serve_chunk_step(
            cfg, mesh, expert_stats=self.edge is not None))
        self.tick = 0
        self.steps = 0                  # fused macro-step invocations
        self._done: Dict[int, List[int]] = {}
        # ---- verified-session state (optimistic trust layer)
        self.trust = trust
        self.records: Dict[int, SessionRecord] = {}
        self.session_log: List[Dict] = []       # commit/finalize/revoke events
        # the on-chain session commitment stream: ONE append per batch
        # tick (a Merkle root over every token emitted that tick)
        self.tick_commitments: List[TickCommitment] = []
        self._window = (ChallengeWindow(trust.challenge_window)
                        if trust is not None else None)
        # audit_rate is the pool-wide sampled fraction (same contract as
        # OptimisticProtocol): each verifier draws its stake-weighted
        # share, and session re-audits catch rubber-stampers too
        self._auditors = (VerifierPool(
            trust.num_verifiers,
            trust.audit_rate / max(trust.num_verifiers, 1),
            trust.lazy_verifier_prob, trust.seed,
            stakes=trust.verifier_stakes, reaudit_rate=trust.reaudit_rate,
            verifier_slash_fraction=trust.verifier_slash_fraction,
            metrics=self.obs.metrics, namespace="serve.verifiers")
            if trust is not None else None)
        self._finalized: set = set()
        # deadline-ordered auto-audit queue: a sealed session's audit is
        # parked off the critical path and drained (whole backlog at
        # once, mirroring OptimisticProtocol.pop_audit_jobs) when the
        # oldest challenge window is about to close — so a tampered
        # stream is caught *before* it can finalize
        self._audit_queue: List[Tuple[int, int]] = []   # (deadline, rid)
        # sessions neither finalized nor revoked: the only ones the
        # finality-deferral and chained-revocation scans must touch —
        # O(open), not O(all sessions ever served)
        self._open_sessions: set = set()

    # ------------------------------------------------------------- views
    @property
    def scheduling(self) -> str:
        return self.sched.policy

    @property
    def slots(self) -> List[SlotState]:
        return self.sched.slots

    @property
    def queue(self):
        return self.sched.queue

    @property
    def request_meta(self) -> Dict[int, Dict[str, int]]:
        """Per-request tick milestones: submitted/admitted/first-token/
        finished — what the serving benchmark derives TTFT and queueing
        delay from."""
        return self.sched.meta

    @property
    def verified(self) -> bool:
        return self.trust is not None

    @property
    def completed(self) -> Dict[int, List[int]]:
        """Finished — and, in verified mode, *finalized* — requests, in
        request-submission order (deterministic output)."""
        if not self.verified:
            return {rid: self._done[rid] for rid in self.sched.submit_order
                    if rid in self._done}
        return {rid: self._done[rid] for rid in self.sched.submit_order
                if rid in self._finalized}

    @property
    def pending_finalization(self) -> List[int]:
        """Finished requests still inside their challenge window."""
        if not self.verified:
            return []
        return [rid for rid in self.sched.submit_order
                if rid in self._done and rid not in self._finalized
                and not self.records[rid].revoked]

    def submit(self, requests: Iterable[dict]):
        self.sched.submit(requests, self.tick)

    def warmup(self) -> int:
        """Compile every fused-step width bucket up front (the pow2s up
        to ``prefill_chunk``; just C=1 under the fixed policy) against
        zero-advance dummy batches — ``adv=0`` masks every cache write,
        so state is untouched — so no compile ever lands in a served
        request's latency.  Returns the number of buckets compiled."""
        w, n = 1, 0
        while True:
            batch = {"tokens": jnp.zeros((self.batch, w), jnp.int32),
                     "start": jnp.zeros(self.batch, jnp.int32),
                     "pos": jnp.zeros(self.batch, jnp.int32),
                     "lengths": jnp.zeros(self.batch, jnp.int32),
                     "adv": jnp.zeros(self.batch, jnp.int32)}
            out = self._step_fn(self.params, self.caches, batch)
            jax.block_until_ready(out[0])
            n += 1
            if self.sched.policy != "continuous" \
                    or w * 2 > self.prefill_chunk:
                return n
            w *= 2

    # ------------------------------------------------------- slot intake
    def _admit(self) -> None:
        admitted = self.sched.admit(self.tick)
        if not admitted:
            return
        self._reset_slot_caches([i for i, _ in admitted])
        if self.kvrt is not None:
            for i, slot in admitted:
                self._kv_on_admit(i, slot)
        if self.verified:
            for _, slot in admitted:
                rid = slot.request_id
                # a paged-out-then-readmitted session keeps its record:
                # its commitment stream continues where it left off
                if rid not in self.records:
                    self.records[rid] = SessionRecord(request_id=rid)
                self._open_sessions.add(rid)

    def _reset_slot_caches(self, idxs: List[int]) -> None:
        """Zero the admitted slots' cache rows (KV + recurrent state) —
        the continuous-batching replacement for the fixed-slot engine's
        whole-cache reset at batch refill."""
        sel = np.zeros(self.batch, bool)
        sel[idxs] = True
        sel = jnp.asarray(sel)

        def zero_rows(axis):
            def f(a):
                m = sel.reshape((1,) * axis + (-1,)
                                + (1,) * (a.ndim - axis - 1))
                return jnp.where(m, jnp.zeros((), a.dtype), a)
            return f

        # stacked block caches carry a leading layer axis: batch is axis 1
        new = {"blocks": jax.tree_util.tree_map(zero_rows(1),
                                                self.caches["blocks"])}
        if "remainder" in self.caches:
            new["remainder"] = jax.tree_util.tree_map(
                zero_rows(0), self.caches["remainder"])
        self.caches = new

    # ------------------------------------------------------- KV paging
    def _kv_template(self):
        """Structure-only template for ``assemble_tree`` (leaf shapes
        come from the manifest, only the treedef must match)."""
        if self.kvrt.like is None:
            self.kvrt.like = tfm.slice_kv_block(self.caches, 0, 0, 1)
        return self.kvrt.like

    @staticmethod
    def _fed_tokens(s: SlotState, a: int, b: int) -> np.ndarray:
        """Token ids FED at cache positions [a, b): the prompt up to its
        length, then the generated continuation (cache row p holds the
        KV of the token fed at position p — a pure function of the
        token prefix, which is what makes prefix-CID addressing
        sound)."""
        L = len(s.prompt)
        out = np.empty(b - a, np.int64)
        for j, p in enumerate(range(a, b)):
            out[j] = int(s.prompt[p]) if p < L else s.generated[p - L]
        return out

    def _kv_on_admit(self, index: int, slot: SlotState) -> None:
        """Admission-side restore: a readmitted paged-out request gets
        its exact sealed state back; a fresh request whose leading
        prompt blocks are already sealed (another session shared the
        prefix) restores them instead of recomputing prefill.  At least
        one prompt token is always left unconsumed — the first
        generated token comes from feeding the LAST prompt token."""
        kv, T = self.kvrt.kv, self.kvrt.T
        rid = slot.request_id
        res = self._kv_resume.pop(rid, None)
        if res is not None:
            for cid, a, b in res["cids"]:
                block = kv.fetch(cid, self._kv_template())
                self.caches = tfm.restore_kv_block(self.caches, index,
                                                   a, block)
            slot.pos, slot.cursor = res["pos"], res["cursor"]
            slot.generated = list(res["generated"])
            self._kv_chain[index] = {"prev": res["prev"],
                                     "sealed": res["sealed"]}
            kv.stats["resumes"] += 1
            kv.stats["restored_tokens"] += slot.pos
            return
        chain = prefix_chain(slot.prompt, T)
        # restorable blocks must end strictly inside the prompt
        restorable = chain[:max(0, (len(slot.prompt) - 1) // T)]
        n = kv.warm_prefix(restorable) if restorable else 0
        for b in range(n):
            block = kv.fetch(chain[b], self._kv_template())
            self.caches = tfm.restore_kv_block(self.caches, index,
                                               b * T, block)
        slot.pos = slot.cursor = n * T
        self._kv_chain[index] = {"prev": chain[n - 1] if n else KV_GENESIS,
                                 "sealed": n}
        if n:
            kv.stats["restored_tokens"] += n * T

    def _kv_seal_upto(self, index: int, s: SlotState) -> None:
        """Seal every full block the slot's fed sequence has crossed.
        The compiled chunk already wrote these rows (cache rows are
        write-once), so slicing the post-chunk cache at any replay tick
        past the block boundary reads exactly what that tick held.  A
        CID another session already sealed dedups without slicing."""
        st, kv, T = self._kv_chain[index], self.kvrt.kv, self.kvrt.T
        while (st["sealed"] + 1) * T <= s.pos:
            b = st["sealed"]
            cid = prefix_cid(st["prev"],
                             self._fed_tokens(s, b * T, (b + 1) * T))
            if cid in kv:
                man = kv.seal(cid, None, 0)
            else:
                block = tfm.slice_kv_block(self.caches, index,
                                           b * T, (b + 1) * T)
                man = kv.seal(cid, block, T)
            st["prev"], st["sealed"] = cid, b + 1
            if self.verified:
                self._pending_kv_roots.append(man.root)
            self._kv_macro_cids.append(cid)

    def _kv_prefetch_queued(self) -> None:
        """Warm the cache with queued requests' sealed prefix blocks —
        issued right after the fused chunk dispatch, so the fetch
        overlaps co-batched decode the way ``GateEMA`` prefetch
        overlaps expert fetch.  Prefetch never evicts residents."""
        kv, T = self.kvrt.kv, self.kvrt.T
        for r in list(self.sched.queue)[:self.batch]:
            if r["id"] in self._kv_resume:
                continue                 # resume fetches exact blocks
            chain = prefix_chain(r["prompt"], T)
            run = []
            for cid in chain[:max(0, (len(r["prompt"]) - 1) // T)]:
                if cid not in kv:
                    break
                run.append(KVBlockStore.object_id(cid))
            if run:
                self.kvrt.cache.prefetch(run, 0,
                                         lambda oid: self._kv_template())

    def page_out(self, index: int) -> int:
        """Page a running slot's KV out of the compute cache: seal its
        full blocks plus the partial tail block to the chunked store,
        stash the resume cursor, and requeue the request at the queue
        FRONT.  Readmission (``_kv_on_admit``) restores the rows and
        the slot resumes decode bit-identically.  Returns the request
        id."""
        if self.kvrt is None:
            raise ValueError("engine was not started with kv_storage")
        s = self.sched.slots[index]
        if not s.active:
            raise ValueError(f"slot {index} is not active")
        kv, T = self.kvrt.kv, self.kvrt.T
        self._kv_seal_upto(index, s)     # normally already sealed
        st = self._kv_chain[index]
        nfull, prev = st["sealed"], st["prev"]
        entries = []
        chain_prev = KV_GENESIS
        for b in range(nfull):
            chain_prev = prefix_cid(chain_prev,
                                    self._fed_tokens(s, b * T, (b + 1) * T))
            entries.append((chain_prev, b * T, (b + 1) * T))
        if s.pos > nfull * T:
            # tail block: chained over its (shorter) token run — the
            # int64 encoding binds the count, so it can never collide
            # with the full block over the same prefix
            tail_cid = prefix_cid(prev,
                                  self._fed_tokens(s, nfull * T, s.pos))
            block = tfm.slice_kv_block(self.caches, index, nfull * T, s.pos)
            man = kv.seal(tail_cid, block, s.pos - nfull * T)
            if self.verified:
                self._pending_kv_roots.append(man.root)
            entries.append((tail_cid, nfull * T, s.pos))
        self._kv_resume[s.request_id] = {
            "pos": s.pos, "cursor": s.cursor,
            "generated": list(s.generated),
            "cids": entries, "prev": prev, "sealed": nfull}
        kv.stats["pageouts"] += 1
        rid = self.sched.preempt(index, self.tick)
        self._kv_chain[index] = None
        return rid

    # --------------------------------------------------------- emissions
    def _emit(self, slot: SlotState, token: int, lat_s: float) -> None:
        slot.generated.append(token)
        if len(slot.generated) == 1:
            slot.first_token_tick = self.tick
            self.sched.meta[slot.request_id]["first_token_tick"] = self.tick
        m = self.obs.metrics
        m.counter("serve.tokens").add(1)
        m.histogram("serve.token_latency_s").observe(lat_s)
        m.histogram("serve.token_latency_s",
                    session=slot.request_id).observe(lat_s)
        if self.verified:
            self.records[slot.request_id].append(self.tick, token)

    def _finish(self, index: int) -> None:
        slot = self.sched.slots[index]
        generated = slot.generated[:slot.to_generate]
        rid = self.sched.release(index, self.tick)
        self._done[rid] = generated
        if not self.verified:
            return
        rec = self.records[rid]
        root = rec.seal() if rec.leaves else ""
        self.session_log.append({"event": "commit", "request": rid,
                                 "root": root[:16], "tick": self.tick,
                                 "leaves": len(rec.leaves)})
        self._window.enter(rid, self.tick)
        if rec.leaves:
            heapq.heappush(self._audit_queue,
                           (self.tick + self.trust.challenge_window, rid))

    # ----------------------------------------------------- the macro-step
    def step(self):
        """One fused macro-step: admit from the queue, then run C engine
        ticks in ONE compiled call — prefilling slots chunk-consume
        their prompts while decoding slots keep generating (C=1 when no
        prompt is in flight, up to ``prefill_chunk`` while one is).
        Per engine tick, host-side: emit, batch-commit the tick's
        Merkle leaf set, evict finished slots.  In verified mode, ticks
        keep running after the queue drains until every challenge
        window has closed."""
        with self.obs.span("step", metric="serve.tick_s", tick=self.tick):
            return self._step_inner()

    def _step_inner(self):
        with self.obs.span("admit", metric="serve.admit_s",
                           tick=self.tick):
            self._admit()
        if not self.sched.any_active:
            if self.verified and len(self._window):
                self.tick += 1               # idle tick: windows still age
                self._expire_windows()
                return bool(len(self._window))
            return False
        self.steps += 1
        m = self.obs.metrics
        m.histogram("serve.occupancy").observe(self.sched.occupancy())
        m.gauge("serve.queue_depth").set(self.sched.depth())
        slots = self.sched.slots
        continuous = self.sched.policy == "continuous"

        # ---- chunk width C (continuous): the largest pow2 <= the
        # busiest active slot's remaining work (prompt left + tokens
        # left to generate, cache-bounded) — so no tick in the chunk is
        # pure waste past everyone's completion — capped by
        # prefill_chunk and every active slot's cache headroom.  The
        # pow2 rounding bounds the compile set to log2(prefill_chunk)+1
        # shape buckets.  The fixed baseline always runs C=1 with a
        # 1-token prompt feed — the legacy batch-synchronous engine,
        # bit for bit.
        if continuous:
            need = self.sched.prefill_lengths(self.prefill_chunk,
                                              self.cache_len)
            work = max((len(s.prompt) - s.cursor)
                       + max(s.to_generate - len(s.generated), 0)
                       for s in slots if s.active)
            headroom = min(self.cache_len - 1 - s.pos
                           for s in slots if s.active)
            cmax = max(1, min(self.prefill_chunk, headroom, work))
            C = 1 << (cmax.bit_length() - 1)      # round DOWN to pow2
            need = np.minimum(need, C).astype(np.int32)
        else:
            C = 1
            need = np.array([1 if s.prefilling else 0 for s in slots],
                            np.int32)

        tokens = np.zeros((self.batch, C), np.int32)
        start = np.zeros(self.batch, np.int32)
        pos = np.zeros(self.batch, np.int32)
        adv = np.zeros(self.batch, np.int32)
        for i, s in enumerate(slots):
            if not s.active:
                continue
            n = int(need[i])
            pos[i] = s.pos
            if n:
                tokens[i, :n] = s.prompt[s.cursor:s.cursor + n]
            if s.generated:
                start[i] = s.generated[-1]
            # a slot that finishes its prompt inside the chunk (or is
            # already decoding) generates for the rest of the scan; a
            # chunk/headroom-capped prefill slot stops at its cap
            adv[i] = C if s.cursor + n >= len(s.prompt) else n
        batch = {"tokens": jnp.asarray(tokens), "start": jnp.asarray(start),
                 "pos": jnp.asarray(pos), "lengths": jnp.asarray(need),
                 "adv": jnp.asarray(adv)}
        prefill_now = continuous and bool((need > 0).any())
        name, metric = (("prefill", "serve.prefill_s") if prefill_now
                        else ("decode", "serve.decode_s"))
        with self.obs.span(name, metric=metric, tick=self.tick,
                           width=C) as sp:
            out = self._step_fn(self.params, self.caches, batch)
            if self.edge is not None:
                outs, self.caches, stats = out
            else:
                (outs, self.caches), stats = out, None
            outs = np.asarray(outs)          # (C, B) greedy next tokens
        if self.edge is not None and stats is not None:
            # resolve the chunk's activated experts through the edge
            # cache (cold: chunk fetches; warm: hits) + EMA prefetch
            self.edge.on_tick(np.asarray(stats))
        if self.kvrt is not None:
            # overlap with the chunk just dispatched: warm queued
            # requests' sealed prefix blocks into the cache
            self._kv_macro_cids = []
            self._kv_prefetch_queued()
        lat = sp.dur_s / C

        # ---- replay the chunk host-side, one engine tick per micro-step
        for t in range(C):
            self.tick += 1
            emissions: List[Tuple[int, int, int]] = []  # (slot, rid, tok)
            for i, s in enumerate(slots):
                if not s.active:             # idle, or finished mid-chunk
                    continue
                n = int(need[i])
                if t < n:                    # consumed a prompt token
                    s.cursor += 1
                    s.pos += 1
                    if s.cursor == len(s.prompt):
                        tok = int(outs[t, i])   # first generated token
                        self._emit(s, tok, lat)
                        emissions.append((i, s.request_id, tok))
                elif int(adv[i]) == C and s.cursor >= len(s.prompt):
                    tok = int(outs[t, i])    # autoregressive continuation
                    self._emit(s, tok, lat)
                    emissions.append((i, s.request_id, tok))
                    s.pos += 1
            if self.kvrt is not None:
                # seal the blocks this tick completed (prefill AND
                # decode rows page through the same chain), BEFORE the
                # commit so their manifest roots ride this tick's
                # on-chain append
                for i, s in enumerate(slots):
                    if s.active:
                        self._kv_seal_upto(i, s)
            if self.verified and emissions:
                self._commit_tick(emissions)
            for i, s in enumerate(slots):
                if not s.active:
                    continue
                done = (not s.prefilling
                        and len(s.generated) >= s.to_generate)
                if done or s.pos >= self.cache_len - 1:
                    self._finish(i)
            if self.verified:
                self._expire_windows()
        if self.kvrt is not None and self.kvrt.da is not None \
                and self._kv_macro_cids:
            # DA challenges over the KV chunks sealed this macro-step:
            # replica nodes answer for sealed KV exactly like expert
            # chunks (corrupt -> slash + repair; withheld -> window)
            seen = sorted(set(self._kv_macro_cids))
            self.kvrt.da.challenge_round(self.tick,
                                         self.kvrt.kv.manifests(seen))
            self.kvrt.da.resolve(self.tick)
        return True

    def _commit_tick(self, emissions: List[Tuple[int, int, int]]) -> None:
        """One Merkle append for the whole batch tick: a tree over every
        token emitted this tick (slot order); each session stores its
        inclusion path into it.  KV-block manifest roots sealed since
        the last append ride along as the side-band ``kv_root`` (a
        prefill tick can seal without emitting, so pending roots carry
        forward); the token ``root`` is untouched — streams and
        verdicts stay bit-identical to paging-off."""
        with self.obs.span("commit", metric="serve.commit_s",
                           tick=self.tick, leaves=len(emissions)):
            entries = [(rid, self.records[rid].leaves[-1])
                       for _, rid, _ in emissions]
            tc, refs = commit_tick(self.tick, entries,
                                   kv_roots=self._pending_kv_roots)
            self._pending_kv_roots = []
            self.tick_commitments.append(tc)
            for rid, ref in refs.items():
                self.records[rid].refs.append(ref)
            m = self.obs.metrics
            m.counter("serve.commit.appends").add(1)
            m.counter("serve.commit.leaves").add(len(entries))

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        ticks = 0
        while self.step() and ticks < max_ticks:
            ticks += 1
        return self.completed

    # ------------------------------------------------------- observability
    def obs_report(self) -> Dict:
        """Serving-side view over the metrics registry: tick/token
        throughput, wall-clock totals per phase, token-latency
        percentiles (aggregate and per session), slot occupancy, the
        batched-commitment append counters, plus the edge storage
        section when edge expert storage is on."""
        m = self.obs.metrics
        out = {
            "ticks": self.tick,
            "tokens": int(m.value("serve.tokens")),
            "tick_s": float(m.value("serve.tick_s")),
            "admit_s": float(m.value("serve.admit_s")),
            "prefill_s": float(m.value("serve.prefill_s")),
            "decode_s": float(m.value("serve.decode_s")),
            "commit_s": float(m.value("serve.commit_s")),
            "audit_offpath_s": float(m.value("serve.audit_s")),
            "token_latency": m.histogram("serve.token_latency_s").snapshot(),
            "occupancy": m.histogram("serve.occupancy").snapshot(),
            "commit_appends": int(m.value("serve.commit.appends")),
            "commit_leaves": int(m.value("serve.commit.leaves")),
            "sessions": {
                name.split("session=", 1)[1].rstrip("}"): snap
                for name, snap in
                m.snapshot("serve.token_latency_s{").items()},
        }
        if self.edge is not None:
            out["edge"] = self.edge.report()
        if self.kvrt is not None:
            out["kv"] = self.kvrt.report()
        return out

    def report(self) -> Dict:
        return self.obs_report()

    # ------------------------------------------------ audits (verified)
    def _audit_full(self, rid: int) -> None:
        """One spot-check pass per verifier (stopping early once a fraud
        revokes the session)."""
        for v in range(self._auditors.num_verifiers):
            self.audit_session(rid, v)
            if self.records[rid].revoked:
                break

    def _drain_session_audits(self) -> None:
        """Run queued session audits once the oldest deadline is due —
        and then the whole backlog, so audits burst off the critical
        path instead of blocking every tick."""
        if not self._audit_queue or self._audit_queue[0][0] > self.tick:
            return
        # burst drains off the critical path: booked to serve.audit_s and
        # excluded from the enclosing tick span's serve.tick_s
        drained = [rid for _, rid in self._audit_queue]
        with self.obs.span("audit-drain", metric="serve.audit_s",
                           off_path=True, tick=self.tick, drained=drained):
            while self._audit_queue:
                _, rid = heapq.heappop(self._audit_queue)
                rec = self.records[rid]
                if rec.revoked or not rec.root:
                    continue
                self._audit_full(rid)

    @staticmethod
    def _overlaps(a: SessionRecord, b: SessionRecord) -> bool:
        return (bool(a.ticks) and bool(b.ticks)
                and b.ticks[0] <= a.ticks[-1] and a.ticks[0] <= b.ticks[-1])

    def _expire_windows(self) -> None:
        self._drain_session_audits()
        for rid in self._window.expire(self.tick):
            rec = self.records[rid]
            if rec.revoked:
                continue
            # serving-side sequential finality: a stream cannot finalize
            # while a tick-overlapping co-batched stream is still being
            # produced (its later-confirmed fraud would void this one) or
            # is sealed but unchecked — spot-check the neighbour first,
            # which revokes this stream too if the neighbour was altered
            deferred = False
            for rid2 in list(self._open_sessions):
                dep = self.records[rid2]
                if rid2 == rid or dep.revoked \
                        or not self._overlaps(rec, dep):
                    continue
                if not dep.root:
                    if rid2 not in self._done:   # neighbour still streaming
                        self._window.hold(rid, self.tick + 1)
                        deferred = True
                        break
                    continue                     # empty session: no leaves
                if not dep.audited:
                    self._audit_full(rid2)
            if deferred or rec.revoked:
                continue
            rec.finalized = True
            self._finalized.add(rid)
            self._open_sessions.discard(rid)
            self.session_log.append({"event": "finalize", "request": rid,
                                     "tick": self.tick})

    def audit_session(self, request_id: int, verifier: int = 0) -> Dict:
        """Spot-check sampled leaves of a session commitment through the
        same batched auditor as training rounds: the sampled (tick,
        token) records are re-digested in one ``leaf_digest_batch`` pass
        and compared against the sealed leaves, then proven against both
        the sealed per-session root AND the batch tick roots the tokens
        were served under.  A mismatch (the served stream was altered
        after commitment) revokes the request: it will never finalize."""
        if not self.verified:
            raise ValueError("engine was not started with a TrustConfig")
        rec = self.records[request_id]
        if not rec.root:
            raise ValueError(f"request {request_id} not sealed yet")
        com = rec.commitment()

        def batch_recompute(experts, slices):
            # honest recompute of a session leaf = re-encoding the served
            # (tick, token) record; leaf i covers batch row i
            rows = [[request_id, rec.ticks[sl.start], rec.tokens[sl.start]]
                    for sl in slices]
            return np.asarray(rows, np.int64)[:, None, :]

        [report] = self._auditors.audit_batched(com, batch_recompute,
                                                verifiers=[verifier])

        def recompute(e: int, sl: slice):
            return np.array([[request_id, rec.ticks[sl.start],
                              rec.tokens[sl.start]]], np.int64)

        # second-layer lottery (reaudit_rate > 0): spot-check this
        # verifier's salted recompute attestations — a rubber-stamping
        # session auditor is slashed out of future lotteries just like a
        # training-round one
        self._auditors.reaudit(com, [report], recompute)
        sampled = report.sampled_leaves
        mismatches = [p.leaf_index for p in report.fraud_proofs]
        # Merkle-path check against the SEALED root: catches a consistent
        # post-seal rewrite of both the record and its leaf digest, which
        # the digest comparison alone (recompute vs current leaf list)
        # cannot see
        tree = MerkleTree(rec.leaves)
        if tree.root != rec.root:
            mismatches = sorted(set(mismatches) | {
                leaf for leaf in sampled
                if not MerkleTree.verify(rec.root, rec.leaves[leaf],
                                         tree.prove(leaf))})
        # inclusion check against the batch tick trees: every sampled
        # leaf must still be the one committed (one append per tick for
        # the whole batch) when its token was served
        if rec.refs and len(rec.refs) == len(rec.leaves):
            bad = verify_session_inclusion(rec.leaves, rec.refs, sampled)
            mismatches = sorted(set(mismatches) | set(bad))
        rec.audited = True
        if mismatches:
            self._revoke_session(request_id, mismatches)
        return {"request": request_id, "sampled": sampled,
                "mismatches": mismatches, "revoked": rec.revoked}

    def _revoke_session(self, request_id: int, mismatches: List[int]) -> None:
        """Revoke a session, then chain the revocation: every session
        whose ticks overlap the revoked stream's and whose window is
        still open is revoked with it — those tokens came out of the
        same batched decode calls as the fraudulent ones, so their
        provenance is void (the per-tick analogue of the training
        pipeline's INVALIDATED descendants; no separate fraud is booked
        for them).  Already-finalized sessions are immune: their windows
        closed clean before the fraud was confirmed."""
        rec = self.records[request_id]
        rec.revoked = True
        rec.finalized = False            # a revoked record is never final
        self._finalized.discard(request_id)
        self._open_sessions.discard(request_id)
        self._window.revoke(request_id)
        self.session_log.append({"event": "revoke", "request": request_id,
                                 "leaves": mismatches})
        for rid in list(self._open_sessions):
            dep = self.records[rid]
            if dep.revoked or dep.finalized or not self._overlaps(rec, dep):
                continue
            dep.revoked = True
            self._finalized.discard(rid)
            self._open_sessions.discard(rid)
            self._window.revoke(rid)
            self.session_log.append({"event": "revoke_dependent",
                                     "request": rid,
                                     "cause": request_id})

    def audit_all(self) -> List[Dict]:
        return [self.audit_session(rid, v)
                for rid in list(self.records)
                if self.records[rid].root
                for v in range(self._auditors.num_verifiers)]
