"""Batched serving engine: continuous-batching-style slot manager over the
single-token ``decode_step`` with a fixed-capacity KV cache.

Requests (prompt + max_new_tokens) are packed into batch slots; prompts
are prefilled token-by-token through the decode path (CPU-scale; on TPU
the prefill_step handles whole prompts), generation is greedy, and
finished slots are refilled from the queue — the serving analogue of the
paper's edge-layer inference (Steps 1-3, no updates).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.builder import materialize
from repro.models.config import ModelConfig
from repro.train.step import make_decode_step


@dataclasses.dataclass
class SlotState:
    request_id: int = -1
    pos: int = 0
    remaining_prompt: List[int] = dataclasses.field(default_factory=list)
    to_generate: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.request_id >= 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 cache_len: int = 256, mesh=None):
        if cfg.is_encoder_decoder:
            raise NotImplementedError("engine drives decoder-only archs")
        self.cfg = cfg
        self.params = params
        self.batch = batch_slots
        self.cache_len = cache_len
        self.caches = materialize(
            tfm.cache_decl(cfg, batch_slots, cache_len),
            jax.random.PRNGKey(0))
        self._decode = jax.jit(make_decode_step(cfg, mesh))
        self.slots = [SlotState() for _ in range(batch_slots)]
        self.queue: deque = deque()
        self.completed: Dict[int, List[int]] = {}

    def submit(self, requests: Iterable[dict]):
        for r in requests:
            self.queue.append(r)

    def _fill_slots(self):
        # batch-synchronous refill: new requests enter only when the whole
        # batch drained, so every slot shares one decode position and no
        # slot attends a predecessor's stale cache rows
        if any(s.active for s in self.slots):
            return
        if not self.queue:
            return
        self.caches = jax.tree_util.tree_map(jnp.zeros_like, self.caches)
        for slot in self.slots:
            if self.queue:
                r = self.queue.popleft()
                slot.request_id = r["id"]
                slot.pos = 0
                slot.remaining_prompt = list(np.asarray(r["prompt"]))
                slot.to_generate = int(r["max_new_tokens"])
                slot.generated = []

    def step(self):
        """One engine tick: each active slot consumes one prompt token or
        generates one token.  (All slots share one decode position per
        tick; a per-slot position mask keeps semantics correct.)"""
        self._fill_slots()
        if not any(s.active for s in self.slots):
            return False
        tokens = np.zeros((self.batch, 1), np.int32)
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            if s.remaining_prompt:
                tokens[i, 0] = s.remaining_prompt[0]
            elif s.generated:
                tokens[i, 0] = s.generated[-1]
        pos = max((s.pos for s in self.slots if s.active), default=0)
        nxt, self.caches = self._decode(
            self.params, self.caches,
            {"tokens": jnp.asarray(tokens), "pos": jnp.int32(pos)})
        nxt = np.asarray(nxt)
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            if s.remaining_prompt:
                s.remaining_prompt.pop(0)
                if not s.remaining_prompt:
                    s.generated.append(int(nxt[i]))  # first generated token
            else:
                s.generated.append(int(nxt[i]))
            s.pos += 1
            done = (not s.remaining_prompt
                    and len(s.generated) >= s.to_generate)
            if done or s.pos >= self.cache_len - 1:
                self.completed[s.request_id] = s.generated[:s.to_generate]
                s.request_id = -1
        return True

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        ticks = 0
        while self.step() and ticks < max_ticks:
            ticks += 1
        return self.completed
