"""repro.serve — continuous-batching serving with prefill/decode
disaggregation and optimistic per-session trust.  See README.md in this
directory for the scheduler lifecycle and the batched per-tick Merkle
commitment scheme."""
from repro.serve.engine import (EdgeStorageConfig, ServingEngine,
                                SessionRecord)
from repro.serve.scheduler import POLICIES, SlotScheduler, SlotState

__all__ = ["EdgeStorageConfig", "POLICIES", "ServingEngine",
           "SessionRecord", "SlotScheduler", "SlotState"]
