"""Structured span tracer for the B-MoE stack.

The paper's blockchain layer exists to "trace, verify, and record" the
experts' computation; this module is the *trace* third: nested wall-
clock spans with per-span attributes (round id, expert id, session id,
block hash, CID), exported as Chrome-trace/Perfetto JSON or a JSONL
event log, and feeding the same ``MetricsRegistry`` the legacy reports
read — a span is both a trace event and (optionally) a phase-seconds
metric.

Three execution modes, chosen per span:

- **no-op** — tracer disabled and the span carries no metric: a shared
  singleton context manager is returned; nothing is timed, nothing is
  allocated (the zero-overhead mode, bounded in tests/test_obs.py);
- **metric-only** — tracer disabled but the span feeds a phase counter
  (``metric="bmoe.consensus_s"``): the span is timed and participates
  in off-path accounting but records no trace event — this is the
  always-on replacement for the old ad-hoc ``_timers`` arithmetic and
  costs what the ``time.perf_counter()`` pairs it replaced cost;
- **recording** — tracer enabled: the span is timed, stacked, and
  appended to the event log with its attributes for export.

Off-path accounting replaces the manual audit-seconds subtraction the
pre-obs ``BMoESystem`` did by hand: a span opened with
``off_path=True`` (e.g. a pipelined audit drain — verifier-pool work
that deployment overlaps with later rounds) reports its full duration
to its own metric, while every enclosing span's metric records
*on-path* time — duration minus off-path descendants — natively.  The
invariant ``parent.metric + off_path_child.metric == parent wall`` is
pinned in tests/test_obs.py.

This module (plus ``benchmarks/common.py``) is the only place in the
repo allowed to call ``time.perf_counter`` — CI lint enforces it, so
every measurement flows through one substrate.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from repro.obs.metrics import MetricsRegistry

_pc = time.perf_counter


class _NoopSpan:
    """Shared do-nothing span (disabled tracer, no metric)."""
    __slots__ = ()
    span_id = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One timed region.  Use via ``with tracer.span(...) as sp:``."""
    __slots__ = ("tracer", "name", "metric", "off_path", "attrs", "span_id",
                 "parent_id", "t0", "dur_s", "off_child_s", "_record")

    def __init__(self, tracer: "Tracer", name: str, metric: Optional[str],
                 off_path: bool, record: bool, attrs: Dict):
        self.tracer = tracer
        self.name = name
        self.metric = metric
        self.off_path = off_path
        self.attrs = attrs
        self._record = record
        self.span_id = 0
        self.parent_id = 0
        self.t0 = 0.0
        self.dur_s = 0.0
        self.off_child_s = 0.0

    def set(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (block hash, verdicts)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self.tracer
        self.span_id = tr._next_id
        tr._next_id += 1
        stack = tr._stack
        self.parent_id = stack[-1].span_id if stack else 0
        stack.append(self)
        self.t0 = _pc()
        return self

    def __exit__(self, *exc) -> bool:
        end = _pc()
        tr = self.tracer
        self.dur_s = end - self.t0
        stack = tr._stack
        if stack and stack[-1] is self:
            stack.pop()
        parent = stack[-1] if stack else None
        # off-path propagation: an off-path span's WHOLE duration is
        # off its ancestors' path; an on-path span passes through only
        # what its own off-path descendants accumulated
        if parent is not None:
            parent.off_child_s += (self.dur_s if self.off_path
                                   else self.off_child_s)
        if self.metric is not None:
            # an on-path phase metric counts self time minus off-path
            # descendants; an off-path metric counts its full duration
            on_path = self.dur_s - (0.0 if self.off_path
                                    else self.off_child_s)
            tr.metrics.counter(self.metric).add(on_path)
        if self._record:
            tr._events.append({
                "name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "trace_id": tr.trace_id,
                "ts_s": self.t0 - tr._origin, "dur_s": self.dur_s,
                "off_path": self.off_path, "metric": self.metric,
                "attrs": self.attrs,
            })
        return False


class Tracer:
    """Span factory + event log.  ``enabled=False`` records nothing but
    still drives metric-bearing spans (the phase timers)."""

    _next_trace_id = 1

    def __init__(self, enabled: bool = False,
                 metrics: Optional[MetricsRegistry] = None):
        self.enabled = bool(enabled)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace_id = Tracer._next_trace_id
        Tracer._next_trace_id += 1
        self._origin = _pc()
        self._events: List[Dict] = []
        self._stack: List[Span] = []
        self._next_id = 1

    # ------------------------------------------------------------- spans
    def span(self, name: str, *, metric: Optional[str] = None,
             off_path: bool = False, **attrs):
        """Open a span.  ``metric``: phase counter fed on exit (seconds,
        off-path descendants excluded).  ``off_path=True``: this work is
        concurrent with the critical path in deployment — its seconds are
        excluded from every enclosing span's metric."""
        if not self.enabled and metric is None and not off_path:
            return NOOP_SPAN
        return Span(self, name, metric, off_path, self.enabled, attrs)

    def current_span_id(self) -> int:
        """Innermost open span id (0 outside any span) — what hosts bind
        into artifacts (ledger blocks) for block -> trace correlation."""
        return self._stack[-1].span_id if self._stack else 0

    # ----------------------------------------------------------- exports
    @property
    def events(self) -> List[Dict]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()

    def chrome_trace(self) -> Dict:
        """Chrome-trace (Perfetto-loadable) JSON object: one complete
        ("ph": "X") event per span, microsecond timestamps, span/parent
        ids and attributes under ``args``."""
        events = []
        for ev in self._events:
            args = {"span_id": ev["span_id"], "parent_id": ev["parent_id"],
                    "off_path": ev["off_path"]}
            if ev["metric"]:
                args["metric"] = ev["metric"]
            args.update(ev["attrs"])
            events.append({
                "name": ev["name"], "cat": "repro",
                "ph": "X", "ts": ev["ts_s"] * 1e6,
                "dur": ev["dur_s"] * 1e6,
                "pid": 1, "tid": ev["trace_id"],
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> Dict:
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return trace

    def export_jsonl(self, path: str) -> int:
        """One JSON object per completed span, append-order."""
        with open(path, "w") as f:
            for ev in self._events:
                f.write(json.dumps(ev) + "\n")
        return len(self._events)


# --------------------------------------------------- kernel annotations
# jax.profiler.TraceAnnotation hooks around the grouped-GEMM hot paths:
# when a jax profile is being captured, the annotation names the kernel
# region on the device timeline.  Off by default (REPRO_OBS_ANNOTATE=1
# or set_annotations(True) enables) so the hot path pays nothing.
_annotate_enabled = os.environ.get("REPRO_OBS_ANNOTATE", "") not in ("", "0")


def set_annotations(enabled: bool) -> None:
    global _annotate_enabled
    _annotate_enabled = bool(enabled)


def annotations_enabled() -> bool:
    return _annotate_enabled


def annotate(name: str):
    """Context manager naming a device-side region on the jax profiler
    timeline (no-op unless annotations are enabled and jax exposes
    ``profiler.TraceAnnotation``)."""
    if not _annotate_enabled:
        return NOOP_SPAN
    try:
        from jax.profiler import TraceAnnotation
    except Exception:                                 # pragma: no cover
        return NOOP_SPAN
    return TraceAnnotation(name)


class Observability:
    """The per-system bundle: one tracer + one metrics registry.

    ``Observability()`` (default) keeps tracing off — spans that carry
    phase metrics still time themselves (the legacy reports depend on
    them); everything else is a shared no-op.  ``enabled=True`` records
    every span for export.
    """

    def __init__(self, enabled: bool = False,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = Tracer(enabled=enabled, metrics=self.metrics)

    @property
    def enabled(self) -> bool:
        return self.trace.enabled

    def span(self, name: str, **kw):
        return self.trace.span(name, **kw)

    def report(self) -> Dict:
        return {"metrics": self.metrics.snapshot()}
