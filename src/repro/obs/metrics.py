"""Metrics registry: counters, gauges, and fixed-bucket histograms.

One ``MetricsRegistry`` is the single numeric ledger of a system run —
every layer (edge compute, blockchain trust, storage, serving) records
into the same registry, so ``obs_report()`` surfaces one merged view
instead of N incompatible per-subsystem dicts.

Conventions:

- metric *names* are dot-namespaced by layer (``bmoe.compute_s``,
  ``storage.cache.hits``, ``trust.train.finalized``,
  ``serve.token_latency_s``); labels, when needed, are canonicalized
  into the name as ``name{k=v}``;
- wall-clock metrics end in ``_s`` (host seconds); *modeled* seconds —
  deterministic cost-model output — end in ``modeled_*_s`` and are
  exactly reproducible across runs, like every byte/count metric;
- histograms hold fixed, ascending bucket upper bounds (p50/p99 are
  first-class: ``percentile`` interpolates inside the owning bucket and
  clamps to the observed min/max, so the error is bounded by the bucket
  width).

``CounterGroup`` is the bridge from the pre-obs world: subsystems that
kept a plain ``stats`` dict (``StorageNetwork``, ``ExpertCache``,
``OptimisticProtocol``, ...) keep the exact same dict interface and
keys, but when constructed with a registry every entry is a live,
namespaced registry counter — the legacy report surface becomes a thin
view over the metrics layer instead of a parallel bookkeeping path.
"""
from __future__ import annotations

import bisect
import math
from collections.abc import MutableMapping
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Union

Number = Union[int, float]


def canonical_name(name: str, **labels) -> str:
    """``name{k=v,...}`` with labels sorted by key (stable identity)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def exp_buckets(start: float = 1e-6, factor: float = 2.0,
                count: int = 26) -> tuple:
    """Exponential bucket upper bounds: ``start * factor**i``."""
    return tuple(start * factor ** i for i in range(count))


# 1us .. ~33s in powers of two: wide enough for a per-chunk hash and a
# whole benchmark run to land in an interior bucket
DEFAULT_TIME_BUCKETS = exp_buckets(1e-6, 2.0, 26)


class Counter:
    """Monotonic accumulator.  Integer adds keep integer exactness
    (byte/count metrics compare ``==`` across identical runs)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def add(self, v: Number = 1) -> None:
        self.value += v

    def snapshot(self) -> Number:
        return self.value


class Gauge:
    """Last-write-wins value."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        self.value = v

    def snapshot(self) -> Number:
        return self.value


class Histogram:
    """Fixed-bucket histogram with first-class percentiles.

    ``bounds`` are ascending upper bounds; observations above the last
    bound land in an implicit overflow bucket.  ``percentile`` linearly
    interpolates within the bucket holding the q-th observation, clamped
    to the observed ``[min, max]`` — exact to within one bucket width
    (pinned against numpy quantiles in tests/test_obs.py).
    """
    __slots__ = ("name", "bounds", "counts", "count", "sum", "min", "max")

    def __init__(self, name: str, buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        self.name = name
        self.bounds: List[float] = sorted(float(b) for b in buckets)
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.bounds) + 1)   # +1: overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: Number) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, q: float) -> float:
        """q in [0, 1].  Returns 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else min(self.min, self.bounds[0])
            hi = self.bounds[i] if i < len(self.bounds) else self.max
            if seen + c >= rank:
                frac = 0.0 if c == 0 else max(rank - seen, 0.0) / c
                est = lo + frac * (hi - lo)
                return min(max(est, self.min), self.max)
            seen += c
        return self.max

    def snapshot(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count, "min": self.min,
                "max": self.max, "p50": self.percentile(0.50),
                "p90": self.percentile(0.90), "p99": self.percentile(0.99)}


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name -> metric, get-or-create, with one merged snapshot."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, *args, **labels):
        name = canonical_name(name, **labels)
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, Counter, **labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, Gauge, **labels)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  **labels) -> Histogram:
        return self._get(name, Histogram, buckets, **labels)

    def value(self, name: str, default: Number = 0, **labels) -> Number:
        m = self._metrics.get(canonical_name(name, **labels))
        return default if m is None else m.value

    def names(self, prefix: str = "") -> List[str]:
        return sorted(n for n in self._metrics if n.startswith(prefix))

    def snapshot(self, prefix: str = "") -> Dict[str, Union[Number, Dict]]:
        """Flat ``{name: value-or-histogram-summary}`` of every metric
        whose name starts with ``prefix`` (insertion-order agnostic)."""
        return {n: self._metrics[n].snapshot() for n in self.names(prefix)}


class CounterGroup(MutableMapping):
    """A ``stats`` dict whose entries are live registry counters.

    Drop-in for the plain dicts subsystems used pre-obs: supports
    ``stats["hits"] += 1``, ``dict(stats)``, ``.get``, iteration — same
    keys, same values.  With ``registry=None`` it degrades to local
    storage (standalone construction in unit tests stays dependency-
    free); with a registry each key is the counter
    ``{namespace}.{key}``, so the one metrics ledger carries the numbers
    the legacy reports are views of.
    """

    def __init__(self, init: Dict[str, Number],
                 registry: Optional[MetricsRegistry] = None,
                 namespace: str = ""):
        self._keys: List[str] = list(init)
        self._registry = registry
        self._namespace = namespace
        if registry is None:
            self._local: Dict[str, Number] = dict(init)
        else:
            self._local = {}
            for k, v in init.items():
                c = registry.counter(self._name(k))
                if v:
                    c.add(v)

    def _name(self, key: str) -> str:
        return f"{self._namespace}.{key}" if self._namespace else key

    def __getitem__(self, key: str) -> Number:
        if self._registry is None:
            return self._local[key]
        if key not in self._keys:
            raise KeyError(key)
        return self._registry.counter(self._name(key)).value

    def __setitem__(self, key: str, value: Number) -> None:
        if self._registry is None:
            self._local[key] = value
            return
        if key not in self._keys:
            self._keys.append(key)
        c = self._registry.counter(self._name(key))
        c.value = value

    def __delitem__(self, key: str) -> None:
        raise TypeError("stats keys are fixed for the run")

    def __iter__(self) -> Iterator[str]:
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __repr__(self) -> str:
        return f"CounterGroup({dict(self)!r})"


def merge_namespaced(*sections: Iterable) -> Dict:
    """Merge ``(name, dict)`` pairs into one namespaced report dict,
    dropping ``None`` sections."""
    out: Dict = {}
    for name, section in sections:
        if section is not None:
            out[name] = section
    return out
