"""repro.obs — unified tracing + metrics across edge, blockchain, and
storage layers.  See README.md in this directory."""
from repro.obs.metrics import (Counter, CounterGroup, Gauge, Histogram,
                               MetricsRegistry, DEFAULT_TIME_BUCKETS,
                               canonical_name, exp_buckets,
                               merge_namespaced)
from repro.obs.trace import (NOOP_SPAN, Observability, Span, Tracer,
                             annotate, annotations_enabled,
                             set_annotations)

__all__ = [
    "Counter", "CounterGroup", "Gauge", "Histogram", "MetricsRegistry",
    "DEFAULT_TIME_BUCKETS", "canonical_name", "exp_buckets",
    "merge_namespaced", "NOOP_SPAN", "Observability", "Span", "Tracer",
    "annotate", "annotations_enabled", "set_annotations",
]
