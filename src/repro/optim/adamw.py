"""AdamW + schedules (functional, pytree-based — optimizer state shards
exactly like the parameters)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps) /
                        max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = (0.5 * (1 + jnp.cos(jnp.pi * frac)) if cfg.schedule == "cosine"
                 else 1.0 - frac)
    return cfg.lr * warm * decay


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt, vdt = m.dtype, v.dtype  # state dtype roundtrips (bf16 or f32)
        g = g.astype(jnp.float32)
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return new_p.astype(p.dtype), m.astype(mdt), v.astype(vdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
