"""Chunked content-addressed serialization (paper §IV-A(4), storage layer).

An expert (any pytree of arrays) is serialized *per leaf*: each leaf's
raw bytes are split into fixed-size chunks and every chunk is
content-addressed by its SHA-256 CID.  A ``ChunkManifest`` names the
chunks in order, carries the leaf layout (shapes/dtypes/treedef) needed
to reassemble the tree, and commits the chunk CID list under one Merkle
root — the single digest that goes on-chain.  That layout is what makes
the storage layer auditable at chunk granularity:

- a *tampered* chunk is self-evident (its bytes no longer hash to the
  CID the manifest names) and is pinpointed without refetching the rest
  of the expert;
- a *withheld* chunk is a data-availability fault attributable to the
  replica node that committed to holding it (see ``repro.trust.da``);
- an *unchanged* chunk between two versions of the same expert keeps its
  CID, so uploading a new version costs only the changed chunks
  (chunk-level dedup — the ``ExpertStore`` economy).

The legacy whole-tree npz blob (``serialize_tree``/``deserialize_tree``)
is kept for checkpoints and one-shot objects; ``deserialize_tree`` now
verifies treedef compatibility against ``like`` instead of silently
unflattening into the wrong structure.
"""
from __future__ import annotations

import dataclasses
import functools
import io
import json
from typing import Any, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.ledger import digest_bytes
from repro.trust.commitments import MerklePath, MerkleTree

DEFAULT_CHUNK_BYTES = 1 << 16          # 64 KiB


# ------------------------------------------------------------ npz blob
def serialize_tree(tree) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, treedef=str(treedef),
             **{f"leaf{i}": np.asarray(l) for i, l in enumerate(leaves)})
    return buf.getvalue()


def deserialize_tree(data: bytes, like) -> Any:
    buf = io.BytesIO(data)
    z = np.load(buf, allow_pickle=False)
    leaves = [z[f"leaf{i}"] for i in range(len(z.files) - 1)]
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    stored = str(z["treedef"])
    if stored != str(treedef):
        raise ValueError(
            f"treedef mismatch: stored object has structure {stored}, "
            f"but `like` has {treedef} — wrong template for this CID")
    if len(leaves) != len(like_leaves):
        raise ValueError(f"stored object has {len(leaves)} leaves, "
                         f"`like` has {len(like_leaves)}")
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ------------------------------------------------------- chunk manifest
@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Layout of one serialized leaf: enough to rebuild the array from
    its chunk bytes without a template."""
    shape: Tuple[int, ...]
    dtype: str
    num_chunks: int
    nbytes: int


@dataclasses.dataclass(frozen=True)
class ChunkManifest:
    """The content-addressed description of one stored object version.

    ``chunk_cids`` is the flat chunk list, leaf-major in leaf order
    (leaf 0's chunks, then leaf 1's, ...).  ``root`` is the Merkle root
    over the chunk CIDs — the 32-byte commitment that goes on-chain; a
    Merkle path from it proves a single chunk's membership without the
    manifest.  The manifest itself is stored in the network as a JSON
    object whose CID (``manifest_cid``) names this exact version.
    """
    object_id: str
    version: int
    treedef: str
    leaves: Tuple[LeafSpec, ...]
    chunk_cids: Tuple[str, ...]
    chunk_sizes: Tuple[int, ...]
    root: str

    @property
    def total_bytes(self) -> int:
        return sum(self.chunk_sizes)

    @property
    def num_chunks(self) -> int:
        return len(self.chunk_cids)

    def to_json(self) -> bytes:
        d = dataclasses.asdict(self)
        return json.dumps(d, sort_keys=True).encode()

    @staticmethod
    def from_json(data: bytes) -> "ChunkManifest":
        d = json.loads(data.decode())
        d["leaves"] = tuple(LeafSpec(shape=tuple(ls["shape"]),
                                     dtype=ls["dtype"],
                                     num_chunks=ls["num_chunks"],
                                     nbytes=ls["nbytes"])
                            for ls in d["leaves"])
        d["chunk_cids"] = tuple(d["chunk_cids"])
        d["chunk_sizes"] = tuple(d["chunk_sizes"])
        return ChunkManifest(**d)

    @functools.cached_property
    def manifest_cid(self) -> str:
        # cached: the dataclass is frozen, so the canonical JSON (and
        # its digest) can never change after construction
        return digest_bytes(self.to_json())

    def prove_chunk(self, index: int) -> MerklePath:
        return MerkleTree(list(self.chunk_cids)).prove(index)

    def verify_chunk(self, index: int, data: bytes,
                     path: MerklePath | None = None) -> bool:
        """Chunk bytes check: hash to the named CID and (optionally)
        authenticate against the on-chain root through a Merkle path."""
        if digest_bytes(data) != self.chunk_cids[index]:
            return False
        if path is not None:
            return MerkleTree.verify(self.root, self.chunk_cids[index], path)
        return True


def split_chunks(data: bytes, chunk_bytes: int) -> List[bytes]:
    if not data:
        return [b""]
    return [data[i:i + chunk_bytes] for i in range(0, len(data), chunk_bytes)]


def build_manifest(object_id: str, version: int, tree,
                   chunk_bytes: int = DEFAULT_CHUNK_BYTES
                   ) -> Tuple[ChunkManifest, List[bytes]]:
    """Chunk a pytree into (manifest, chunk bytes), leaf-major order."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    specs: List[LeafSpec] = []
    chunks: List[bytes] = []
    for leaf in leaves:
        a = np.ascontiguousarray(np.asarray(leaf))
        parts = split_chunks(a.tobytes(), chunk_bytes)
        specs.append(LeafSpec(shape=tuple(a.shape), dtype=str(a.dtype),
                              num_chunks=len(parts), nbytes=a.nbytes))
        chunks.extend(parts)
    cids = tuple(digest_bytes(c) for c in chunks)
    root = MerkleTree(list(cids)).root
    manifest = ChunkManifest(object_id=object_id, version=version,
                             treedef=str(treedef), leaves=tuple(specs),
                             chunk_cids=cids,
                             chunk_sizes=tuple(len(c) for c in chunks),
                             root=root)
    return manifest, chunks


def assemble_tree(manifest: ChunkManifest, chunks: Sequence[bytes],
                  like) -> Any:
    """Rebuild the pytree from its chunk bytes (chunk-for-chunk inverse
    of ``build_manifest``).  ``like`` supplies the unflatten structure
    and is verified against the manifest's recorded treedef."""
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if str(treedef) != manifest.treedef:
        raise ValueError(
            f"treedef mismatch for {manifest.object_id!r} v{manifest.version}"
            f": manifest records {manifest.treedef}, `like` has {treedef}")
    if len(like_leaves) != len(manifest.leaves):
        raise ValueError(f"{manifest.object_id!r}: manifest has "
                         f"{len(manifest.leaves)} leaves, `like` has "
                         f"{len(like_leaves)}")
    if len(chunks) != manifest.num_chunks:
        raise ValueError(f"{manifest.object_id!r}: got {len(chunks)} chunks "
                         f"for a {manifest.num_chunks}-chunk manifest")
    out = []
    cursor = 0
    for spec in manifest.leaves:
        data = b"".join(chunks[cursor:cursor + spec.num_chunks])
        cursor += spec.num_chunks
        if len(data) != spec.nbytes:
            raise ValueError(f"{manifest.object_id!r}: leaf byte length "
                             f"{len(data)} != recorded {spec.nbytes}")
        out.append(np.frombuffer(data, dtype=np.dtype(spec.dtype))
                   .reshape(spec.shape))
    return jax.tree_util.tree_unflatten(treedef, out)
