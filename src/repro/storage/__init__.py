"""Decentralized storage layer (paper §IV-A(4)) as a real subsystem.

- ``chunks``: per-leaf fixed-size chunking under Merkle chunk manifests
  (the manifest root is the CID recorded on-chain), plus the legacy
  whole-tree npz blob serialization.
- ``network``: replicated content-addressed storage nodes with a
  randomized (seeded) replica read order, a deterministic
  bandwidth/latency cost model, and fault injection
  (corrupt/withhold) for the data-availability challenges.
- ``store``: ``ExpertStore`` — per-object *versioned* manifests keyed by
  training round with chunk-level dedup uploads and window-scoped
  retention/garbage collection.
- ``cache``: ``ExpertCache`` — the edge device's bounded-byte LRU of
  deserialized experts (pin-while-activated, hit/miss/evict/byte
  counters) with ``GateEMA`` gate-statistics-driven prefetch.
- ``kv``: ``KVBlockStore`` — sealed serving KV blocks addressed by
  prefix-hash CIDs, paged through the same store/cache machinery
  (cross-session prefix dedup, single byte budget with experts).
"""
from repro.storage.cache import ExpertCache, GateEMA
from repro.storage.chunks import (DEFAULT_CHUNK_BYTES, ChunkManifest,
                                  LeafSpec, assemble_tree, build_manifest,
                                  deserialize_tree, serialize_tree,
                                  split_chunks)
from repro.storage.kv import (KV_GENESIS, KVBlockStore, KVStorageConfig,
                              prefix_chain, prefix_cid)
from repro.storage.network import (DataUnavailable, NetworkCostModel,
                                   ReplicaFault, StorageNetwork, StorageNode)
from repro.storage.store import ChunkUnavailableError, ExpertStore

__all__ = [
    "ExpertCache", "GateEMA",
    "DEFAULT_CHUNK_BYTES", "ChunkManifest", "LeafSpec", "assemble_tree",
    "build_manifest", "deserialize_tree", "serialize_tree", "split_chunks",
    "KV_GENESIS", "KVBlockStore", "KVStorageConfig", "prefix_chain",
    "prefix_cid",
    "DataUnavailable", "NetworkCostModel", "ReplicaFault", "StorageNetwork",
    "StorageNode", "ChunkUnavailableError", "ExpertStore",
]
