"""Decentralized storage network (IPFS-like, paper §IV-A(4)).

Content-addressed: the CID of an object is the SHA-256 of its bytes, so
anything downloaded by CID can be verified against the CID recorded
on-chain (tamper-evidence).  ``StorageNetwork`` replicates each object
across ``replication`` nodes, survives node loss up to the replication
factor, and serves reads from a per-request *randomized* replica order
(seeded — deterministic across runs, but no node absorbs all reads).

Transfer cost is modeled, not just wall-clocked: every put/get accrues
``latency + bytes/bandwidth`` seconds on a deterministic
``NetworkCostModel``, so benchmarks can report byte and time economies
that do not depend on the host machine.

Fault injection (for the storage/serving fault suite and the
data-availability challenges in ``repro.trust.da``): a replica can be
*corrupted* (bytes flipped — detected by CID verification, served
around) or *withheld* (the node refuses to produce the bytes — the
DA-challengeable fault).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional

from repro.core.ledger import digest_bytes
from repro.obs.metrics import CounterGroup, MetricsRegistry


@dataclasses.dataclass(frozen=True)
class NetworkCostModel:
    """Deterministic per-request transfer cost: latency + bytes/bw."""
    bandwidth_bytes_per_s: float = 125e6       # 1 Gbps links
    latency_s: float = 2e-3

    def seconds(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_bytes_per_s


@dataclasses.dataclass
class ReplicaFault:
    """One observed bad replica: a get() that had to skip a node."""
    cid: str
    node_id: int
    kind: str                                  # "corrupted" | "withheld"


class StorageNode:
    def __init__(self, node_id: int):
        self.node_id = node_id
        self.objects: Dict[str, bytes] = {}
        self.withheld: set = set()             # cids the node refuses to serve
        self.reads = 0                         # served (healthy) reads

    def put(self, cid: str, data: bytes) -> None:
        self.objects[cid] = data

    def get(self, cid: str) -> Optional[bytes]:
        if cid in self.withheld:
            return None
        return self.objects.get(cid)

    def holds(self, cid: str) -> bool:
        """Committed to holding the object (withholding doesn't erase
        the commitment — that is exactly the DA-challengeable state)."""
        return cid in self.objects or cid in self.withheld


class StorageNetwork:
    """A set of storage nodes with replication. ``put`` returns the CID."""

    def __init__(self, num_nodes: int = 4, replication: int = 2,
                 seed: int = 0, cost: Optional[NetworkCostModel] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "storage.network"):
        self.nodes: List[StorageNode] = [StorageNode(i) for i in range(num_nodes)]
        self.replication = min(replication, num_nodes)
        # placement and read-scan orders draw from SEPARATE seeded
        # streams: the number of reads performed must never perturb
        # where later objects are placed (determinism across call
        # patterns that differ only in read count)
        self._place_rng = random.Random(seed)
        self._scan_rng = random.Random((seed << 1) ^ 0x5DEECE66D)
        self.cost = cost or NetworkCostModel()
        self.faults: List[ReplicaFault] = []
        # CIDs a read observed a bad replica of: a later re-offer of the
        # verified bytes heals those copies (see put)
        self._suspect: set = set()
        # transfer ledger: plain-dict interface, but with a registry
        # every entry is the live metric {namespace}.{key} (the obs
        # layer's view and this dict are the same numbers)
        self.stats = CounterGroup(
            {"put_requests": 0, "put_bytes": 0, "dedup_puts": 0,
             "healed_puts": 0, "get_requests": 0, "get_bytes": 0,
             "modeled_put_s": 0.0, "modeled_get_s": 0.0},
            metrics, namespace)

    # ------------------------------------------------------------ write
    def put(self, data: bytes) -> str:
        cid = digest_bytes(data)
        if self.has(cid):
            # content-addressed dedup: the bytes are already replicated,
            # nothing crosses the network.  If a reader has reported a
            # bad replica of this CID, the re-offered (verified) bytes
            # heal the corrupted copies instead of being dropped — an
            # honest re-upload must never be silently discarded just
            # because a poisoned key exists.
            if cid in self._suspect:
                for node in self.nodes:
                    if cid in node.objects \
                            and digest_bytes(node.objects[cid]) != cid:
                        node.put(cid, data)
                        self.stats["healed_puts"] += 1
                self._suspect.discard(cid)
            self.stats["dedup_puts"] += 1
            return cid
        for node in self._place_rng.sample(self.nodes, self.replication):
            node.put(cid, data)
            self.stats["put_requests"] += 1
            self.stats["put_bytes"] += len(data)
            self.stats["modeled_put_s"] += self.cost.seconds(len(data))
        return cid

    def put_tree(self, tree) -> str:
        from repro.storage.chunks import serialize_tree
        return self.put(serialize_tree(tree))

    # ------------------------------------------------------------- read
    def has(self, cid: str) -> bool:
        return any(cid in n.objects for n in self.nodes)

    def replicas(self, cid: str) -> List[int]:
        """Nodes committed to holding the object (withholding included)."""
        return [n.node_id for n in self.nodes if n.holds(cid)]

    def get(self, cid: str, verify: bool = True) -> bytes:
        """Fetch by CID: probe replicas in a per-request randomized order
        (seeded), skip corrupted/withheld copies (recording the fault),
        and serve the first copy whose bytes hash back to the CID — the
        verified-refetch path a tampered replica triggers."""
        found = False
        for node in self._scan_rng.sample(self.nodes, len(self.nodes)):
            data = node.get(cid)
            if data is None:
                if node.holds(cid):            # committed but not serving
                    self.faults.append(ReplicaFault(cid, node.node_id,
                                                    "withheld"))
                continue
            found = True
            if verify and digest_bytes(data) != cid:
                self.faults.append(ReplicaFault(cid, node.node_id,
                                                "corrupted"))
                self._suspect.add(cid)         # heal on the next re-offer
                continue                       # try another replica
            node.reads += 1
            self.stats["get_requests"] += 1
            self.stats["get_bytes"] += len(data)
            self.stats["modeled_get_s"] += self.cost.seconds(len(data))
            return data
        kind = "corrupted on every replica" if found else "not found"
        raise KeyError(f"CID {cid[:12]}... {kind} on any storage node")

    def get_tree(self, cid: str, like):
        from repro.storage.chunks import deserialize_tree
        return deserialize_tree(self.get(cid), like)

    def read_load(self) -> List[int]:
        """Per-node served-read counters (load-balance regression)."""
        return [n.reads for n in self.nodes]

    # ------------------------------------------------------ maintenance
    def discard(self, cid: str) -> None:
        """Drop an object from every node — e.g. a superseded expert
        version whose data-availability window (the challenge window)
        has closed."""
        for node in self.nodes:
            node.objects.pop(cid, None)
            node.withheld.discard(cid)

    def drop_node(self, node_id: int) -> None:
        self.nodes = [n for n in self.nodes if n.node_id != node_id]

    def repair(self, cid: str, node_id: int) -> bool:
        """Overwrite a node's replica with verified bytes refetched from
        a healthy replica (the recovery step after a corrupted-replica
        fault).  Returns False when no healthy replica remains."""
        try:
            data = self.get(cid)
        except KeyError:
            return False
        for node in self.nodes:
            if node.node_id == node_id:
                node.put(cid, data)
                node.withheld.discard(cid)
                return True
        return False

    # -------------------------------------------------- fault injection
    def node(self, node_id: int) -> StorageNode:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        raise KeyError(f"no node {node_id}")

    def corrupt_replica(self, cid: str, node_id: int) -> None:
        """Bit-flip one node's copy (CID verification will catch it)."""
        node = self.node(node_id)
        if cid not in node.objects:
            raise KeyError(f"node {node_id} holds no replica of "
                           f"{cid[:12]}...")
        data = bytearray(node.objects[cid])
        if data:
            data[0] ^= 0xFF
        else:
            data = bytearray(b"\x00")
        node.objects[cid] = bytes(data)

    def withhold(self, cid: str, node_id: Optional[int] = None) -> None:
        """Make replica(s) refuse to serve the object while still being
        committed to it — the data-availability fault."""
        for node in self.nodes:
            if node_id is not None and node.node_id != node_id:
                continue
            if cid in node.objects:
                node.withheld.add(cid)
