"""Decentralized storage network (IPFS-like, paper §IV-A(4)).

Content-addressed: the CID of an object is the SHA-256 of its bytes, so
anything downloaded by CID can be verified against the CID recorded
on-chain (tamper-evidence).  ``StorageNetwork`` replicates each object
across ``replication`` nodes, survives node loss up to the replication
factor, and serves reads from a per-request *randomized* replica order
(seeded — deterministic across runs, but no node absorbs all reads).

Transfer cost is modeled, not just wall-clocked: every put/get accrues
``latency + bytes/bandwidth`` seconds on a deterministic
``NetworkCostModel``, so benchmarks can report byte and time economies
that do not depend on the host machine.

Fault injection (for the storage/serving fault suite and the
data-availability challenges in ``repro.trust.da``): a replica can be
*corrupted* (bytes flipped — detected by CID verification, served
around) or *withheld* (the node refuses to produce the bytes — the
DA-challengeable fault; ``transient=k`` models a node that recovers
after ``k`` failed probes, the case the read retry loop exists for).

Reads are retried: a ``get`` whose first replica scan comes up empty
re-scans up to ``retry_budget`` times with exponentially-growing
*modeled* backoff seconds (booked to ``storage.network.retries`` /
``.modeled_backoff_s`` in the obs registry), then surfaces a hard
``DataUnavailable`` — a ``KeyError`` subclass, so every existing
handler (``ExpertStore.fetch_manifest`` -> ``ChunkUnavailableError``,
the DA challenges) still fires.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

from repro.core.ledger import digest_bytes
from repro.obs.metrics import CounterGroup, MetricsRegistry


@dataclasses.dataclass(frozen=True)
class NetworkCostModel:
    """Deterministic per-request transfer cost: latency + bytes/bw."""
    bandwidth_bytes_per_s: float = 125e6       # 1 Gbps links
    latency_s: float = 2e-3

    def seconds(self, nbytes: int) -> float:
        return self.latency_s + nbytes / self.bandwidth_bytes_per_s


@dataclasses.dataclass
class ReplicaFault:
    """One observed bad replica: a get() that had to skip a node, or a
    dropped node that took the last replica of an object with it."""
    cid: str
    node_id: int
    kind: str                     # "corrupted" | "withheld" | "lost"


class DataUnavailable(KeyError):
    """Hard unavailability: no replica produced verifiable bytes within
    the read retry budget (or the last replica left the network).  A
    ``KeyError`` subclass so existing recovery paths — the store's
    ``ChunkUnavailableError`` wrap, the DA challenges — fire unchanged.
    """

    def __init__(self, cid: str, kind: str, retries: int = 0):
        super().__init__(cid)
        self.cid = cid
        self.kind = kind
        self.retries = retries

    def __str__(self) -> str:
        tail = f" after {self.retries} retries" if self.retries else ""
        return f"CID {self.cid[:12]}... {self.kind}{tail}"


class StorageNode:
    def __init__(self, node_id: int):
        self.node_id = node_id
        self.objects: Dict[str, bytes] = {}
        self.withheld: set = set()             # cids the node refuses to serve
        self.transient: Dict[str, int] = {}    # cid -> refusals left before
        #                                        the node serves it again
        self.reads = 0                         # served (healthy) reads

    def put(self, cid: str, data: bytes) -> None:
        self.objects[cid] = data

    def get(self, cid: str) -> Optional[bytes]:
        left = self.transient.get(cid)
        if left is not None:
            if left > 0:                       # still refusing — but the
                self.transient[cid] = left - 1  # refusal budget drains, so
                return None                    # a retried read gets through
            del self.transient[cid]
        if cid in self.withheld:
            return None
        return self.objects.get(cid)

    def holds(self, cid: str) -> bool:
        """Committed to holding the object (withholding doesn't erase
        the commitment — that is exactly the DA-challengeable state)."""
        return cid in self.objects or cid in self.withheld


class StorageNetwork:
    """A set of storage nodes with replication. ``put`` returns the CID."""

    def __init__(self, num_nodes: int = 4, replication: int = 2,
                 seed: int = 0, cost: Optional[NetworkCostModel] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "storage.network",
                 retry_budget: int = 2, backoff_base_s: float = 0.05):
        self.nodes: List[StorageNode] = [StorageNode(i) for i in range(num_nodes)]
        self.replication = min(replication, num_nodes)
        # read retries: extra full replica scans after a failed one, with
        # exponential modeled backoff (deterministic — no wall clock)
        self.retry_budget = int(retry_budget)
        self.backoff_base_s = float(backoff_base_s)
        # placement and read-scan orders draw from SEPARATE seeded
        # streams: the number of reads performed must never perturb
        # where later objects are placed (determinism across call
        # patterns that differ only in read count)
        self._place_rng = random.Random(seed)
        self._scan_rng = random.Random((seed << 1) ^ 0x5DEECE66D)
        self.cost = cost or NetworkCostModel()
        self.faults: List[ReplicaFault] = []
        # CIDs a read observed a bad replica of: a later re-offer of the
        # verified bytes heals those copies (see put)
        self._suspect: set = set()
        # CIDs whose last replica left with a dropped node (the trust
        # event readers surface instead of an uncaught KeyError)
        self.lost: set = set()
        # transfer ledger: plain-dict interface, but with a registry
        # every entry is the live metric {namespace}.{key} (the obs
        # layer's view and this dict are the same numbers)
        self.stats = CounterGroup(
            {"put_requests": 0, "put_bytes": 0, "dedup_puts": 0,
             "healed_puts": 0, "get_requests": 0, "get_bytes": 0,
             "modeled_put_s": 0.0, "modeled_get_s": 0.0,
             "retries": 0, "modeled_backoff_s": 0.0,
             "lost_objects": 0, "repaired_replicas": 0},
            metrics, namespace)

    # ------------------------------------------------------------ write
    def put(self, data: bytes) -> str:
        cid = digest_bytes(data)
        if self.has(cid):
            # content-addressed dedup: the bytes are already replicated,
            # nothing crosses the network.  If a reader has reported a
            # bad replica of this CID, the re-offered (verified) bytes
            # heal the corrupted copies instead of being dropped — an
            # honest re-upload must never be silently discarded just
            # because a poisoned key exists.
            if cid in self._suspect:
                for node in self.nodes:
                    if cid in node.objects \
                            and digest_bytes(node.objects[cid]) != cid:
                        node.put(cid, data)
                        self.stats["healed_puts"] += 1
                self._suspect.discard(cid)
            self.stats["dedup_puts"] += 1
            return cid
        self.lost.discard(cid)                 # re-uploaded: available again
        for node in self._place_rng.sample(self.nodes, self.replication):
            node.put(cid, data)
            self.stats["put_requests"] += 1
            self.stats["put_bytes"] += len(data)
            self.stats["modeled_put_s"] += self.cost.seconds(len(data))
        return cid

    def put_tree(self, tree) -> str:
        from repro.storage.chunks import serialize_tree
        return self.put(serialize_tree(tree))

    # ------------------------------------------------------------- read
    def has(self, cid: str) -> bool:
        return any(cid in n.objects for n in self.nodes)

    def replicas(self, cid: str) -> List[int]:
        """Nodes committed to holding the object (withholding included)."""
        return [n.node_id for n in self.nodes if n.holds(cid)]

    def _scan(self, cid: str, verify: bool,
              seen: set) -> Tuple[Optional[bytes], bool]:
        """One randomized pass over the replicas: (bytes or None, whether
        any replica produced bytes at all).  ``seen`` dedupes the fault
        records across the retry passes of a single request."""
        found = False
        for node in self._scan_rng.sample(self.nodes, len(self.nodes)):
            data = node.get(cid)
            if data is None:
                if node.holds(cid) \
                        and (node.node_id, "withheld") not in seen:
                    seen.add((node.node_id, "withheld"))
                    self.faults.append(ReplicaFault(cid, node.node_id,
                                                    "withheld"))
                continue
            found = True
            if verify and digest_bytes(data) != cid:
                if (node.node_id, "corrupted") not in seen:
                    seen.add((node.node_id, "corrupted"))
                    self.faults.append(ReplicaFault(cid, node.node_id,
                                                    "corrupted"))
                self._suspect.add(cid)         # heal on the next re-offer
                continue                       # try another replica
            node.reads += 1
            self.stats["get_requests"] += 1
            self.stats["get_bytes"] += len(data)
            self.stats["modeled_get_s"] += self.cost.seconds(len(data))
            return data, True
        return None, found

    def get(self, cid: str, verify: bool = True) -> bytes:
        """Fetch by CID: probe replicas in a per-request randomized order
        (seeded), skip corrupted/withheld copies (recording the fault),
        and serve the first copy whose bytes hash back to the CID — the
        verified-refetch path a tampered replica triggers.

        A failed pass is retried up to ``retry_budget`` times as long as
        some node is still *committed* to the object (transient refusals
        recover, healed replicas reappear); each retry books one
        ``retries`` tick plus exponentially-growing modeled backoff
        seconds.  An exhausted budget surfaces ``DataUnavailable`` — the
        hard fault DA challenges attribute and slash."""
        seen: set = set()
        data, found = self._scan(cid, verify, seen)
        retries = 0
        while data is None and retries < self.retry_budget \
                and any(n.holds(cid) for n in self.nodes):
            retries += 1
            self.stats["retries"] += 1
            self.stats["modeled_backoff_s"] += \
                self.backoff_base_s * (2 ** (retries - 1))
            data, f = self._scan(cid, verify, seen)
            found = found or f
        if data is not None:
            return data
        if cid in self.lost:
            raise DataUnavailable(cid, "lost with its last replica",
                                  retries)
        kind = ("corrupted on every replica" if found else
                "unavailable on every replica" if seen else "not found")
        raise DataUnavailable(cid, kind, retries)

    def get_tree(self, cid: str, like):
        from repro.storage.chunks import deserialize_tree
        return deserialize_tree(self.get(cid), like)

    def read_load(self) -> List[int]:
        """Per-node served-read counters (load-balance regression)."""
        return [n.reads for n in self.nodes]

    # ------------------------------------------------------ maintenance
    def discard(self, cid: str) -> None:
        """Drop an object from every node — e.g. a superseded expert
        version whose data-availability window (the challenge window)
        has closed."""
        for node in self.nodes:
            node.objects.pop(cid, None)
            node.withheld.discard(cid)
            node.transient.pop(cid, None)

    def _healthy_bytes(self, cid: str) -> Optional[bytes]:
        """Verified bytes from any replica, without read accounting or
        fault records (the maintenance path re-replication uses)."""
        for node in self.nodes:
            data = node.objects.get(cid)
            if data is not None and digest_bytes(data) == cid:
                return data
        return None

    def drop_node(self, node_id: int, repair: bool = False) -> None:
        """Remove a node.  Every object it held is checked against the
        survivors: with ``repair=True`` the verified bytes are re-
        replicated from a surviving replica back up to the replication
        factor (so a fetch racing the drop still finds a healthy copy);
        an object whose LAST replica left with the node is recorded as a
        ``lost`` ReplicaFault trust event (and later fetches surface
        ``DataUnavailable``) instead of dying in an uncaught KeyError."""
        victim = next((n for n in self.nodes if n.node_id == node_id), None)
        self.nodes = [n for n in self.nodes if n.node_id != node_id]
        if victim is None:
            return
        for cid in sorted(set(victim.objects) | set(victim.withheld)):
            survivors = [n for n in self.nodes if n.holds(cid)]
            if not survivors:
                self.faults.append(ReplicaFault(cid, node_id, "lost"))
                self.lost.add(cid)
                self.stats["lost_objects"] += 1
                continue
            if not repair:
                continue
            data = self._healthy_bytes(cid)
            if data is None:
                continue        # survivors all corrupt/withheld: DA's case
            holders = {n.node_id for n in survivors}
            spares = [n for n in self.nodes if n.node_id not in holders]
            need = min(self.replication, len(self.nodes)) - len(holders)
            if need <= 0 or not spares:
                continue
            for node in self._place_rng.sample(spares,
                                               min(need, len(spares))):
                node.put(cid, data)
                self.stats["repaired_replicas"] += 1

    def repair(self, cid: str, node_id: int) -> bool:
        """Overwrite a node's replica with verified bytes refetched from
        a healthy replica (the recovery step after a corrupted-replica
        fault).  Returns False when no healthy replica remains."""
        try:
            data = self.get(cid)
        except KeyError:
            return False
        for node in self.nodes:
            if node.node_id == node_id:
                node.put(cid, data)
                node.withheld.discard(cid)
                node.transient.pop(cid, None)
                return True
        return False

    # -------------------------------------------------- fault injection
    def node(self, node_id: int) -> StorageNode:
        for n in self.nodes:
            if n.node_id == node_id:
                return n
        raise KeyError(f"no node {node_id}")

    def corrupt_replica(self, cid: str, node_id: int) -> None:
        """Bit-flip one node's copy (CID verification will catch it)."""
        node = self.node(node_id)
        if cid not in node.objects:
            raise KeyError(f"node {node_id} holds no replica of "
                           f"{cid[:12]}...")
        data = bytearray(node.objects[cid])
        if data:
            data[0] ^= 0xFF
        else:
            data = bytearray(b"\x00")
        node.objects[cid] = bytes(data)

    def withhold(self, cid: str, node_id: Optional[int] = None,
                 transient: int = 0) -> None:
        """Make replica(s) refuse to serve the object while still being
        committed to it — the data-availability fault.  ``transient=k``
        makes the refusal recover after ``k`` failed probes (the flaky-
        replica case the read retry budget is sized for); the default is
        a permanent withhold until repaired."""
        for node in self.nodes:
            if node_id is not None and node.node_id != node_id:
                continue
            if cid in node.objects:
                if transient > 0:
                    node.transient[cid] = int(transient)
                else:
                    node.withheld.add(cid)
