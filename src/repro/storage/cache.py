"""Edge-side expert cache with gate-statistics-driven prefetch.

The paper's edge layer "employs the activated experts downloaded from
the storage layer": an edge device holds a bounded-byte cache of expert
parameter trees keyed by object id, validated against the *current
version manifest* (a stale entry — the expert changed on-storage — is a
miss and refetches).  Eviction is LRU over unpinned entries; experts
activated by the round in flight are pinned so resolving a bank can
never evict what it is about to compute with.  Every hit/miss/eviction
and every fetched/evicted byte is counted — the cache IS the transfer
ledger benchmarks read.

``GateEMA`` tracks an exponential moving average of routing frequencies
(the gate statistics); ``ExpertCache.prefetch`` warms the top-EMA
experts before the next round/tick, fetching only while the byte budget
has room (prefetch never evicts — it fills idle capacity, it does not
compete with resident experts).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.metrics import CounterGroup, MetricsRegistry
from repro.storage.store import ExpertStore


class GateEMA:
    """EMA of per-expert routing frequencies (the prefetch signal)."""

    def __init__(self, num_experts: int, decay: float = 0.8):
        self.decay = float(decay)
        self.ema = np.zeros(num_experts, np.float64)
        self.updates = 0

    def update(self, counts) -> None:
        c = np.asarray(counts, np.float64)
        total = c.sum()
        freq = c / total if total > 0 else c
        if self.updates == 0:
            self.ema = freq
        else:
            self.ema = self.decay * self.ema + (1.0 - self.decay) * freq
        self.updates += 1

    def ranking(self) -> List[int]:
        """Expert ids, hottest first (deterministic: ties break by id)."""
        return sorted(range(len(self.ema)),
                      key=lambda e: (-self.ema[e], e))


class ExpertCache:
    def __init__(self, store: ExpertStore,
                 budget_bytes: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "storage.cache"):
        self.store = store
        self.budget_bytes = budget_bytes        # None: unbounded
        self._entries: "OrderedDict[str, Dict]" = OrderedDict()
        self._pinned: set = set()
        self.stats = CounterGroup(
            {"hits": 0, "misses": 0, "evictions": 0,
             "fetched_bytes": 0, "evicted_bytes": 0,
             "prefetches": 0, "bypasses": 0},
            metrics, namespace)

    # -------------------------------------------------------- residency
    @property
    def resident_bytes(self) -> int:
        return sum(e["nbytes"] for e in self._entries.values())

    def __contains__(self, object_id: str) -> bool:
        return object_id in self._entries

    def fresh(self, object_id: str, version: int) -> bool:
        """Cached AND current: the entry matches the version's manifest."""
        entry = self._entries.get(object_id)
        if entry is None:
            return False
        return entry["manifest_cid"] == self.store.manifest_cid(object_id,
                                                                version)

    def pin(self, object_ids: Sequence[str]) -> None:
        self._pinned.update(object_ids)

    def unpin(self, object_ids: Optional[Sequence[str]] = None) -> None:
        if object_ids is None:
            self._pinned.clear()
        else:
            self._pinned.difference_update(object_ids)
        # a resolve that pinned more than the budget holds runs
        # over-budget for its own duration only — the budget is
        # re-enforced the moment the pins drop (this is what makes a
        # tight budget *thrash* instead of silently growing)
        self._evict_to_budget()

    # ------------------------------------------------------------ fetch
    def get(self, object_id: str, version: int, like) -> Any:
        """Resolve an object at a version through the cache: a fresh
        entry is a hit; anything else (absent, or stale because the
        expert has a newer manifest at this version) fetches from the
        storage layer and admits the new bytes."""
        mcid = self.store.manifest_cid(object_id, version)
        entry = self._entries.get(object_id)
        if entry is not None and entry["manifest_cid"] == mcid:
            self.stats["hits"] += 1
            self._entries.move_to_end(object_id)
            return entry["tree"]
        self.stats["misses"] += 1
        manifest = self.store.manifest_by_cid(mcid)
        tree = self.store.fetch_manifest(manifest, like)
        self.stats["fetched_bytes"] += manifest.total_bytes
        self._admit(object_id, mcid, tree, manifest.total_bytes)
        return tree

    def _admit(self, object_id: str, manifest_cid: str, tree: Any,
               nbytes: int) -> None:
        if self.budget_bytes is not None and nbytes > self.budget_bytes:
            # larger than the whole cache: serve without admitting
            self._entries.pop(object_id, None)
            self.stats["bypasses"] += 1
            return
        self._entries.pop(object_id, None)
        self._entries[object_id] = {"manifest_cid": manifest_cid,
                                    "tree": tree, "nbytes": nbytes}
        self._evict_to_budget()

    def _evict_to_budget(self) -> None:
        if self.budget_bytes is None:
            return
        while self.resident_bytes > self.budget_bytes:
            victim = next((oid for oid in self._entries
                           if oid not in self._pinned), None)
            if victim is None:
                return                   # everything pinned: over-budget
            entry = self._entries.pop(victim)
            self.stats["evictions"] += 1
            self.stats["evicted_bytes"] += entry["nbytes"]

    # --------------------------------------------------------- prefetch
    def prefetch(self, ranked_ids: Sequence[str], version: int,
                 like_fn: Callable[[str], Any],
                 max_fetches: Optional[int] = None) -> List[str]:
        """Warm the cache with the hottest experts (``ranked_ids`` comes
        from ``GateEMA.ranking``): fetch each id that is not already
        fresh, in ranking order, while the byte budget has room — a
        prefetch never evicts a resident entry and never exceeds the
        budget.  Returns the ids actually fetched."""
        fetched: List[str] = []
        for object_id in ranked_ids:
            if max_fetches is not None and len(fetched) >= max_fetches:
                break
            if self.fresh(object_id, version):
                continue
            manifest = self.store.manifest(object_id, version)
            if self.budget_bytes is not None and \
                    self.resident_bytes + manifest.total_bytes \
                    > self.budget_bytes:
                continue                 # no room: prefetch never evicts
            tree = self.store.fetch_manifest(manifest, like_fn(object_id))
            self.stats["prefetches"] += 1
            self.stats["fetched_bytes"] += manifest.total_bytes
            self._admit(object_id, manifest.manifest_cid, tree,
                        manifest.total_bytes)
            fetched.append(object_id)
        return fetched
