"""Versioned, chunk-deduplicated expert store over the storage network.

One ``ExpertStore`` tracks any number of objects (experts, by
``object_id``), each with a sequence of *versions* keyed by training
round: ``put_version`` chunks the pytree (``repro.storage.chunks``),
uploads only the chunks the network does not already hold (unchanged
chunks between versions keep their CIDs — chunk-level dedup), and stores
the version's ``ChunkManifest`` as a content-addressed object of its
own.  The manifest's Merkle ``root`` is what the host records on-chain;
``manifest_cid`` names the exact version for retention accounting.

``fetch`` resolves an object at a version (the latest manifest tagged at
or before it — an expert untouched by rounds r..r+k serves round r+k
from its round-r manifest), pulls each chunk by CID (the network skips
corrupted replicas: verified refetch), verifies the chunk against the
manifest, and reassembles the tree chunk-for-chunk.  A chunk no healthy
replica can produce raises ``ChunkUnavailableError`` — the fault the
data-availability challenges (``repro.trust.da``) attribute and slash.

Retention: hosts ``retain`` the manifests a round's challenge window
still needs and ``release`` them when the round closes; a released
manifest that has been superseded by a newer version is garbage
collected, discarding the chunks no live manifest references.  The
latest version of every object is never collected.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.obs.metrics import CounterGroup, MetricsRegistry
from repro.storage.chunks import (DEFAULT_CHUNK_BYTES, ChunkManifest,
                                  assemble_tree, build_manifest)
from repro.storage.network import StorageNetwork


class ChunkUnavailableError(KeyError):
    """No healthy replica could produce a committed chunk."""

    def __init__(self, object_id: str, version: int, index: int, cid: str):
        super().__init__(cid)
        self.object_id = object_id
        self.version = version
        self.index = index
        self.cid = cid

    def __str__(self) -> str:
        return (f"chunk {self.index} ({self.cid[:12]}...) of "
                f"{self.object_id!r} v{self.version} unavailable on every "
                f"replica")


class ExpertStore:
    def __init__(self, network: StorageNetwork,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "storage.store"):
        self.network = network
        self.chunk_bytes = int(chunk_bytes)
        # object_id -> [(version, manifest_cid)], version-ascending
        self._versions: Dict[str, List[Tuple[int, str]]] = {}
        self._manifests: Dict[str, ChunkManifest] = {}    # by manifest cid
        self._refs: Dict[str, int] = {}                   # host retention
        self._chunk_refs: Dict[str, int] = {}             # live manifests
        self.stats = CounterGroup(
            {"versions": 0, "noop_versions": 0,
             "chunks_uploaded": 0, "chunks_deduped": 0,
             "uploaded_bytes": 0, "dedup_bytes": 0,
             "fetched_bytes": 0, "fetches": 0},
            metrics, namespace)

    # ------------------------------------------------------------ write
    def put_version(self, object_id: str, tree: Any,
                    version: int) -> ChunkManifest:
        """Publish one version of an object: upload only the chunks the
        network does not already hold; replace any manifest previously
        tagged at the same (object, version) — the honest-replay path
        after a chained rollback re-publishes the voided versions.

        Publishing content *identical* to what already serves this
        version tag is a no-op (the existing manifest is returned):
        re-publication never double-counts chunk references, and a
        rollback replay's full-bank republish creates no new version
        tags for experts the replay left unchanged."""
        manifest, chunks = build_manifest(object_id, version, tree,
                                          self.chunk_bytes)
        entries = self._versions.setdefault(object_id, [])
        serving = None
        for v, cid in entries:
            if v <= version:
                serving = cid
            else:
                break
        if serving is not None:
            cur = self._manifests.get(serving)
            if cur is not None and cur.chunk_cids == manifest.chunk_cids \
                    and cur.leaves == manifest.leaves:
                self.stats["noop_versions"] += 1
                return cur
        for cid, data in zip(manifest.chunk_cids, chunks):
            if self.network.has(cid):
                self.stats["chunks_deduped"] += 1
                self.stats["dedup_bytes"] += len(data)
            else:
                self.network.put(data)
                self.stats["chunks_uploaded"] += 1
                self.stats["uploaded_bytes"] += len(data)
            self._chunk_refs[cid] = self._chunk_refs.get(cid, 0) + 1
        self.network.put(manifest.to_json())
        mcid = manifest.manifest_cid
        self._manifests[mcid] = manifest
        replaced = [(v, c) for v, c in entries if v == version]
        entries[:] = [(v, c) for v, c in entries if v != version]
        entries.append((version, mcid))
        entries.sort()
        self.stats["versions"] += 1
        for _, old_cid in replaced:
            # a replaced manifest someone still retains (an open round
            # committed against it) keeps its bytes until released —
            # its auditors must fetch exactly what was committed, not
            # the replacement
            if old_cid != mcid and self._refs.get(old_cid, 0) == 0:
                self._drop_manifest(old_cid)
        # auto-GC: the version this one supersedes is collected as soon
        # as no host retains it (hosts without retention windows keep
        # only the latest version's bytes in the network)
        if len(entries) >= 2 and entries[-1][1] == mcid:
            prev_cid = entries[-2][1]
            if prev_cid != mcid and self._refs.get(prev_cid, 0) == 0:
                entries[:] = [(v, c) for v, c in entries if c != prev_cid]
                self._drop_manifest(prev_cid)
        return manifest

    # ------------------------------------------------------------ read
    def objects(self) -> List[str]:
        return sorted(self._versions)

    def contains(self, object_id: str, version: int = 0) -> bool:
        """Whether some manifest serves ``version`` of the object — the
        non-raising probe behind warm-prefix detection."""
        return any(v <= version
                   for v, _ in self._versions.get(object_id, []))

    def manifest_cid(self, object_id: str, version: int) -> str:
        """CID of the manifest serving ``version``: the newest one
        tagged at or before it."""
        entries = self._versions.get(object_id, [])
        best = None
        for v, cid in entries:
            if v <= version:
                best = cid
            else:
                break
        if best is None:
            raise KeyError(f"{object_id!r} has no version <= {version}")
        return best

    def manifest(self, object_id: str, version: int) -> ChunkManifest:
        return self._manifests[self.manifest_cid(object_id, version)]

    def manifest_by_cid(self, manifest_cid: str) -> ChunkManifest:
        if manifest_cid in self._manifests:
            return self._manifests[manifest_cid]
        # host-side index lost (fresh auditor): fetch the manifest object
        # from the network and verify it hashes back to its CID
        data = self.network.get(manifest_cid)
        manifest = ChunkManifest.from_json(data)
        if manifest.manifest_cid != manifest_cid:
            raise ValueError(f"manifest {manifest_cid[:12]}... does not "
                             f"hash to its CID")
        return manifest

    def fetch_manifest(self, manifest: ChunkManifest, like) -> Any:
        """Fetch + verify every chunk of a manifest and reassemble."""
        chunks: List[bytes] = []
        for i, cid in enumerate(manifest.chunk_cids):
            try:
                # network.get() hash-verifies every replica it serves, so
                # the returned bytes are already proven to match the CID
                # the manifest (and through its root, the chain) names
                data = self.network.get(cid)
            except KeyError as e:
                raise ChunkUnavailableError(manifest.object_id,
                                            manifest.version, i, cid) from e
            chunks.append(data)
        self.stats["fetches"] += 1
        self.stats["fetched_bytes"] += manifest.total_bytes
        return assemble_tree(manifest, chunks, like)

    def fetch(self, object_id: str, version: int, like) -> Any:
        return self.fetch_manifest(self.manifest(object_id, version), like)

    # -------------------------------------------------------- retention
    def retain(self, manifest_cid: str) -> None:
        self._refs[manifest_cid] = self._refs.get(manifest_cid, 0) + 1

    def release(self, manifest_cid: str) -> None:
        """Drop one retention ref; a superseded version nobody retains is
        garbage collected (manifest + the chunks only it references)."""
        refs = self._refs.get(manifest_cid, 0) - 1
        if refs > 0:
            self._refs[manifest_cid] = refs
            return
        self._refs.pop(manifest_cid, None)
        manifest = self._manifests.get(manifest_cid)
        if manifest is None:
            return
        entries = self._versions.get(manifest.object_id, [])
        if entries and entries[-1][1] == manifest_cid:
            return                      # latest version: never collected
        entries[:] = [(v, c) for v, c in entries if c != manifest_cid]
        self._drop_manifest(manifest_cid)

    def _drop_manifest(self, manifest_cid: str) -> None:
        manifest = self._manifests.pop(manifest_cid, None)
        if manifest is None:
            return
        for cid in manifest.chunk_cids:
            left = self._chunk_refs.get(cid, 0) - 1
            if left <= 0:
                self._chunk_refs.pop(cid, None)
                self.network.discard(cid)
            else:
                self._chunk_refs[cid] = left
        self.network.discard(manifest_cid)

    # ------------------------------------------------------- accounting
    def object_bytes(self, object_id: str,
                     version: Optional[int] = None) -> int:
        entries = self._versions.get(object_id, [])
        if not entries:
            return 0
        cid = (entries[-1][1] if version is None
               else self.manifest_cid(object_id, version))
        return self._manifests[cid].total_bytes

    def total_bytes(self) -> int:
        """Payload bytes of every object's latest version."""
        return sum(self.object_bytes(o) for o in self._versions)
