"""KV-cache paging through the chunked trust store (Mooncake / MoE-
Lightning storage-for-compute trade applied to the KV cache).

Decoded KV is sealed into fixed-size **blocks** of ``block_tokens``
cache rows each.  A block is an ordinary pytree (the per-layer K/V row
slices, plus the int8 scale rows when ``kv_cache_dtype="int8"``) and is
stored through the same ``ExpertStore`` machinery as expert weights:
chunked, content-addressed, Merkle-manifested, replicated, DA-
challengeable.

Blocks are addressed by **prefix-hash CIDs**: the CID of block *i* is

    cid_i = H(cid_{i-1} || int64 token ids the block covers)

seeded from ``KV_GENESIS``.  Cache row *p* holds the KV of the token
*fed* at position *p*, which is a pure function of the whole token
prefix — so the chain CID names exactly the content the block holds.
Two sessions sharing a prompt prefix derive identical CIDs for the
shared blocks, the second ``seal`` is an ``ExpertStore`` no-op
(chunk-level dedup), and a later admission with a matching prefix
fetches the sealed rows instead of recomputing prefill ("warm hit").

``KVBlockStore`` resolves blocks through an ``ExpertCache`` — the SAME
cache instance as the edge expert runtime when both are configured, so
KV blocks and expert weights compete under ONE byte budget and one LRU
(experts are pinned while activated; cold KV goes first).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.ledger import digest_bytes
from repro.obs.metrics import CounterGroup, MetricsRegistry
from repro.storage.cache import ExpertCache
from repro.storage.chunks import ChunkManifest
from repro.storage.store import ExpertStore

__all__ = ["KV_GENESIS", "KVStorageConfig", "KVBlockStore",
           "prefix_cid", "prefix_chain"]

KV_GENESIS = "kv-genesis"


@dataclasses.dataclass(frozen=True)
class KVStorageConfig:
    """Serving-engine KV paging knobs.

    ``block_tokens``: cache rows per sealed block (the paging granule).
    ``cache_bytes``: edge cache byte budget for the KV store's OWN cache
    — ignored when the engine shares the expert runtime's cache (the
    single-budget mode).  ``da_rate > 0`` runs data-availability
    challenges over the sealed KV chunks each time the engine seals a
    tick's worth of blocks, exactly like expert-chunk DA."""
    block_tokens: int = 16
    cache_bytes: Optional[int] = None       # None: unbounded
    chunk_bytes: int = 1 << 15
    num_nodes: int = 4
    replication: int = 2
    seed: int = 0
    da_rate: float = 0.0
    da_window: int = 2


# ------------------------------------------------------- prefix chain
def prefix_cid(prev_cid: str, tokens) -> str:
    """CID of the block covering ``tokens``, chained onto ``prev_cid``.

    Tokens are encoded as int64 bytes, so the CID binds both the values
    and the count — a tail block over fewer tokens can never collide
    with a full block over the same prefix."""
    t = np.ascontiguousarray(np.asarray(tokens, np.int64))
    return digest_bytes(prev_cid.encode() + t.tobytes())


def prefix_chain(tokens, block_tokens: int) -> List[str]:
    """CIDs of every FULL block of ``tokens`` (partial tail excluded):
    ``len(tokens) // block_tokens`` chained CIDs from ``KV_GENESIS``."""
    t = np.asarray(tokens, np.int64).reshape(-1)
    cids: List[str] = []
    prev = KV_GENESIS
    for b in range(len(t) // block_tokens):
        prev = prefix_cid(prev, t[b * block_tokens:(b + 1) * block_tokens])
        cids.append(prev)
    return cids


# ---------------------------------------------------------- the store
class KVBlockStore:
    """Sealed-KV-block store over an ``ExpertStore`` + ``ExpertCache``.

    Blocks are stored as object ``kv/{cid}`` at version 0 (a prefix CID
    names immutable content — there are no versions to roll).  Sealing
    a CID the store already holds is free: the identical content makes
    ``put_version`` a no-op and every chunk dedups (cross-session
    prefix reuse).  ``store``/``cache`` may be shared with the edge
    expert runtime — that sharing IS the single-byte-budget contract."""

    def __init__(self, store: ExpertStore, cache: ExpertCache,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "storage.kv"):
        self.store = store
        self.cache = cache
        self._sealed: Dict[str, str] = {}       # cid -> manifest cid
        self.stats = CounterGroup(
            {"sealed_blocks": 0, "sealed_tokens": 0, "sealed_bytes": 0,
             "dedup_blocks": 0, "warm_hits": 0, "warm_misses": 0,
             "restored_tokens": 0, "pageouts": 0, "resumes": 0},
            metrics, namespace)

    @staticmethod
    def object_id(cid: str) -> str:
        return f"kv/{cid}"

    def __contains__(self, cid: str) -> bool:
        return cid in self._sealed

    def sealed_cids(self) -> List[str]:
        return sorted(self._sealed)

    # ----------------------------------------------------------- seal
    def seal(self, cid: str, block: Any, num_tokens: int) -> ChunkManifest:
        """Publish one block under its prefix CID.  Re-sealing a known
        CID (another session reached the same prefix) is pure dedup —
        no new chunks, no new manifest."""
        if cid in self._sealed:
            self.stats["dedup_blocks"] += 1
            return self.store.manifest_by_cid(self._sealed[cid])
        manifest = self.store.put_version(self.object_id(cid), block, 0)
        self._sealed[cid] = manifest.manifest_cid
        self.stats["sealed_blocks"] += 1
        self.stats["sealed_tokens"] += int(num_tokens)
        self.stats["sealed_bytes"] += manifest.total_bytes
        return manifest

    def manifest(self, cid: str) -> ChunkManifest:
        return self.store.manifest_by_cid(self._sealed[cid])

    # ---------------------------------------------------------- fetch
    def fetch(self, cid: str, like: Any) -> Any:
        """Resolve a sealed block through the (possibly shared) cache."""
        return self.cache.get(self.object_id(cid), 0, like)

    def warm_prefix(self, cids: Sequence[str]) -> int:
        """How many leading CIDs of a chain are sealed (restorable).
        Books one warm hit per sealed leading block, one warm miss if
        the chain breaks before its end."""
        n = 0
        for cid in cids:
            if cid not in self._sealed:
                break
            n += 1
        self.stats["warm_hits"] += n
        if n < len(cids):
            self.stats["warm_misses"] += 1
        return n

    # ------------------------------------------------------ manifests
    def manifests(self, cids: Sequence[str]) -> Dict[str, ChunkManifest]:
        """object_id -> manifest map for DA challenges over sealed KV."""
        return {self.object_id(c): self.manifest(c) for c in cids
                if c in self._sealed}
