"""smollm-360m — llama-arch small dense, GQA (kv=5).
[hf:HuggingFaceTB/SmolLM-135M]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    tie_embeddings=True,
    block_pattern=(LayerSpec("attn", "dense"),),
    num_blocks=32,
    citation="[hf:HuggingFaceTB/SmolLM-135M]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=240, num_heads=5,
    num_kv_heads=5, head_dim=48, d_ff=512, vocab_size=512)
