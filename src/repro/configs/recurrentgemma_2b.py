"""recurrentgemma-2b — hybrid: RG-LRU recurrent blocks + local attention,
pattern (rglru, rglru, local_attn). [arXiv:2402.19427]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

_PATTERN = (LayerSpec("rglru", "dense"), LayerSpec("rglru", "dense"),
            LayerSpec("local_attn", "dense"))

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=_PATTERN,
    num_blocks=8,
    remainder=(LayerSpec("rglru", "dense"), LayerSpec("rglru", "dense")),
    rglru_expand=1,
    train_microbatches=2,
    citation="[arXiv:2402.19427]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=256, num_heads=2, num_kv_heads=1,
    head_dim=64, d_ff=512, vocab_size=512, sliding_window=32,
    block_pattern=(LayerSpec("rglru", "dense"),
                   LayerSpec("local_attn", "dense")),
    num_blocks=1, remainder=())
