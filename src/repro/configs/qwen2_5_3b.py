"""qwen2.5-3b — dense, GQA (kv=2), QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    block_pattern=(LayerSpec("attn", "dense"),),
    num_blocks=36,
    train_microbatches=2,
    citation="[hf:Qwen/Qwen2.5-0.5B]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=256, num_heads=4,
    num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512)
