"""gemma3-27b — dense with 5:1 local:global attention, 128k context,
qk_norm. [hf:google/gemma-3-1b-pt]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

_L = LayerSpec("local_attn", "dense")
_G = LayerSpec("attn", "dense")

CONFIG = ModelConfig(
    name="gemma3-27b",
    arch_type="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    qk_norm=True,
    sliding_window=1024,
    rope_theta=1_000_000.0,
    block_pattern=(_L, _L, _L, _L, _L, _G),
    num_blocks=10,
    remainder=(_L, _L),
    train_microbatches=8,
    citation="[hf:google/gemma-3-1b-pt]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    head_dim=64, d_ff=512, vocab_size=512, sliding_window=32,
    block_pattern=(_L, _G), num_blocks=1, remainder=())
