"""bmoe-paper — the paper's own MoE setup lifted to an LM-scale config:
N=10 experts, K=3 activated (paper §V: N=M=10, K=3), with B-MoE
redundancy enabled (faithful mode, r=2 by default).

This is the config used to demonstrate the paper's technique inside the
transformer framework; the paper's *original* MLP/CNN-expert experiments
live in repro.core.bmoe and the fig* benchmarks.
"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig, RedundancyConfig

CONFIG = ModelConfig(
    name="bmoe-paper",
    arch_type="moe",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2816,
    vocab_size=32768,
    block_pattern=(LayerSpec("attn", "moe"),),
    num_blocks=12,
    num_experts=10,            # N = 10 (paper)
    num_experts_per_tok=3,     # K = 3 (paper)
    num_shared_experts=0,
    moe_d_ff=2816,
    redundancy=RedundancyConfig(r=2, mode="faithful"),
    citation="[this paper, §V experiment setting]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=256, num_heads=4,
    num_kv_heads=2, head_dim=64, d_ff=256, vocab_size=512, num_experts=4,
    num_experts_per_tok=3, moe_d_ff=128,
    redundancy=RedundancyConfig(r=2, mode="faithful"))
