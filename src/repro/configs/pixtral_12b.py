"""pixtral-12b — VLM: pixtral-ViT frontend (STUB: precomputed patch
embeddings) + mistral-nemo decoder backbone. [hf:mistralai/Pixtral-12B-2409]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    block_pattern=(LayerSpec("attn", "dense"),),
    num_blocks=40,
    frontend="vision",
    frontend_tokens=1024,     # patch embeddings per image (stub)
    train_microbatches=4,
    citation="[hf:mistralai/Pixtral-12B-2409]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=256, num_heads=4,
    num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
    frontend_tokens=16)
