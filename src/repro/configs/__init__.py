"""Assigned-architecture registry.

Every architecture is selectable as ``--arch <id>``; each file carries the
exact assigned config plus a REDUCED smoke variant (<=2 layers,
d_model<=512, <=4 experts) used by CPU tests.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "qwen2.5-3b",
    "smollm-360m",
    "qwen3-32b",
    "recurrentgemma-2b",
    "pixtral-12b",
    "seamless-m4t-medium",
    "gemma3-27b",
    "llama4-maverick-400b-a17b",
    "qwen2-moe-a2.7b",
    "mamba2-2.7b",
    "bmoe-paper",            # the paper's own MoE setup at LM scale
)

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "smollm-360m": "smollm_360m",
    "qwen3-32b": "qwen3_32b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "pixtral-12b": "pixtral_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "gemma3-27b": "gemma3_27b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "bmoe-paper": "bmoe_paper",
}


def get_config(arch_id: str, smoke: bool = False):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if smoke and (cfg.train_microbatches != 1 or cfg.padded_num_experts):
        import dataclasses
        cfg = dataclasses.replace(cfg, train_microbatches=1,
                                  padded_num_experts=0)
    return cfg.validate()
