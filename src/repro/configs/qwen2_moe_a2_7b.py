"""qwen2-moe-a2.7b — MoE: 60 routed experts top-4 + 4 shared experts
(fused), moe_d_ff=1408. [hf:Qwen/Qwen1.5-MoE-A2.7B]

60 experts do not divide the 16-wide model axis, so the sharding rules
fall back to tensor parallelism inside each expert (moe_ff axis)."""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig, RedundancyConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,                 # shared-expert fused hidden (4 x 1408)
    vocab_size=151936,
    qkv_bias=True,
    block_pattern=(LayerSpec("attn", "moe"),),
    num_blocks=24,
    num_experts=60,
    padded_num_experts=64,   # pad to shard 64 experts over 16-wide model axis
    moe_impl="ep",           # shard_map all_to_all expert parallelism
    num_experts_per_tok=4,
    num_shared_experts=4,
    moe_d_ff=1408,
    train_microbatches=2,
    citation="[hf:Qwen/Qwen1.5-MoE-A2.7B]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=256, num_heads=4,
    num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512, num_experts=4,
    num_experts_per_tok=2, num_shared_experts=1, moe_d_ff=128)

TRUSTED_FAITHFUL = dataclasses.replace(
    CONFIG, redundancy=RedundancyConfig(r=4, mode="faithful"))
TRUSTED_DIGEST = dataclasses.replace(
    CONFIG, redundancy=RedundancyConfig(r=4, mode="digest"))
