"""qwen3-32b — dense, GQA (kv=8), qk_norm. [hf:Qwen/Qwen3-8B]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=(LayerSpec("attn", "dense"),),
    num_blocks=64,
    train_microbatches=8,
    citation="[hf:Qwen/Qwen3-8B]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=256, num_heads=8,
    num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=512)
