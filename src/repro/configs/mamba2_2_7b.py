"""mamba2-2.7b — attention-free SSM, SSD (state-space duality),
ssm_state=128. [arXiv:2405.21060]

The paper's expert-level redundancy technique is inapplicable (no routed
experts); implemented without it per DESIGN.md §Arch-applicability."""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,               # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(LayerSpec("ssm", "none"),),
    num_blocks=64,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    train_microbatches=4,
    citation="[arXiv:2405.21060]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, d_model=256, vocab_size=512,
    ssm_state=32, ssm_head_dim=32)
