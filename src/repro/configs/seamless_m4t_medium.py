"""seamless-m4t-medium — audio enc-dec backbone (STUB audio frontend:
precomputed frame embeddings feed the encoder). [arXiv:2308.11596]"""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,            # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    block_pattern=(LayerSpec("attn", "dense"),),
    num_blocks=12,
    frontend="audio",
    citation="[arXiv:2308.11596]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=2, num_encoder_layers=2, d_model=256,
    num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512, vocab_size=512)
