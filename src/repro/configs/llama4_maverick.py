"""llama4-maverick-400b-a17b — MoE 128 routed experts top-1 + 1 shared
expert, GQA (kv=8), early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]

Native target of the paper's B-MoE technique: per-expert redundancy +
consensus vote (see repro.core.trusted_moe)."""
import dataclasses

from repro.models.config import LayerSpec, ModelConfig, RedundancyConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    # Maverick interleaves dense and MoE layers 1:1 — 24 MoE layers of
    # 128 routed experts + shared expert => ~400B total / ~17B active
    block_pattern=(LayerSpec("attn", "dense"), LayerSpec("attn", "moe")),
    num_blocks=24,
    num_experts=128,
    moe_impl="ep",           # shard_map all_to_all expert parallelism
    num_experts_per_tok=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    train_microbatches=4,
    citation="[hf:meta-llama/Llama-4-Scout-17B-16E]",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, num_blocks=1, d_model=256, num_heads=4,
    train_microbatches=1,
    num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512, num_experts=4,
    moe_d_ff=256)

# paper-faithful trusted variants (r-way redundancy on expert outputs)
TRUSTED_FAITHFUL = dataclasses.replace(
    CONFIG, redundancy=RedundancyConfig(r=4, mode="faithful"))
TRUSTED_DIGEST = dataclasses.replace(
    CONFIG, redundancy=RedundancyConfig(r=4, mode="digest"))
