"""Merkle commitments over per-expert output chunks.

The executor edge splits each expert's result on the published task into
``chunks_per_expert`` contiguous batch chunks, digests every chunk into a
leaf, and commits the single Merkle root on-chain.  Auditors later
recompute sampled leaves; a mismatching leaf plus its Merkle path is a
fraud proof checkable by anyone holding only the 32-byte root — the
commitment is what makes O(1)-sized proofs possible.

Leaf ordering is row-major over (expert, chunk): leaf index
``e * chunks_per_expert + c`` covers expert ``e``'s rows
``[chunk_bounds[c], chunk_bounds[c+1])`` of the batch.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ledger import digest_bytes


def leaf_digest(chunk: np.ndarray) -> str:
    """Digest of one output chunk (shape/dtype-sensitive, like
    ledger.digest_array, but domain-separated from interior nodes)."""
    a = np.ascontiguousarray(chunk)
    return digest_bytes(b"leaf:" + a.tobytes() + str(a.shape).encode()
                        + str(a.dtype).encode())


def leaf_digest_batch(chunks, lengths: Optional[Sequence[int]] = None
                      ) -> List[str]:
    """Digest every leaf of a stacked chunk batch in one pass.

    ``chunks`` is ``(S, Cmax, *tail)``; row ``s`` covers the leaf's first
    ``lengths[s]`` rows (``Cmax`` when ``lengths`` is None — the
    equal-chunk fast path).  Rows past a leaf's length are padding and
    never enter the hash.  Digests are byte-identical to
    ``leaf_digest(chunks[s, :lengths[s]])``: one ``ascontiguousarray``
    up front makes every leading-axis slice a contiguous view, so no
    per-leaf canonicalization copies remain — this is the fused-hash
    half of the batched audit pass, and what ``commit_outputs`` uses to
    digest a whole round at once.
    """
    a = np.ascontiguousarray(chunks)
    if a.ndim < 2:
        raise ValueError(f"expected (S, Cmax, ...), got {a.shape}")
    dt = str(a.dtype).encode()
    if lengths is None:
        shp = str(a.shape[1:]).encode()
        return [digest_bytes(b"leaf:" + a[s].tobytes() + shp + dt)
                for s in range(a.shape[0])]
    if len(lengths) != a.shape[0]:
        raise ValueError(f"{len(lengths)} lengths for {a.shape[0]} leaves")
    out = []
    for s, n in enumerate(lengths):
        v = a[s, :n]
        out.append(digest_bytes(b"leaf:" + v.tobytes()
                                + str(v.shape).encode() + dt))
    return out


def _node_digest(left: str, right: str) -> str:
    return hashlib.sha256(b"node:" + left.encode() + right.encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class MerklePath:
    """Authentication path for one leaf: sibling digests bottom-up plus
    the leaf's index (the index determines left/right at each level)."""
    index: int
    siblings: Tuple[str, ...]


class MerkleTree:
    """Binary Merkle tree over a list of leaf digests.

    Odd levels are padded by duplicating the last node (Bitcoin-style),
    so any leaf count works.  ``prove``/``verify`` round-trip: a path is
    valid iff folding the leaf digest up through the siblings reproduces
    the root.
    """

    def __init__(self, leaves: Sequence[str]):
        if not leaves:
            raise ValueError("Merkle tree needs at least one leaf")
        self.leaves = list(leaves)
        self.levels: List[List[str]] = [list(leaves)]
        while len(self.levels[-1]) > 1:
            cur = self.levels[-1]
            if len(cur) % 2:
                cur = cur + [cur[-1]]
            self.levels.append([_node_digest(cur[i], cur[i + 1])
                                for i in range(0, len(cur), 2)])

    @property
    def root(self) -> str:
        return self.levels[-1][0]

    def prove(self, index: int) -> MerklePath:
        if not 0 <= index < len(self.leaves):
            raise IndexError(index)
        siblings = []
        i = index
        for level in self.levels[:-1]:
            padded = level + [level[-1]] if len(level) % 2 else level
            sib = i + 1 if i % 2 == 0 else i - 1
            siblings.append(padded[sib])
            i //= 2
        return MerklePath(index=index, siblings=tuple(siblings))

    @staticmethod
    def verify(root: str, leaf: str, path: MerklePath) -> bool:
        h = leaf
        i = path.index
        for sib in path.siblings:
            h = _node_digest(h, sib) if i % 2 == 0 else _node_digest(sib, h)
            i //= 2
        return h == root


def chunk_bounds(batch: int, chunks: int) -> List[int]:
    """Contiguous near-equal chunk boundaries: len == chunks+1."""
    chunks = max(1, min(chunks, batch))
    edges = np.linspace(0, batch, chunks + 1).astype(int)
    return list(edges)


@dataclasses.dataclass
class RoundCommitment:
    """What the executor publishes for one round.

    Only ``root`` (plus, for sparse dispatch, the routing digest) goes
    on-chain; the claimed outputs (the leaf data) stay off-chain with the
    executor, retrievable by auditors on demand.

    Dense dispatch commits the full per-expert outputs ``(N, B, C)`` —
    leaf ``(e, c)`` covers batch rows ``bounds[c]:bounds[c+1]``.  Sparse
    dispatch commits the capacity-bucketed buffers ``(N, capacity, C)``
    the executor actually computed: leaf ``(e, c)`` covers bucket slots
    ``bounds[c]:bounds[c+1]`` of expert ``e``, and ``row_index[e, s]``
    names the task row filling slot ``s`` (one past the batch = empty
    slot, recomputed from a zero row).  Publishing ``row_index`` is what
    lets any auditor re-derive the exact buckets and recompute a sampled
    leaf without re-running the gate — verification cost scales with
    ``top_k/num_experts`` exactly like execution cost.
    """
    round_id: int
    executor: int
    root: str
    num_experts: int
    chunks_per_expert: int
    bounds: List[int]                       # batch/bucket chunk boundaries
    leaf_digests: List[str]
    claimed: np.ndarray                     # (N, B|cap, C) executor outputs
    task_digest: str = ""
    row_index: Optional[np.ndarray] = None  # (N, cap) task row per slot
    routing_digest: str = ""                # binds row_index on-chain
    num_shards: int = 1                     # edge shards that hashed locally
    shard_roots: Optional[List[str]] = None  # per-edge subtree roots

    @property
    def num_leaves(self) -> int:
        return len(self.leaf_digests)

    @property
    def rows_per_expert(self) -> int:
        """Committed rows per expert: the capacity bucket under sparse
        dispatch, the full batch under dense — the unit audit/court
        recompute cost scales with."""
        return int(self.claimed.shape[1])

    def leaf_coords(self, leaf: int) -> Tuple[int, int, slice]:
        """leaf index -> (expert, chunk, batch slice)."""
        e, c = divmod(leaf, self.chunks_per_expert)
        return e, c, slice(self.bounds[c], self.bounds[c + 1])

    def leaf_chunk(self, leaf: int) -> np.ndarray:
        e, _, sl = self.leaf_coords(leaf)
        return self.claimed[e, sl]

    def tree(self) -> MerkleTree:
        return MerkleTree(self.leaf_digests)


def routing_digest(row_index: np.ndarray) -> str:
    """Digest of the published routing indices (domain-separated so a
    routing tensor can never collide with an output leaf)."""
    a = np.ascontiguousarray(row_index)
    return digest_bytes(b"routing:" + a.tobytes() + str(a.shape).encode()
                        + str(a.dtype).encode())


def _leaf_digests(claimed: np.ndarray, bounds: List[int]) -> List[str]:
    """Leaf digests for one executor's (or one edge shard's) expert
    slice, in (expert, chunk) row-major leaf order."""
    n_experts = claimed.shape[0]
    chunks = len(bounds) - 1
    widths = [bounds[c + 1] - bounds[c] for c in range(chunks)]
    if len(set(widths)) == 1:
        # equal chunks: digest the whole slice through one reshaped view
        # (leaf order is (e, c) row-major, exactly the reshape order)
        return leaf_digest_batch(
            claimed.reshape((n_experts * chunks, widths[0])
                            + claimed.shape[2:]))
    per_chunk = [leaf_digest_batch(claimed[:, bounds[c]:bounds[c + 1]])
                 for c in range(chunks)]
    return [per_chunk[c][e]
            for e in range(n_experts) for c in range(chunks)]


def commit_outputs(outputs, *, round_id: int, executor: int,
                   chunks_per_expert: int = 4, task_digest: str = "",
                   row_index: Optional[np.ndarray] = None,
                   num_shards: int = 1) -> RoundCommitment:
    """Build the executor's round commitment from its claimed per-expert
    outputs ``(N, B, C)`` — or, with ``row_index``, from its sparse
    capacity-bucketed buffers ``(N, capacity, C)`` (see RoundCommitment:
    the routing indices travel with the commitment so auditors re-derive
    the same buckets).

    ``num_shards`` > 1 models mesh execution: the expert axis splits
    into contiguous edge slices (shard ``s`` owns experts
    ``[s*E_l, (s+1)*E_l)``), each edge digests only its local
    ``(E_l, capacity, C)`` buffers into its own Merkle subtree, and the
    round root is the Merkle reduction over the ``num_shards`` shard
    roots.  Each shard's leaf count must be a power of two — then every
    shard subtree is a complete subtree of the flat tree, so the
    root-of-roots, every leaf's authentication path, and hence every
    fraud proof are BIT-IDENTICAL to the single-device commitment
    (pinned in tests/test_mesh_bmoe.py)."""
    claimed = np.ascontiguousarray(outputs)
    n_experts, batch = claimed.shape[:2]
    if num_shards < 1 or n_experts % num_shards:
        raise ValueError(f"num_shards ({num_shards}) must divide the "
                         f"expert count ({n_experts})")
    bounds = chunk_bounds(batch, chunks_per_expert)
    chunks = len(bounds) - 1
    shard_roots: Optional[List[str]] = None
    if num_shards > 1:
        e_l = n_experts // num_shards
        digests = []
        for s in range(num_shards):   # each edge hashes only its slice
            digests.extend(_leaf_digests(
                claimed[s * e_l:(s + 1) * e_l], bounds))
        lps = len(digests) // num_shards
        if lps & (lps - 1):
            raise ValueError(
                f"shard-local commitment needs a power-of-two leaf count "
                f"per shard, got ({n_experts}/{num_shards}) experts x "
                f"{chunks} chunks = {lps}; pick chunks_per_expert or the "
                f"shard count so (num_experts/num_shards)*chunks_per_expert "
                f"is a power of two")
        shard_roots = [MerkleTree(digests[s * lps:(s + 1) * lps]).root
                       for s in range(num_shards)]
        tree = MerkleTree(shard_roots)
    else:
        digests = _leaf_digests(claimed, bounds)
        tree = MerkleTree(digests)
    if row_index is not None:
        row_index = np.ascontiguousarray(np.asarray(row_index, np.int32))
        if row_index.shape != (n_experts, batch):
            raise ValueError(f"row_index {row_index.shape} does not match "
                             f"claimed {(n_experts, batch)}")
    return RoundCommitment(round_id=round_id, executor=executor,
                           root=tree.root, num_experts=n_experts,
                           chunks_per_expert=chunks, bounds=bounds,
                           leaf_digests=digests, claimed=claimed,
                           task_digest=task_digest, row_index=row_index,
                           routing_digest=(routing_digest(row_index)
                                           if row_index is not None else ""),
                           num_shards=num_shards, shard_roots=shard_roots)
