"""Stake accounting, slashing, and the dispute court.

Optimistic acceptance is only safe if cheating is unprofitable: every
executor posts a deposit, and a confirmed fraud proof burns a fraction of
it (part is paid to the reporting verifier as a bounty).  Confirmed
proofs also feed the existing ``ReputationLedger`` (paper §VI-B/D) so
repeat offenders cross the exclusion threshold and are barred from the
executor rotation and the electorate — the same damage-bounding the
paper applies to redundancy consensus, reused for the optimistic path.

The ``DisputeCourt`` is the fallback when a round is challenged: it
re-runs the paper's full M-way redundancy vote (every edge recomputes,
majority wins) for that single round, so a disputed round costs O(M)
but an undisputed one stays O(1) + audit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.reputation import ReputationLedger
from repro.kernels import ref as kref
from repro.trust.audit import FraudProof


@dataclasses.dataclass
class SlashEvent:
    round_id: int
    edge: int
    amount: float
    bounty: float
    verifier: int


class StakeBook:
    """Per-edge security deposits with slashing and bounties."""

    def __init__(self, num_edges: int, stake: float = 1.0,
                 slash_fraction: float = 0.5, bounty_fraction: float = 0.5,
                 min_stake: float = 0.25):
        self.stake = np.full(num_edges, float(stake))
        self.initial = float(stake)
        self.slash_fraction = float(slash_fraction)
        self.bounty_fraction = float(bounty_fraction)
        self.min_stake = float(min_stake)
        # keyed by verifier id — a distinct id space from edges
        self.bounties: Dict[int, float] = {}
        self.events: List[SlashEvent] = []

    def bonded(self, edge: int) -> bool:
        """Only edges with enough remaining stake may execute."""
        return self.stake[edge] >= self.min_stake

    def bonded_edges(self) -> List[int]:
        return [i for i in range(len(self.stake)) if self.bonded(i)]

    def slash(self, proof: FraudProof) -> SlashEvent:
        """Burn a fraction of the executor's stake; pay the bounty to the
        verifier that raised the proof (griefing-resistant because the
        proof was already court-confirmed)."""
        edge = proof.executor
        amount = self.stake[edge] * self.slash_fraction
        self.stake[edge] -= amount
        bounty = amount * self.bounty_fraction
        if proof.verifier >= 0:
            self.bounties[proof.verifier] = \
                self.bounties.get(proof.verifier, 0.0) + bounty
        ev = SlashEvent(round_id=proof.round_id, edge=edge, amount=amount,
                        bounty=bounty, verifier=proof.verifier)
        self.events.append(ev)
        return ev


def reputation_fraud_update(reputation: Optional[ReputationLedger],
                            guilty_edge: int, num_edges: int) -> None:
    """Feed a confirmed fraud proof into the reputation ledger as a
    consensus outcome: the guilty edge's result was rejected (its column
    is all-zero), everyone else's stood (paper §VI-D slashing signal)."""
    if reputation is None:
        return
    flags = np.ones((1, num_edges), dtype=np.int32)
    flags[0, guilty_edge] = 0
    reputation.update_from_flags(flags)


@dataclasses.dataclass
class Verdict:
    """Outcome of a dispute escalation (the full-redundancy court)."""
    round_id: int
    trusted: np.ndarray                 # (N, B, C) majority outputs
    support: np.ndarray                 # (N,) coalition sizes
    flags: np.ndarray                   # (N, M) per-edge agreement
    executor_guilty: bool               # executor's copy lost the vote


class DisputeCourt:
    """Escalation path: one disputed round pays the paper's full M-way
    redundancy vote to settle what the trusted outputs are."""

    def __init__(self, num_edges: int):
        self.num_edges = num_edges
        self.cases: List[Verdict] = []

    def escalate(self, round_id: int, published: np.ndarray,
                 executor: int, active: Optional[np.ndarray] = None) -> Verdict:
        """``published``: (N, M, B, C) — every edge's copy of every
        expert's result, exactly the redundancy-mechanism input (paper
        Step 3).  The majority vote is the verdict; the executor is
        guilty iff its copy disagrees with the accepted majority for any
        expert."""
        act = (np.ones(self.num_edges, np.float32) if active is None
               else np.asarray(active, np.float32))
        trusted, support, flags = (np.asarray(r) for r in
                                   kref.redundancy_vote_masked_ref(
                                       published, act))
        guilty = bool((flags[:, executor] == 0).any())
        verdict = Verdict(round_id=round_id, trusted=trusted,
                          support=support, flags=flags,
                          executor_guilty=guilty)
        self.cases.append(verdict)
        return verdict
