"""Batched per-tick session commitments for the serving engine.

The fixed-slot engine appended one commitment leaf *per active stream
per tick* — O(batch) on-chain appends per tick.  Continuous batching
amortizes that to **one Merkle append per batch tick**: every token the
engine emits in a tick becomes a leaf of a single tick tree (slot
order), only that tree's 32-byte root is appended to the engine's tick
log (the on-chain object), and each session keeps a compact *inclusion
reference* — the tick root plus the leaf's Merkle path — derived from
the same tree.

Per-session leaf digests are unchanged (``leaf_digest`` over the
``(request_id, tick, token)`` record), so the per-session Merkle root a
session seals with — and every ``audit_session`` verdict built on it —
is bit-identical to the per-stream commitment scheme on the same trace.
The tick tree adds a second, independent check: a sampled leaf must
*also* prove membership in the tick root committed when the token was
served, so a post-hoc rewrite of a session's leaf list is caught even
if the per-session root is recomputed consistently.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.trust.commitments import MerklePath, MerkleTree


@dataclasses.dataclass(frozen=True)
class SessionLeafRef:
    """One emitted token's inclusion reference: the batch tick it was
    served in, the tick tree's root (the on-chain append), and the
    Merkle path proving the session's leaf digest sits in that tree."""
    tick: int
    root: str
    path: MerklePath

    def verify(self, leaf: str) -> bool:
        return MerkleTree.verify(self.root, leaf, self.path)


@dataclasses.dataclass(frozen=True)
class TickCommitment:
    """What one batch tick appends on-chain: a single root over every
    token emitted that tick (slot order), plus which sessions it binds.

    ``kv_root`` is a side-band commitment over the KV-block manifest
    roots the engine sealed since the previous append (KV paging on;
    ``""`` otherwise).  It rides the same on-chain object but is NOT
    folded into the token ``root`` — token streams and their audit
    verdicts stay bit-identical with paging on or off."""
    tick: int
    root: str
    request_ids: Tuple[int, ...]
    kv_root: str = ""

    @property
    def num_leaves(self) -> int:
        return len(self.request_ids)


def commit_tick(tick: int, entries: Sequence[Tuple[int, str]],
                kv_roots: Sequence[str] = ()
                ) -> Tuple[TickCommitment, Dict[int, SessionLeafRef]]:
    """Build the batch-tick commitment.

    ``entries``: the tick's emissions in slot order, ``(request_id,
    leaf_digest)`` — one per stream that produced a token this tick (a
    stream emits at most one token per tick, so request ids are unique
    within an entry list).  ``kv_roots``: manifest roots of the KV
    blocks sealed since the last append, committed under one Merkle
    root in ``kv_root`` (prefill ticks can seal without emitting, so
    the engine carries pending roots to the next commit).  Returns the
    tick commitment (one on-chain append for the whole batch) and each
    session's inclusion reference into it."""
    if not entries:
        raise ValueError("commit_tick needs at least one emission")
    rids = [rid for rid, _ in entries]
    if len(set(rids)) != len(rids):
        raise ValueError(f"duplicate request ids in tick {tick}: {rids}")
    tree = MerkleTree([leaf for _, leaf in entries])
    refs = {rid: SessionLeafRef(tick=tick, root=tree.root,
                                path=tree.prove(i))
            for i, (rid, _) in enumerate(entries)}
    kv_root = MerkleTree(list(kv_roots)).root if kv_roots else ""
    return TickCommitment(tick=tick, root=tree.root,
                          request_ids=tuple(rids), kv_root=kv_root), refs


def verify_session_inclusion(leaves: Sequence[str],
                             refs: Sequence[SessionLeafRef],
                             indices: Sequence[int]) -> List[int]:
    """Check sampled session leaves against their committed tick roots.

    Returns the sampled indices whose *current* leaf digest fails its
    inclusion proof — i.e. the session's leaf list no longer matches
    what the engine batch-committed when the token was served."""
    if len(leaves) != len(refs):
        raise ValueError(f"{len(leaves)} leaves but {len(refs)} refs")
    return [i for i in indices if not refs[i].verify(leaves[i])]
