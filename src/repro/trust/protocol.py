"""The optimistic round state machine, pipelined.

One round of optimistically-verified execution moves through:

    COMMITTED  -- executor publishes outputs + Merkle root (on-chain)
        |
    ACCEPTED   -- the system uses the result immediately (optimistic)
        |                         ... async challenge window (in rounds) ...
        +--> FINALIZED            no confirmed fraud inside the window
        +--> CHALLENGED           a fraud proof was raised
        |        +--> ROLLED_BACK  court confirms: slash + undo the round
        |        +--> ACCEPTED     court clears: griefing attempt rejected
        |                          (finalizes at its deadline, in order)
        +--> INVALIDATED          an *ancestor* round was rolled back: this
                                  round's commitment was built on revoked
                                  state, so it is void (no slash — the
                                  executor computed honestly on the state
                                  it was handed)

The window is truly asynchronous: the host keeps committing rounds
r+1..r+w while round r's audit sits in a deadline-ordered queue
(``schedule_audit`` / ``drain_audits``), so verification is off the
critical path.  Finality is *sequential*: ``advance`` closes windows in
deadline order and stops at the first unresolved (CHALLENGED) round —
a round can never finalize while an ancestor it built on is still in
dispute.  When a fraud proof is confirmed for round r after descendants
have committed, ``resolve`` rolls back the whole chain: round r is
ROLLED_BACK (exactly one slash), every ACCEPTED descendant is
INVALIDATED (CHALLENGED descendants keep their own court date — fraud
is punished per round), and the host restores its pre-r snapshot and
re-executes (see ``BMoESystem``).

The protocol object owns the verifier pool, the stake book, and the
dispute court; the host system (``BMoESystem``, ``ServingEngine``)
supplies the recompute function and applies rollbacks, keeping the trust
layer independent of what is being verified.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.reputation import ReputationLedger
from repro.obs.metrics import CounterGroup, MetricsRegistry
from repro.trust.audit import (AuditReport, BatchRecomputeFn, FraudProof,
                               RecomputeFn, VerifierPool, verify_fraud_proof)
from repro.trust.commitments import (RoundCommitment, commit_outputs,
                                     leaf_digest)
from repro.trust.slashing import (DisputeCourt, StakeBook, Verdict,
                                  reputation_fraud_update)


@dataclasses.dataclass(frozen=True)
class TrustConfig:
    """Knobs of the commit-challenge-audit protocol."""
    audit_rate: float = 0.1            # total fraction of leaves audited
    #                                    (split across the verifier pool)
    num_verifiers: int = 3             # independent auditors per round
    chunks_per_expert: int = 4         # Merkle leaves per expert output
    challenge_window: int = 2          # rounds before finalization
    stake: float = 1.0                 # executor deposit
    slash_fraction: float = 0.5        # stake burned per confirmed fraud
    bounty_fraction: float = 0.5       # slashed amount paid to reporter
    min_stake: float = 0.25            # bond needed to execute
    lazy_verifier_prob: float = 0.0    # P[a verifier rubber-stamps]
    # stake-weighted verifier lottery (None: uniform split, the legacy
    # streams): verifier v samples each leaf with probability
    # audit_rate * stake_v / sum(stakes) — pool-wide rate conserved
    verifier_stakes: Optional[Tuple[float, ...]] = None
    # second-layer audit of the auditors: spot-check each verifier's
    # salted recompute attestations at this per-leaf rate; mismatches
    # (rubber-stampers) burn verifier_slash_fraction of their stake
    reaudit_rate: float = 0.0
    verifier_slash_fraction: float = 0.5
    audit_backend: str = "batched"     # batched (one grouped recompute
    #                                    call/round) | eager (reference
    #                                    oracle: one dispatch per leaf)
    scheduling: str = "pipelined"      # pipelined (audits drain off the
    #                                    critical path at window deadlines,
    #                                    chained rollback on late fraud)
    #                                  | synchronous (audit in the commit
    #                                    round — the pre-pipeline oracle)
    seed: int = 0


class RoundPhase(enum.Enum):
    COMMITTED = "committed"
    ACCEPTED = "accepted"
    CHALLENGED = "challenged"
    FINALIZED = "finalized"
    ROLLED_BACK = "rolled_back"
    INVALIDATED = "invalidated"


# phases only move forward through this partial order.  The two open
# phases share a rank — a court acquittal legitimately returns a
# CHALLENGED round to ACCEPTED (griefing rejected) and a fresh challenge
# can re-open it; the three terminal phases share a rank and a terminal
# round never transitions again.
PHASE_RANK = {RoundPhase.COMMITTED: 0, RoundPhase.ACCEPTED: 1,
              RoundPhase.CHALLENGED: 1, RoundPhase.FINALIZED: 2,
              RoundPhase.ROLLED_BACK: 2, RoundPhase.INVALIDATED: 2}

TERMINAL_PHASES = frozenset({RoundPhase.FINALIZED, RoundPhase.ROLLED_BACK,
                             RoundPhase.INVALIDATED})


@dataclasses.dataclass
class RoundState:
    round_id: int
    executor: int
    commitment: RoundCommitment
    phase: RoundPhase
    deadline: int                          # round id after which finalized
    reports: List[AuditReport] = dataclasses.field(default_factory=list)
    proofs: List[FraudProof] = dataclasses.field(default_factory=list)
    verdict: Optional[Verdict] = None
    # set when an ancestor was rolled back while this round was in
    # dispute: even a court acquittal cannot finalize it — the state it
    # was built on is gone (it invalidates instead)
    tainted: bool = False


@dataclasses.dataclass
class RollbackRecord:
    """One confirmed-fraud rollback: the convicted round plus the chain of
    optimistic descendants its conviction voided."""
    round_id: int
    executor: int
    invalidated: List[int]                 # ACCEPTED descendants voided
    at_clock: int


@dataclasses.dataclass
class AuditJob:
    """A queued (deferred) audit for one committed round."""
    round_id: int
    deadline: int
    recompute_fn: RecomputeFn
    batch_recompute_fn: Optional[BatchRecomputeFn] = None


class OptimisticProtocol:
    """Commit -> optimistic accept -> async challenge window ->
    finalize/rollback, over any per-round (N, B, C) output tensor.

    All bookkeeping that scales with history is heap-based: ``advance``
    and ``pending`` touch only open rounds (plus lazily-discarded stale
    heap entries), never the full ``rounds`` dict — O(open) per call
    instead of O(all rounds ever committed).
    """

    def __init__(self, cfg: TrustConfig, num_edges: int,
                 reputation: Optional[ReputationLedger] = None,
                 stakes: Optional[StakeBook] = None,
                 court: Optional[DisputeCourt] = None,
                 chained: bool = True,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "trust"):
        self.cfg = cfg
        self.num_edges = num_edges
        self.reputation = reputation
        # chained=True: round r+1 builds on round r's optimistic state
        # (training), so a conviction voids descendants and an open
        # dispute blocks later finality.  chained=False: rounds are
        # independent (batch inference against frozen weights) — a
        # conviction revokes only its own round.
        self.chained = chained
        # cfg.audit_rate is the pool-wide sampled fraction; each verifier
        # draws its share (stake-weighted when verifier_stakes is set) so
        # total recompute stays at audit_rate
        self.verifiers = VerifierPool(
            cfg.num_verifiers, cfg.audit_rate / max(cfg.num_verifiers, 1),
            cfg.lazy_verifier_prob, cfg.seed,
            stakes=cfg.verifier_stakes, reaudit_rate=cfg.reaudit_rate,
            verifier_slash_fraction=cfg.verifier_slash_fraction,
            metrics=metrics, namespace=f"{namespace}.verifiers")
        # stakes/court may be shared with a sibling protocol instance (the
        # host's inference pipeline shares the training pipeline's bonds,
        # so one edge's deposit backs both workloads)
        self.stakes = stakes if stakes is not None else StakeBook(
            num_edges, cfg.stake, cfg.slash_fraction,
            cfg.bounty_fraction, cfg.min_stake)
        self.court = court if court is not None else DisputeCourt(num_edges)
        self.rounds: Dict[int, RoundState] = {}
        self.clock = 0                     # latest round id seen
        # min-heaps keyed by deadline; entries for rounds that left the
        # ACCEPTED/queued state are discarded lazily on pop
        self._open_heap: List[Tuple[int, int]] = []      # (deadline, rid)
        self._audit_heap: List[Tuple[int, int]] = []     # (deadline, rid)
        self._audit_jobs: Dict[int, AuditJob] = {}
        self.rollbacks: List[RollbackRecord] = []
        # phase-transition counters: with a registry these are the live
        # metrics {namespace}.{committed,finalized,rolled_back,...} the
        # obs layer reads (the host passes "trust.train"/"trust.infer"
        # so sibling protocols never collide on metric names)
        self._metrics = metrics
        self._namespace = namespace
        self.stats = CounterGroup(
            {"committed": 0, "finalized": 0, "rolled_back": 0,
             "invalidated": 0, "audited_leaves": 0,
             "fraud_proofs": 0, "escalations": 0,
             "audit_drains": 0},
            metrics, namespace)

    # -------------------------------------------------------- executors
    def pick_executor(self, round_id: int) -> int:
        """Rotate over bonded, non-excluded edges."""
        eligible = [e for e in self.stakes.bonded_edges()
                    if self.reputation is None
                    or not self.reputation.excluded[e]]
        if not eligible:                   # everyone slashed out: reset to 0
            eligible = list(range(self.num_edges))
        return eligible[round_id % len(eligible)]

    # ------------------------------------------------------------ commit
    def commit(self, round_id: int, executor: int, outputs,
               task_digest: str = "", row_index=None,
               num_shards: int = 1) -> RoundState:
        commitment = commit_outputs(
            outputs, round_id=round_id, executor=executor,
            chunks_per_expert=self.cfg.chunks_per_expert,
            task_digest=task_digest, row_index=row_index,
            num_shards=num_shards)
        state = RoundState(round_id=round_id, executor=executor,
                           commitment=commitment, phase=RoundPhase.ACCEPTED,
                           deadline=round_id + self.cfg.challenge_window)
        self.rounds[round_id] = state
        heapq.heappush(self._open_heap, (state.deadline, round_id))
        self.clock = max(self.clock, round_id)
        self.stats["committed"] += 1
        return state

    # ------------------------------------------------------- audit queue
    def schedule_audit(self, round_id: int, recompute_fn: RecomputeFn,
                       batch_recompute_fn: Optional[BatchRecomputeFn] = None
                       ) -> None:
        """Queue round ``round_id``'s audit to run off the critical path
        (any time before its finalization deadline).  The recompute
        closures must capture the round's *snapshot* (the state the
        executor was handed), not the host's live state."""
        state = self.rounds[round_id]
        self._audit_jobs[round_id] = AuditJob(
            round_id=round_id, deadline=state.deadline,
            recompute_fn=recompute_fn,
            batch_recompute_fn=batch_recompute_fn)
        heapq.heappush(self._audit_heap, (state.deadline, round_id))

    def audit_backlog(self) -> List[int]:
        """Queued-but-unaudited rounds, deadline-ordered."""
        return [rid for _, rid in sorted(self._audit_heap)
                if rid in self._audit_jobs]

    def pop_audit_jobs(self, now: Optional[int] = None) -> List[AuditJob]:
        """Claim the audit backlog for a drain.

        Returns ``[]`` unless some queued job is due (deadline <= now) —
        audits stay parked off the critical path until a window is about
        to close.  Once ANY job is due the ENTIRE backlog is handed out,
        deadline-ordered: a drain batches every queued round into one
        grouped recompute (the cross-round analogue of PR 2's in-round
        batching).  ``now=None`` forces a full flush.
        """
        if not self._audit_jobs:
            return []
        if now is not None:
            due = [dl for dl, rid in self._audit_heap
                   if rid in self._audit_jobs and dl <= now]
            if not due:
                return []
        jobs: List[AuditJob] = []
        while self._audit_heap:
            _, rid = heapq.heappop(self._audit_heap)
            job = self._audit_jobs.pop(rid, None)
            if job is not None:
                jobs.append(job)
        if jobs:
            self.stats["audit_drains"] += 1
            if self._metrics is not None:
                # audit-burst size: how many windowed rounds one drain
                # hands to the verifier pool at once
                self._metrics.histogram(
                    f"{self._namespace}.audit_burst_rounds",
                    buckets=(1, 2, 4, 8, 16, 32, 64, 128)
                ).observe(len(jobs))
        return jobs

    def drain_audits(self, now: Optional[int] = None
                     ) -> Dict[int, List[FraudProof]]:
        """Run every queued audit that ``pop_audit_jobs`` releases, one
        round at a time (hosts with a cross-round batched recompute — see
        ``BMoESystem`` — pop the jobs themselves and merge the work).
        Returns the confirmed proofs per drained round."""
        out: Dict[int, List[FraudProof]] = {}
        for job in self.pop_audit_jobs(now):
            out[job.round_id] = self.run_audits(
                job.round_id, job.recompute_fn, job.batch_recompute_fn)
        return out

    # ------------------------------------------------------------- audit
    def run_audits(self, round_id: int, recompute_fn: RecomputeFn,
                   batch_recompute_fn: Optional[BatchRecomputeFn] = None
                   ) -> List[FraudProof]:
        """All verifiers audit the round; raised proofs are court-checked
        against the committed root before they count (so a lying verifier
        cannot grief with a fabricated proof).

        With ``batch_recompute_fn`` the pool audits through the batched
        planner (``VerifierPool.audit_batched``): one grouped recompute
        call for the whole round, deduped across verifiers.  The eager
        ``recompute_fn`` is still used by the court to confirm raised
        proofs — an independent recompute on the (rare) fraud path.
        """
        state = self.rounds[round_id]
        if state.phase is not RoundPhase.ACCEPTED:
            return []                  # window already closed or resolved
        if batch_recompute_fn is not None:
            reports = self.verifiers.audit_batched(state.commitment,
                                                   batch_recompute_fn)
        else:
            reports = self.verifiers.audit(state.commitment, recompute_fn)
        return self.apply_reports(round_id, reports, recompute_fn)

    def apply_reports(self, round_id: int, reports: List[AuditReport],
                      recompute_fn: RecomputeFn) -> List[FraudProof]:
        """Record a set of verifier reports for a round and court-check
        any raised proofs (the shared tail of ``run_audits``; hosts that
        batch audits across rounds call this per round afterwards)."""
        state = self.rounds[round_id]
        if state.phase is not RoundPhase.ACCEPTED:
            return []
        state.reports.extend(reports)
        confirmed: List[FraudProof] = []
        for rep in reports:
            self.stats["audited_leaves"] += rep.recomputed_leaves
            for proof in rep.fraud_proofs:
                e, _, sl = state.commitment.leaf_coords(proof.leaf_index)
                if verify_fraud_proof(state.commitment.root, proof,
                                      recompute_fn, sl):
                    confirmed.append(proof)
        # second-layer lottery: spot-check the verifiers' own recompute
        # attestations and slash rubber-stampers out of future lotteries
        self.verifiers.reaudit(state.commitment, reports, recompute_fn)
        if confirmed:
            state.phase = RoundPhase.CHALLENGED
            state.proofs.extend(confirmed)
            self.stats["fraud_proofs"] += len(confirmed)
        return confirmed

    # --------------------------------------------------------- challenge
    def resolve(self, round_id: int, verdict: Verdict) -> RoundState:
        """Court outcome for a challenged round.

        Guilty: slash + reputation + ROLLED_BACK, and every ACCEPTED
        descendant — a round committed on top of the revoked state — is
        INVALIDATED in the same stroke (no slash: those executors
        computed honestly on the state they were handed).  CHALLENGED
        descendants are left for their own court date, so per-round fraud
        is always punished exactly once.  The chain is recorded in
        ``rollbacks`` for the host to restore snapshots / re-execute.

        Innocent (griefing attempt rejected): the round returns to
        ACCEPTED and finalizes at its deadline through ``advance``, in
        deadline order — never out of turn.  If an ancestor was rolled
        back while this round was in dispute (``tainted``), acquittal
        still INVALIDATES it: its commitment stands on revoked state.
        """
        state = self.rounds[round_id]
        state.verdict = verdict
        self.stats["escalations"] += 1
        if verdict.executor_guilty:
            # one slash per convicted round (proofs for further leaves of
            # the same commitment are the same offense)
            self.stakes.slash(state.proofs[0])
            reputation_fraud_update(self.reputation, state.executor,
                                    self.num_edges)
            state.phase = RoundPhase.ROLLED_BACK
            self.stats["rolled_back"] += 1
            invalidated = (self._invalidate_descendants(round_id)
                           if self.chained else [])
            self.rollbacks.append(RollbackRecord(
                round_id=round_id, executor=state.executor,
                invalidated=invalidated, at_clock=self.clock))
            if self._metrics is not None:
                # chain length of the rollback: the convicted round plus
                # every optimistic descendant it voided
                self._metrics.histogram(
                    f"{self._namespace}.rollback_chain_rounds",
                    buckets=(1, 2, 4, 8, 16, 32, 64, 128)
                ).observe(1 + len(invalidated))
        elif state.tainted:
            state.phase = RoundPhase.INVALIDATED
            self.stats["invalidated"] += 1
        else:
            state.phase = RoundPhase.ACCEPTED
        return state

    def resolve_by_recompute(self, round_id: int,
                             recompute_fn: RecomputeFn) -> RoundState:
        """Court for hosts whose committed computation has no M-way
        redundancy matrix to vote over (federated aggregation: each delta
        is published once, not recomputed by M edges).  The court settles
        the dispute by recomputing EVERY leaf of the challenged
        commitment from the committed inputs — the executor is guilty iff
        any recomputed leaf digest differs from the committed one, and
        the verdict's trusted tensor is the full honest recompute.  Costs
        O(one honest execution) instead of O(M); same ``resolve`` tail
        (slash, chained rollback, sequential finality)."""
        state = self.rounds[round_id]
        com = state.commitment
        trusted = np.array(com.claimed, copy=True)
        guilty = False
        for leaf in range(com.num_leaves):
            e, _, sl = com.leaf_coords(leaf)
            chunk = np.asarray(recompute_fn(e, sl))
            trusted[e, sl] = chunk
            if leaf_digest(chunk) != com.leaf_digests[leaf]:
                guilty = True
        flags = np.ones((com.num_experts, self.num_edges), np.int32)
        if guilty:
            flags[:, state.executor] = 0
        verdict = Verdict(round_id=round_id, trusted=trusted,
                          support=np.full(com.num_experts,
                                          float(self.num_edges)),
                          flags=flags, executor_guilty=guilty)
        self.court.cases.append(verdict)
        return self.resolve(round_id, verdict)

    def _invalidate_descendants(self, round_id: int) -> List[int]:
        """Void every ACCEPTED round built (transitively) on ``round_id``:
        with sequential finality nothing after a rolled-back round can
        have finalized, so the open heap holds the whole chain.
        CHALLENGED descendants are only *tainted* — their own court still
        rules (guilty: slashed; innocent: invalidated anyway)."""
        invalidated = []
        for _, rid in sorted(self._open_heap):
            if rid <= round_id:
                continue
            state = self.rounds[rid]
            if state.phase is RoundPhase.ACCEPTED:
                state.phase = RoundPhase.INVALIDATED
                self.stats["invalidated"] += 1
                # its audit (if still queued) is moot: the commitment is
                # void with its ancestor, not fraud by this executor
                self._audit_jobs.pop(rid, None)
                invalidated.append(rid)
            elif state.phase is RoundPhase.CHALLENGED:
                state.tainted = True
        return invalidated

    # ---------------------------------------------------------- finalize
    def advance(self, now: int) -> List[int]:
        """Close challenge windows in deadline order: every ACCEPTED round
        whose deadline passed becomes FINALIZED — but never past an
        unresolved CHALLENGED round.  Finality is sequential: a round
        built on a disputed ancestor waits for the dispute (and is
        invalidated with it if the ancestor is convicted)."""
        self.clock = max(self.clock, now)
        done = []
        requeue = []
        while self._open_heap:
            deadline, rid = self._open_heap[0]
            if deadline > now:
                break
            state = self.rounds[rid]
            if state.phase is RoundPhase.CHALLENGED:
                if self.chained:
                    break                  # dispute blocks all successors
                heapq.heappop(self._open_heap)
                requeue.append((deadline, rid))   # awaits its own court
                continue
            heapq.heappop(self._open_heap)
            if state.phase is RoundPhase.ACCEPTED:
                state.phase = RoundPhase.FINALIZED
                self.stats["finalized"] += 1
                done.append(rid)
            # terminal phases (resolved/invalidated): stale entry, drop
        for entry in requeue:
            heapq.heappush(self._open_heap, entry)
        return done

    def pending(self) -> List[int]:
        """Open rounds (ACCEPTED or awaiting court), deadline-ordered.
        Touches only the open heap — O(open), not O(history)."""
        return [rid for _, rid in sorted(self._open_heap)
                if self.rounds[rid].phase in (RoundPhase.ACCEPTED,
                                              RoundPhase.CHALLENGED)]


class ChallengeWindow:
    """Minimal tick-based finalization tracker for streaming hosts (the
    serving engine): items become final ``window`` ticks after entry
    unless revoked.  ``enter`` on an already-pending item refreshes its
    deadline; ``revoke`` after expiry is a no-op (final is final)."""

    def __init__(self, window: int):
        self.window = int(window)
        self._pending: Dict[int, int] = {}      # item id -> deadline tick
        self.revoked: List[int] = []

    def enter(self, item_id: int, now: int) -> None:
        self._pending[item_id] = now + self.window

    def revoke(self, item_id: int) -> None:
        if item_id in self._pending:
            del self._pending[item_id]
            self.revoked.append(item_id)

    def expire(self, now: int) -> List[int]:
        done = [i for i, dl in self._pending.items() if now >= dl]
        for i in done:
            del self._pending[i]
        return done

    def hold(self, item_id: int, deadline: int) -> None:
        """Re-park an expired-but-blocked item with an explicit deadline
        (the host's sequential-finality deferral)."""
        self._pending[item_id] = int(deadline)

    def deadline(self, item_id: int) -> Optional[int]:
        return self._pending.get(item_id)

    def __len__(self) -> int:
        return len(self._pending)
