"""The optimistic round state machine.

One round of optimistically-verified execution moves through:

    COMMITTED  -- executor publishes outputs + Merkle root (on-chain)
        |
    ACCEPTED   -- the system uses the result immediately (optimistic)
        |                         ... async challenge window (in rounds) ...
        +--> FINALIZED            no confirmed fraud inside the window
        +--> CHALLENGED           a fraud proof was raised
                 +--> ROLLED_BACK  court confirms: slash + undo the round
                 +--> FINALIZED    court clears: griefing attempt rejected

The protocol object owns the verifier pool, the stake book, and the
dispute court; the host system (``BMoESystem``, ``ServingEngine``)
supplies the recompute function and applies rollbacks, keeping the trust
layer independent of what is being verified.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

from repro.core.reputation import ReputationLedger
from repro.trust.audit import (AuditReport, BatchRecomputeFn, FraudProof,
                               RecomputeFn, VerifierPool, verify_fraud_proof)
from repro.trust.commitments import RoundCommitment, commit_outputs
from repro.trust.slashing import (DisputeCourt, StakeBook, Verdict,
                                  reputation_fraud_update)


@dataclasses.dataclass(frozen=True)
class TrustConfig:
    """Knobs of the commit-challenge-audit protocol."""
    audit_rate: float = 0.1            # total fraction of leaves audited
    #                                    (split across the verifier pool)
    num_verifiers: int = 3             # independent auditors per round
    chunks_per_expert: int = 4         # Merkle leaves per expert output
    challenge_window: int = 2          # rounds before finalization
    stake: float = 1.0                 # executor deposit
    slash_fraction: float = 0.5        # stake burned per confirmed fraud
    bounty_fraction: float = 0.5       # slashed amount paid to reporter
    min_stake: float = 0.25            # bond needed to execute
    lazy_verifier_prob: float = 0.0    # P[a verifier rubber-stamps]
    audit_backend: str = "batched"     # batched (one grouped recompute
    #                                    call/round) | eager (reference
    #                                    oracle: one dispatch per leaf)
    seed: int = 0


class RoundPhase(enum.Enum):
    COMMITTED = "committed"
    ACCEPTED = "accepted"
    CHALLENGED = "challenged"
    FINALIZED = "finalized"
    ROLLED_BACK = "rolled_back"


@dataclasses.dataclass
class RoundState:
    round_id: int
    executor: int
    commitment: RoundCommitment
    phase: RoundPhase
    deadline: int                          # round id after which finalized
    reports: List[AuditReport] = dataclasses.field(default_factory=list)
    proofs: List[FraudProof] = dataclasses.field(default_factory=list)
    verdict: Optional[Verdict] = None


class OptimisticProtocol:
    """Commit -> optimistic accept -> async challenge window ->
    finalize/rollback, over any per-round (N, B, C) output tensor."""

    def __init__(self, cfg: TrustConfig, num_edges: int,
                 reputation: Optional[ReputationLedger] = None):
        self.cfg = cfg
        self.num_edges = num_edges
        self.reputation = reputation
        # cfg.audit_rate is the pool-wide sampled fraction; each verifier
        # draws its share so total recompute stays at audit_rate
        self.verifiers = VerifierPool(
            cfg.num_verifiers, cfg.audit_rate / max(cfg.num_verifiers, 1),
            cfg.lazy_verifier_prob, cfg.seed)
        self.stakes = StakeBook(num_edges, cfg.stake, cfg.slash_fraction,
                                cfg.bounty_fraction, cfg.min_stake)
        self.court = DisputeCourt(num_edges)
        self.rounds: Dict[int, RoundState] = {}
        self.clock = 0                     # latest round id seen
        self.stats = {"committed": 0, "finalized": 0, "rolled_back": 0,
                      "audited_leaves": 0, "fraud_proofs": 0,
                      "escalations": 0}

    # -------------------------------------------------------- executors
    def pick_executor(self, round_id: int) -> int:
        """Rotate over bonded, non-excluded edges."""
        eligible = [e for e in self.stakes.bonded_edges()
                    if self.reputation is None
                    or not self.reputation.excluded[e]]
        if not eligible:                   # everyone slashed out: reset to 0
            eligible = list(range(self.num_edges))
        return eligible[round_id % len(eligible)]

    # ------------------------------------------------------------ commit
    def commit(self, round_id: int, executor: int, outputs,
               task_digest: str = "") -> RoundState:
        commitment = commit_outputs(
            outputs, round_id=round_id, executor=executor,
            chunks_per_expert=self.cfg.chunks_per_expert,
            task_digest=task_digest)
        state = RoundState(round_id=round_id, executor=executor,
                           commitment=commitment, phase=RoundPhase.ACCEPTED,
                           deadline=round_id + self.cfg.challenge_window)
        self.rounds[round_id] = state
        self.clock = max(self.clock, round_id)
        self.stats["committed"] += 1
        return state

    # ------------------------------------------------------------- audit
    def run_audits(self, round_id: int, recompute_fn: RecomputeFn,
                   batch_recompute_fn: Optional[BatchRecomputeFn] = None
                   ) -> List[FraudProof]:
        """All verifiers audit the round; raised proofs are court-checked
        against the committed root before they count (so a lying verifier
        cannot grief with a fabricated proof).

        With ``batch_recompute_fn`` the pool audits through the batched
        planner (``VerifierPool.audit_batched``): one grouped recompute
        call for the whole round, deduped across verifiers.  The eager
        ``recompute_fn`` is still used by the court to confirm raised
        proofs — an independent recompute on the (rare) fraud path.
        """
        state = self.rounds[round_id]
        if state.phase is not RoundPhase.ACCEPTED:
            return []                  # window already closed or resolved
        if batch_recompute_fn is not None:
            reports = self.verifiers.audit_batched(state.commitment,
                                                   batch_recompute_fn)
        else:
            reports = self.verifiers.audit(state.commitment, recompute_fn)
        state.reports.extend(reports)
        confirmed: List[FraudProof] = []
        for rep in reports:
            self.stats["audited_leaves"] += rep.recomputed_leaves
            for proof in rep.fraud_proofs:
                e, _, sl = state.commitment.leaf_coords(proof.leaf_index)
                if verify_fraud_proof(state.commitment.root, proof,
                                      recompute_fn, sl):
                    confirmed.append(proof)
        if confirmed:
            state.phase = RoundPhase.CHALLENGED
            state.proofs.extend(confirmed)
            self.stats["fraud_proofs"] += len(confirmed)
        return confirmed

    # --------------------------------------------------------- challenge
    def resolve(self, round_id: int, verdict: Verdict) -> RoundState:
        """Court outcome for a challenged round: rollback if the executor
        is guilty (slash + reputation), else finalize (griefing case)."""
        state = self.rounds[round_id]
        state.verdict = verdict
        self.stats["escalations"] += 1
        if verdict.executor_guilty:
            # one slash per convicted round (proofs for further leaves of
            # the same commitment are the same offense)
            self.stakes.slash(state.proofs[0])
            reputation_fraud_update(self.reputation, state.executor,
                                    self.num_edges)
            state.phase = RoundPhase.ROLLED_BACK
            self.stats["rolled_back"] += 1
        else:
            state.phase = RoundPhase.FINALIZED
            self.stats["finalized"] += 1
        return state

    # ---------------------------------------------------------- finalize
    def advance(self, now: int) -> List[int]:
        """Close challenge windows: every ACCEPTED round whose deadline
        passed without a confirmed fraud proof becomes FINALIZED."""
        self.clock = max(self.clock, now)
        done = []
        for rid, state in self.rounds.items():
            if state.phase is RoundPhase.ACCEPTED and now >= state.deadline:
                state.phase = RoundPhase.FINALIZED
                self.stats["finalized"] += 1
                done.append(rid)
        return done

    def pending(self) -> List[int]:
        return [rid for rid, s in self.rounds.items()
                if s.phase is RoundPhase.ACCEPTED]


class ChallengeWindow:
    """Minimal tick-based finalization tracker for streaming hosts (the
    serving engine): items become final ``window`` ticks after entry
    unless revoked."""

    def __init__(self, window: int):
        self.window = int(window)
        self._pending: Dict[int, int] = {}      # item id -> deadline tick
        self.revoked: List[int] = []

    def enter(self, item_id: int, now: int) -> None:
        self._pending[item_id] = now + self.window

    def revoke(self, item_id: int) -> None:
        if item_id in self._pending:
            del self._pending[item_id]
            self.revoked.append(item_id)

    def expire(self, now: int) -> List[int]:
        done = [i for i, dl in self._pending.items() if now >= dl]
        for i in done:
            del self._pending[i]
        return done

    def __len__(self) -> int:
        return len(self._pending)
