"""Data-availability challenges over the chunked storage layer.

The optimistic protocol is only sound while the data behind a round's
commitments stays *retrievable*: auditors must be able to fetch the
committed expert versions (by the manifest root recorded on-chain) for
the whole challenge window.  A storage node that accepted a replica and
then cannot produce a committed chunk is therefore a protocol fault in
its own right — distinct from executor fraud — and is slashed out of its
*storage* stake through the same ``StakeBook`` machinery the executor
bonds use.

Per round the ``DataAvailabilityAuditor`` samples committed chunks (rate
per chunk, seeded by round id — deterministic, unpredictable without the
seed, like the verifier lottery) and challenges every replica node
committed to each sampled chunk to produce its bytes:

- bytes produced, hash matches the CID       -> challenge satisfied;
- bytes produced, hash mismatch (corruption) -> self-evident fault: the
  node is slashed immediately, and a *verified refetch* from a healthy
  replica repairs its copy (availability restored);
- bytes not produced (withheld)              -> an OPEN challenge with a
  deadline one challenge window away; a node that still cannot produce
  the chunk when the window closes is slashed (``resolve``), while one
  that recovers in time satisfies the challenge late (transient
  unavailability is not punished).

Hosts mine the resulting slash events into the ledger (``BMoESystem``
emits one ``kind="da_slash"`` block per conviction).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.ledger import digest_bytes
from repro.obs.metrics import CounterGroup, MetricsRegistry
from repro.storage.chunks import ChunkManifest
from repro.storage.network import StorageNetwork
from repro.trust.slashing import StakeBook


@dataclasses.dataclass(frozen=True)
class DAFault:
    """A confirmed data-availability fault, shaped for StakeBook.slash
    (``executor`` is the guilty *storage node*; ``verifier`` the
    challenger credited with the bounty)."""
    round_id: int
    executor: int                       # storage node id
    verifier: int
    object_id: str
    chunk_index: int
    cid: str
    kind: str                           # "withheld" | "corrupted"


@dataclasses.dataclass
class DAChallenge:
    """One (chunk, node) availability challenge."""
    challenge_id: int
    round_id: int
    object_id: str
    chunk_index: int
    cid: str
    node_id: int
    deadline: int
    status: str = "open"                # open | satisfied | slashed
    kind: str = "withheld"


class DataAvailabilityAuditor:
    """Samples committed chunks per round and holds replica nodes to
    their storage commitments (see module docstring)."""

    def __init__(self, network: StorageNetwork, num_nodes: int,
                 window: int = 2, sample_rate: float = 0.05, seed: int = 0,
                 stake: float = 1.0, slash_fraction: float = 0.5,
                 challenger: int = -1,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "trust.da"):
        self.network = network
        self.window = int(window)
        self.sample_rate = float(sample_rate)
        self._seed = seed
        self.challenger = challenger
        self.stakes = StakeBook(num_nodes, stake=stake,
                                slash_fraction=slash_fraction,
                                bounty_fraction=0.0)
        self.challenges: List[DAChallenge] = []
        self.faults: List[DAFault] = []
        self._open: Dict[int, DAChallenge] = {}
        # (cid, node) pairs with an open challenge or a booked slash:
        # one availability fault is punished once, even when chunk dedup
        # makes many manifests reference the same CID (a zero-init bias
        # chunk shared by every expert, say) or many rounds re-sample it
        self._outstanding: set = set()
        self._next_id = 0
        self.stats = CounterGroup(
            {"probed": 0, "satisfied": 0, "opened": 0,
             "slashed": 0, "repaired": 0, "deduped": 0},
            metrics, namespace)

    def _rng(self, round_id: int) -> np.random.Generator:
        return np.random.default_rng((self._seed * 7_368_787 + round_id) * 13)

    # ------------------------------------------------------------ probe
    def _probe(self, round_id: int, object_id: str, index: int, cid: str,
               node_id: int) -> Optional[DAChallenge]:
        if (cid, node_id) in self._outstanding:
            self.stats["deduped"] += 1
            return None
        ch = DAChallenge(challenge_id=self._next_id, round_id=round_id,
                         object_id=object_id, chunk_index=index, cid=cid,
                         node_id=node_id, deadline=round_id + self.window)
        self._next_id += 1
        self.challenges.append(ch)
        self.stats["probed"] += 1
        data = self.network.node(node_id).get(cid)
        if data is None:
            # committed but not produced: the DA-challengeable state —
            # the node has until the window closes to recover
            self._open[ch.challenge_id] = ch
            self._outstanding.add((cid, node_id))
            self.stats["opened"] += 1
            return ch
        if digest_bytes(data) == cid:
            ch.status = "satisfied"
            self.stats["satisfied"] += 1
            return ch
        # corrupted replica: self-evident fault (the produced bytes do
        # not hash to the committed CID) — slash now, then repair the
        # copy by verified refetch from a healthy replica
        self._slash(ch, "corrupted")
        if self.network.repair(cid, node_id):
            self.stats["repaired"] += 1
        return ch

    def challenge_round(self, round_id: int,
                        manifests: Dict[str, ChunkManifest]
                        ) -> List[DAChallenge]:
        """Sample each committed chunk at ``sample_rate`` (seeded by
        round id) and challenge every replica node committed to it."""
        out: List[DAChallenge] = []
        rng = self._rng(round_id)
        for object_id in sorted(manifests):
            man = manifests[object_id]
            coins = rng.random(man.num_chunks)
            for i, cid in enumerate(man.chunk_cids):
                if coins[i] >= self.sample_rate:
                    continue
                for node_id in self.network.replicas(cid):
                    ch = self._probe(round_id, object_id, i, cid, node_id)
                    if ch is not None:
                        out.append(ch)
        return out

    # ---------------------------------------------------------- resolve
    def resolve(self, now: Optional[int] = None) -> List[DAChallenge]:
        """Close every open challenge whose deadline passed (``now=None``
        closes all): a node that can produce the committed bytes by the
        deadline satisfies late; one that still cannot is slashed."""
        resolved: List[DAChallenge] = []
        for ch in sorted(self._open.values(),
                         key=lambda c: (c.deadline, c.challenge_id)):
            if now is not None and ch.deadline > now:
                continue
            del self._open[ch.challenge_id]
            try:
                data = self.network.node(ch.node_id).get(ch.cid)
            except KeyError:
                data = None              # node left the network: withheld
            if data is not None and digest_bytes(data) == ch.cid:
                ch.status = "satisfied"
                self.stats["satisfied"] += 1
                # recovered: the pair may be challenged afresh later
                self._outstanding.discard((ch.cid, ch.node_id))
            else:
                self._slash(ch, "withheld")
            resolved.append(ch)
        return resolved

    def pending(self) -> List[DAChallenge]:
        return sorted(self._open.values(),
                      key=lambda c: (c.deadline, c.challenge_id))

    def _slash(self, ch: DAChallenge, kind: str) -> None:
        ch.status = "slashed"
        ch.kind = kind
        self._outstanding.add((ch.cid, ch.node_id))   # punished once
        fault = DAFault(round_id=ch.round_id, executor=ch.node_id,
                        verifier=self.challenger, object_id=ch.object_id,
                        chunk_index=ch.chunk_index, cid=ch.cid, kind=kind)
        self.faults.append(fault)
        self.stakes.slash(fault)
        self.stats["slashed"] += 1
