"""Optimistic commit-challenge-audit trust layer.

The paper's B-MoE buys robustness with full M-way redundancy: every edge
recomputes every activated expert and the blockchain layer majority-votes
all M copies (paper Step 3) — the latency/bandwidth overhead its Fig. 4b
measures.  This subsystem implements the optimistic alternative: one
executor edge computes, commits a Merkle root over its per-expert output
chunks on-chain, the result is accepted optimistically, and a verifier
pool spot-checks a sample of leaves during an asynchronous challenge
window.  A mismatch yields a compact fraud proof (Merkle path +
recomputed leaf) that anyone can check against the on-chain root; a
confirmed proof slashes the executor's stake, feeds the existing
reputation ledger (exclusion of repeat offenders), and escalates the
disputed round to the paper's full redundancy vote as the fallback
court.  Expected verification cost drops from O(M) recomputes per round
to O(1) + audit_rate, with the same trust guarantee in the limit: a
cheating executor is caught with probability 1-(1-audit_rate)^k when it
corrupts k committed leaves.

Modules
-------
- ``commitments``: Merkle trees over per-expert output chunks; one root
  digest per round goes on-chain.
- ``audit``: the verifier pool — leaf sampling, recompute against the
  stored expert (by CID, storage layer), fraud-proof construction and
  verification.
- ``slashing``: stake/deposit accounting; confirmed fraud proofs slash
  the executor and update the ReputationLedger; the dispute court
  escalates to the full redundancy vote.
- ``protocol``: the round state machine (commit -> optimistic accept ->
  async challenge window -> finalize/rollback) gluing the above to the
  ledger.
- ``session``: batched per-tick session commitments for the serving
  engine — one Merkle append per batch tick (one tree over all active
  slots' token digests), with per-session inclusion paths derived from
  it.
- ``da`` (import directly — not re-exported here, it depends on
  ``repro.storage`` which itself imports this package): data-availability
  challenges holding storage replica nodes to the chunks they committed
  to store; withheld chunks past the challenge window slash the node.
"""
from repro.trust.audit import (AuditPlan, AuditReport, BatchRecomputeFn,
                               FraudProof, MultiBatchRecomputeFn,
                               VerifierPool, verify_fraud_proof)
from repro.trust.commitments import (MerklePath, MerkleTree, RoundCommitment,
                                     commit_outputs, leaf_digest,
                                     leaf_digest_batch)
from repro.trust.protocol import (AuditJob, OptimisticProtocol, RollbackRecord,
                                  RoundPhase, RoundState, TrustConfig)
from repro.trust.session import (SessionLeafRef, TickCommitment, commit_tick,
                                 verify_session_inclusion)
from repro.trust.slashing import DisputeCourt, StakeBook

__all__ = [
    "AuditPlan", "AuditReport", "BatchRecomputeFn", "FraudProof",
    "MultiBatchRecomputeFn", "VerifierPool", "verify_fraud_proof",
    "MerklePath", "MerkleTree", "RoundCommitment", "commit_outputs",
    "leaf_digest", "leaf_digest_batch",
    "AuditJob", "OptimisticProtocol", "RollbackRecord", "RoundPhase",
    "RoundState", "TrustConfig", "DisputeCourt", "StakeBook",
    "SessionLeafRef", "TickCommitment", "commit_tick",
    "verify_session_inclusion",
]
