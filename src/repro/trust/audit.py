"""The verifier pool: sampled recompute and fraud proofs.

Each verifier independently samples committed leaves with probability
``audit_rate``, fetches the expert that produced the leaf from the
storage layer by CID (content-addressed, so a tampered replica is
self-evident), recomputes the chunk on the published task, and compares
digests.  A mismatch yields a ``FraudProof``: the claimed leaf chunk plus
its Merkle path — enough for anyone holding the on-chain root to confirm
(a) the executor really committed that leaf and (b) the honest recompute
disagrees.  An executor corrupting ``k`` leaves is caught by one honest
verifier with probability ``1 - (1-audit_rate)**k``; with ``v``
independent honest verifiers the exponent becomes ``k*v``.

Lazy verifiers (rubber-stampers that skip their recompute) are modeled
with ``lazy_prob`` — they sample leaves but never raise proofs, which is
how audit-evasion scenarios are expressed.

The lottery is *stake-weighted* when the pool is given per-verifier
``stakes``: verifier ``v`` samples each leaf with probability
``pool_rate * stake_v / sum(stakes)`` (``pool_rate`` = the per-verifier
base rate x the pool size), so the pool-wide expected sampled fraction
is conserved while high-stake verifiers carry proportionally more of the
audit load — the simulation analogue of a stake-weighted VRF lottery.
Lazy verifiers are *caught by re-audit*: every recomputing verifier must
attest ``H(salt_{round,verifier} || recomputed_chunk)`` per sampled leaf
(``attestation_digest``); the salt makes the attestation underivable
from the executor's published leaf digests, so a rubber-stamper's echo
fails any spot-check — even on honest rounds — and its stake is slashed
(``reaudit``), shrinking its share of every future lottery.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.ledger import digest_bytes
from repro.obs.metrics import CounterGroup, MetricsRegistry
from repro.trust.commitments import (MerklePath, MerkleTree, RoundCommitment,
                                     leaf_digest, leaf_digest_batch)

# recompute_fn(expert_index, batch_slice) -> honest output chunk
RecomputeFn = Callable[[int, slice], np.ndarray]

# batch_recompute_fn(expert_indices, batch_slices) -> stacked honest
# chunks (S, Cmax, ...): row s covers slices[s] of experts[s]'s output,
# padded past the slice length (padding rows are never hashed).  One
# call recomputes every sampled leaf of a round — the host backs it
# with a single jitted grouped kernel instead of S eager dispatches.
BatchRecomputeFn = Callable[[Sequence[int], Sequence[slice]], np.ndarray]

# multi_batch_recompute_fn(round_slots, expert_indices, batch_slices) ->
# stacked honest chunks (S, Cmax, ...): like BatchRecomputeFn but rows
# may belong to DIFFERENT rounds — ``round_slots[s]`` indexes the round
# (in the order the commitments were handed to ``audit_rounds``) whose
# snapshot state and task row ``s`` must be recomputed against.  One
# call covers a whole drained audit backlog: the host stacks the
# per-round expert-bank snapshots and concatenates the per-round tasks
# so several rounds' audits fuse into one grouped kernel dispatch.
MultiBatchRecomputeFn = Callable[
    [Sequence[int], Sequence[int], Sequence[slice]], np.ndarray]


def pack_audit_batch(expert_ids: Sequence[int], slices: Sequence[slice],
                     bucket: int = 4,
                     row_map: Optional[np.ndarray] = None):
    """Pack a deduped (expert, slice) work list for a grouped recompute.

    Returns ``(idx, gid, n)``: ``idx`` is ``(Sp, Cmax)`` int32 batch-row
    indices per sample (rows past a slice's width point at row 0 — pure
    padding, trimmed before hashing), ``gid`` the ``(Sp,)`` int32 expert
    per sample, ``n`` the real sample count.  ``Sp`` buckets ``n`` up to
    a multiple of ``bucket`` so a jitted consumer retraces O(1) times.

    Dense commitments slice the task directly (``idx`` rows are the
    slice's own indices).  Sparse commitments pass ``row_map`` — the
    commitment's ``(N, capacity)`` routing indices — and slot ``s`` of
    expert ``e``'s bucket reads task row ``row_map[e, s]`` (empty slots
    point one past the batch, at the zero sentinel row the host appends).
    Shared by ``BMoESystem._make_batched_recompute`` and the
    ``benchmarks/audit_kernels.py`` perf gate, so the benchmark measures
    exactly the production packing.
    """
    n = len(expert_ids)
    sp = -(-n // bucket) * bucket
    cmax = max(sl.stop - sl.start for sl in slices)
    idx = np.zeros((sp, cmax), np.int32)
    gid = np.zeros(sp, np.int32)
    for s, (e, sl) in enumerate(zip(expert_ids, slices)):
        rows = (np.arange(sl.start, sl.stop) if row_map is None
                else row_map[int(e), sl.start:sl.stop])
        idx[s, :sl.stop - sl.start] = rows
        gid[s] = int(e)
    return idx, gid, n


def pack_audit_batch_multi(slots: Sequence[int], expert_ids: Sequence[int],
                           slices: Sequence[slice],
                           row_offsets: Sequence[int], num_experts: int,
                           bucket: int = 4,
                           row_maps: Optional[Sequence[
                               Optional[np.ndarray]]] = None):
    """Cross-round variant of ``pack_audit_batch``: the work list spans
    several rounds whose expert banks are stacked to ``(R*N, ...)`` and
    whose tasks are concatenated row-wise.  Sample ``s`` of round slot
    ``k = slots[s]`` reads task rows ``row_offsets[k] + slice`` and
    expert ``k * num_experts + expert_ids[s]`` — so one grouped kernel
    call recomputes a whole drained audit backlog.  ``row_maps[k]``, when
    set, is round ``k``'s sparse routing (see ``pack_audit_batch``): the
    slice then indexes bucket slots and the task rows come from the
    committed routing.  Returns the same ``(idx, gid, n)`` contract as
    ``pack_audit_batch``.
    """
    n = len(expert_ids)
    sp = -(-n // bucket) * bucket
    cmax = max(sl.stop - sl.start for sl in slices) if n else 1
    idx = np.zeros((sp, cmax), np.int32)
    gid = np.zeros(sp, np.int32)
    for s, (k, e, sl) in enumerate(zip(slots, expert_ids, slices)):
        off = int(row_offsets[k])
        rmap = row_maps[k] if row_maps is not None else None
        rows = (np.arange(sl.start, sl.stop) if rmap is None
                else rmap[int(e), sl.start:sl.stop])
        idx[s, :sl.stop - sl.start] = off + rows
        gid[s] = int(k) * num_experts + int(e)
    return idx, gid, n


def attestation_digest(round_id: int, verifier: int,
                       chunk: np.ndarray) -> str:
    """Salted proof-of-recompute a verifier attests per sampled leaf.

    Domain-separated per (round, verifier): it can only be produced from
    the recomputed chunk *bytes*, never derived from the executor's
    published ``leaf_digest`` — which is exactly what lets a re-audit
    distinguish a real recompute from a rubber-stamp."""
    a = np.ascontiguousarray(chunk)
    salt = f"attest:{round_id}:{verifier}:".encode()
    return digest_bytes(salt + a.tobytes() + str(a.shape).encode()
                        + str(a.dtype).encode())


@dataclasses.dataclass
class LazySlashEvent:
    """A verifier caught rubber-stamping by re-audit."""
    round_id: int
    verifier: int
    leaf_index: int
    amount: float


@dataclasses.dataclass(frozen=True)
class FraudProof:
    round_id: int
    executor: int
    leaf_index: int
    expert: int
    claimed_chunk: np.ndarray               # the committed (bad) leaf data
    path: MerklePath
    claimed_digest: str
    recomputed_digest: str
    verifier: int = -1

    def compact_size_bytes(self) -> int:
        """On-wire size: one chunk + log2(leaves) siblings (32B each)."""
        return self.claimed_chunk.nbytes + 32 * len(self.path.siblings)


@dataclasses.dataclass(frozen=True)
class AuditPlan:
    """Every verifier's lottery for one round, drawn up front.

    ``unique_leaves`` dedupes across verifiers: a leaf sampled by three
    non-lazy verifiers is recomputed once, not three times (each verifier
    still gets the digest for its own report/fraud proof).  ``owner``
    credits the recompute to the first non-lazy verifier that sampled the
    leaf, so summed ``recomputed_leaves`` equals real recompute work.
    """
    round_id: int
    sampled: Dict[int, List[int]]          # verifier -> sampled leaves
    lazy: Dict[int, bool]
    unique_leaves: List[int]               # deduped, ascending
    owner: Dict[int, int]                  # leaf -> crediting verifier

    @property
    def num_recomputes(self) -> int:
        return len(self.unique_leaves)


@dataclasses.dataclass
class AuditReport:
    """One verifier pass over one round commitment.

    ``attestations`` (leaf -> salted recompute digest) are only filled
    when the pool re-audits (``reaudit_rate > 0``): honest verifiers
    attest from the recomputed bytes, lazy ones echo the executor's
    published digests — the only data available without recomputing."""
    round_id: int
    verifier: int
    sampled_leaves: List[int]
    fraud_proofs: List[FraudProof]
    recomputed_leaves: int = 0
    lazy: bool = False
    attestations: Dict[int, str] = dataclasses.field(default_factory=dict)

    @property
    def clean(self) -> bool:
        return not self.fraud_proofs


def verify_fraud_proof(root: str, proof: FraudProof,
                       recompute_fn: Optional[RecomputeFn] = None,
                       batch_slice: Optional[slice] = None) -> bool:
    """Anyone-can-check verdict on a fraud proof.

    Confirms (1) the claimed chunk is really committed under ``root``
    (Merkle path), and (2) its digest differs from the honest recompute.
    When ``recompute_fn`` is given the recompute is redone here (the
    court's own computation); otherwise the proof's recorded
    ``recomputed_digest`` is trusted (a verifier-signed attestation).
    """
    claimed = leaf_digest(proof.claimed_chunk)
    if claimed != proof.claimed_digest:
        return False
    if not MerkleTree.verify(root, claimed, proof.path):
        return False                      # not actually committed: griefing
    if recompute_fn is not None and batch_slice is not None:
        honest = leaf_digest(np.asarray(recompute_fn(proof.expert,
                                                     batch_slice)))
        return honest != claimed
    return proof.recomputed_digest != claimed


class VerifierPool:
    """``num_verifiers`` independent auditors with a shared audit rate.

    Deterministic given ``seed`` and the round id, so audit schedules are
    reproducible (and an executor cannot predict them without the seed —
    the simulation analogue of a VRF-drawn audit lottery).
    """

    def __init__(self, num_verifiers: int = 3, audit_rate: float = 0.1,
                 lazy_prob: float = 0.0, seed: int = 0,
                 stakes: Optional[Sequence[float]] = None,
                 reaudit_rate: float = 0.0,
                 verifier_slash_fraction: float = 0.5,
                 metrics: Optional[MetricsRegistry] = None,
                 namespace: str = "trust.verifiers"):
        self.num_verifiers = num_verifiers
        self.audit_rate = float(audit_rate)
        self.lazy_prob = float(lazy_prob)
        self._seed = seed
        # stake-weighted lottery: None keeps the uniform split (and the
        # exact sampling streams of the pre-stake pool); re-audits need
        # a stake to burn, so they default an unstaked pool to 1.0 each
        if stakes is None and reaudit_rate > 0:
            stakes = np.ones(num_verifiers)
        if stakes is not None:
            stakes = np.asarray(stakes, np.float64).copy()
            if stakes.shape != (num_verifiers,):
                raise ValueError(f"{stakes.shape} stakes for "
                                 f"{num_verifiers} verifiers")
            if (stakes < 0).any():
                raise ValueError("verifier stakes must be >= 0")
        self.stakes = stakes
        self.reaudit_rate = float(reaudit_rate)
        self.verifier_slash_fraction = float(verifier_slash_fraction)
        self.lazy_slashes: List[LazySlashEvent] = []
        # one ledger for every audit path (eager, batched, cross-round
        # burst): the pool's workload as the obs layer sees it
        self.stats = CounterGroup(
            {"audit_passes": 0, "lazy_passes": 0, "sampled_leaves": 0,
             "recomputed_leaves": 0, "fraud_proofs": 0,
             "reaudit_slashes": 0},
            metrics, namespace)

    def _count_report(self, report: "AuditReport") -> None:
        self.stats["audit_passes"] += 1
        self.stats["sampled_leaves"] += len(report.sampled_leaves)
        self.stats["recomputed_leaves"] += report.recomputed_leaves
        self.stats["fraud_proofs"] += len(report.fraud_proofs)
        if report.lazy:
            self.stats["lazy_passes"] += 1

    def _rng(self, round_id: int, verifier: int,
             salt: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            ((self._seed * 1_000_003 + round_id) * 97 + verifier) * 31 + salt)

    def rate_of(self, verifier: int) -> float:
        """Verifier ``verifier``'s per-leaf sampling probability: its
        stake share of the pool-wide budget ``audit_rate * V`` (uniform
        pools: exactly ``audit_rate``).  The sum over verifiers is
        conserved at the pool-wide rate — unless a share is clipped at
        1.0, sampling probabilities being probabilities."""
        if self.stakes is None:
            return self.audit_rate
        total = float(self.stakes.sum())
        if total <= 0.0:
            return 0.0                    # fully-slashed pool audits nothing
        # (stake * V) / total first: exactly 1.0 for a uniform pool, so
        # equal stakes reproduce the unweighted sampling streams bit-
        # for-bit (pinned in tests/test_verifier_lottery.py)
        share = float(self.stakes[verifier]) * self.num_verifiers / total
        return min(1.0, self.audit_rate * share)

    def sample_leaves(self, round_id: int, verifier: int,
                      num_leaves: int) -> List[int]:
        rng = self._rng(round_id, verifier)
        keep = rng.random(num_leaves) < self.rate_of(verifier)
        return [int(i) for i in np.nonzero(keep)[0]]

    def audit_one(self, commitment: RoundCommitment,
                  recompute_fn: RecomputeFn, verifier: int) -> AuditReport:
        """One verifier's pass: sample, recompute, emit fraud proofs."""
        # distinct stream from sample_leaves: the lazy coin must not be
        # correlated with which leaves get sampled (a shared first draw
        # would silently lower leaf 0's effective audit rate)
        lazy = bool(self._rng(commitment.round_id, verifier,
                              salt=1).random() < self.lazy_prob)
        sampled = self.sample_leaves(commitment.round_id, verifier,
                                     commitment.num_leaves)
        report = AuditReport(round_id=commitment.round_id, verifier=verifier,
                             sampled_leaves=sampled, fraud_proofs=[],
                             lazy=lazy)
        if lazy:
            # rubber-stamp: no recompute.  When attestations are due the
            # lazy verifier echoes the executor's published digests —
            # the only bytes it holds — which can never match the salted
            # attestation a re-audit recomputes.
            if self.reaudit_rate > 0:
                report.attestations = {
                    leaf: commitment.leaf_digests[leaf] for leaf in sampled}
            self._count_report(report)
            return report
        tree = commitment.tree()
        for leaf in sampled:
            e, _, sl = commitment.leaf_coords(leaf)
            chunk = np.asarray(recompute_fn(e, sl))
            honest = leaf_digest(chunk)
            if self.reaudit_rate > 0:
                report.attestations[leaf] = attestation_digest(
                    commitment.round_id, verifier, chunk)
            report.recomputed_leaves += 1
            claimed = commitment.leaf_digests[leaf]
            if honest != claimed:
                report.fraud_proofs.append(FraudProof(
                    round_id=commitment.round_id,
                    executor=commitment.executor, leaf_index=leaf, expert=e,
                    claimed_chunk=commitment.leaf_chunk(leaf),
                    path=tree.prove(leaf), claimed_digest=claimed,
                    recomputed_digest=honest, verifier=verifier))
        self._count_report(report)
        return report

    def audit(self, commitment: RoundCommitment,
              recompute_fn: RecomputeFn,
              verifiers: Optional[Sequence[int]] = None) -> List[AuditReport]:
        ids = range(self.num_verifiers) if verifiers is None else verifiers
        return [self.audit_one(commitment, recompute_fn, v) for v in ids]

    # ------------------------------------------------------ batched path
    def plan_audits(self, round_id: int, num_leaves: int,
                    verifiers: Optional[Sequence[int]] = None) -> AuditPlan:
        """Draw every verifier's lottery up front (same RNG streams as
        ``audit_one``, so the plan is sample-for-sample identical to the
        eager path) and dedupe the recompute work across verifiers."""
        ids = list(range(self.num_verifiers) if verifiers is None
                   else verifiers)
        sampled = {v: self.sample_leaves(round_id, v, num_leaves)
                   for v in ids}
        lazy = {v: bool(self._rng(round_id, v, salt=1).random()
                        < self.lazy_prob) for v in ids}
        owner: Dict[int, int] = {}
        for v in ids:                       # verifier order fixes ownership
            if lazy[v]:
                continue
            for leaf in sampled[v]:
                owner.setdefault(leaf, v)
        return AuditPlan(round_id=round_id, sampled=sampled, lazy=lazy,
                         unique_leaves=sorted(owner), owner=owner)

    def audit_batched(self, commitment: RoundCommitment,
                      batch_recompute_fn: BatchRecomputeFn,
                      verifiers: Optional[Sequence[int]] = None
                      ) -> List[AuditReport]:
        """The whole pool's audit pass as ONE recompute call.

        Plans all lotteries, gathers the deduped (expert, slice) work
        list, recomputes it in a single ``batch_recompute_fn`` call, and
        hashes every recomputed chunk in one ``leaf_digest_batch`` pass.
        Per-verifier reports (sampled leaves, lazy flags, fraud proofs)
        are identical to ``audit``'s; only ``recomputed_leaves`` differs —
        it now counts real (deduped) recompute work, credited to the
        first non-lazy sampler of each leaf.
        """
        plan = self.plan_audits(commitment.round_id, commitment.num_leaves,
                                verifiers)
        digest_of: Dict[int, str] = {}
        chunk_of: Optional[Dict[int, np.ndarray]] = None
        if plan.unique_leaves:
            coords = [commitment.leaf_coords(leaf)
                      for leaf in plan.unique_leaves]
            experts = [e for e, _, _ in coords]
            slices = [sl for _, _, sl in coords]
            stacked = np.asarray(batch_recompute_fn(experts, slices))
            lengths = [sl.stop - sl.start for sl in slices]
            digests = leaf_digest_batch(stacked, lengths)
            digest_of = dict(zip(plan.unique_leaves, digests))
            if self.reaudit_rate > 0:
                chunk_of = {leaf: stacked[i, :lengths[i]]
                            for i, leaf in enumerate(plan.unique_leaves)}
        return self._reports_from_digests(commitment, plan, digest_of,
                                          chunk_of)

    def _reports_from_digests(self, commitment: RoundCommitment,
                              plan: AuditPlan, digest_of: Dict[int, str],
                              chunk_of: Optional[Dict[int, np.ndarray]] = None
                              ) -> List[AuditReport]:
        """Per-verifier reports/fraud proofs from a plan plus the honest
        digests (and, when re-audits are on, the recomputed bytes) of its
        unique leaves (shared by ``audit_batched`` and the cross-round
        ``audit_rounds``)."""
        tree = None
        reports = []
        for v, leaves in plan.sampled.items():
            report = AuditReport(round_id=commitment.round_id, verifier=v,
                                 sampled_leaves=leaves, fraud_proofs=[],
                                 lazy=plan.lazy[v])
            reports.append(report)
            if plan.lazy[v]:
                if self.reaudit_rate > 0:
                    report.attestations = {
                        leaf: commitment.leaf_digests[leaf]
                        for leaf in leaves}
                continue
            report.recomputed_leaves = sum(
                1 for leaf in leaves if plan.owner.get(leaf) == v)
            for leaf in leaves:
                honest = digest_of[leaf]
                if chunk_of is not None:
                    report.attestations[leaf] = attestation_digest(
                        commitment.round_id, v, chunk_of[leaf])
                claimed = commitment.leaf_digests[leaf]
                if honest != claimed:
                    if tree is None:
                        tree = commitment.tree()
                    e, _, _ = commitment.leaf_coords(leaf)
                    report.fraud_proofs.append(FraudProof(
                        round_id=commitment.round_id,
                        executor=commitment.executor, leaf_index=leaf,
                        expert=e, claimed_chunk=commitment.leaf_chunk(leaf),
                        path=tree.prove(leaf), claimed_digest=claimed,
                        recomputed_digest=honest, verifier=v))
        for report in reports:
            self._count_report(report)
        return reports

    def audit_rounds(self, commitments: Sequence[RoundCommitment],
                     multi_recompute_fn: MultiBatchRecomputeFn,
                     verifiers: Optional[Sequence[int]] = None
                     ) -> Dict[int, List[AuditReport]]:
        """A whole drained audit *backlog* as ONE recompute call.

        The pipelined protocol parks each round's audit until its window
        is about to close, then drains the backlog in a burst; this is
        the burst's engine.  Every round's lottery is planned exactly as
        ``audit_batched`` would (same RNG streams, keyed by round id, so
        reports are round-for-round identical to draining one at a
        time), the deduped work lists are concatenated with a round-slot
        tag per row, recomputed in a single ``multi_recompute_fn`` call,
        and hashed in one ``leaf_digest_batch`` pass.  Returns reports
        keyed by round id.
        """
        plans = [self.plan_audits(c.round_id, c.num_leaves, verifiers)
                 for c in commitments]
        slots: List[int] = []
        experts: List[int] = []
        slices: List[slice] = []
        for k, (com, plan) in enumerate(zip(commitments, plans)):
            for leaf in plan.unique_leaves:
                e, _, sl = com.leaf_coords(leaf)
                slots.append(k)
                experts.append(e)
                slices.append(sl)
        digests: List[str] = []
        stacked = None
        lengths = [sl.stop - sl.start for sl in slices]
        if slots:
            stacked = np.asarray(multi_recompute_fn(slots, experts, slices))
            digests = leaf_digest_batch(stacked, lengths)
        out: Dict[int, List[AuditReport]] = {}
        cursor = 0
        for com, plan in zip(commitments, plans):
            span = range(cursor, cursor + len(plan.unique_leaves))
            digest_of = dict(zip(plan.unique_leaves,
                                 [digests[i] for i in span]))
            chunk_of = ({leaf: stacked[i, :lengths[i]]
                         for leaf, i in zip(plan.unique_leaves, span)}
                        if self.reaudit_rate > 0 and stacked is not None
                        else None)
            cursor += len(plan.unique_leaves)
            out[com.round_id] = self._reports_from_digests(com, plan,
                                                           digest_of,
                                                           chunk_of)
        return out

    # -------------------------------------------------------- re-audit
    def reaudit(self, commitment: RoundCommitment,
                reports: Sequence[AuditReport],
                recompute_fn: RecomputeFn) -> List[int]:
        """Second-layer audit of the auditors: spot-check each verifier's
        attestations at ``reaudit_rate`` per sampled leaf.

        The expected attestation is recomputed from the honest chunk
        bytes with the (round, verifier) salt; a verifier whose submitted
        attestation differs — a rubber-stamper echoing published digests,
        or one that attested garbage — is slashed
        (``verifier_slash_fraction`` of its stake burned, which also
        shrinks its share of every future stake-weighted lottery).  One
        slash per (round, verifier).  Returns the caught verifier ids.
        """
        if self.reaudit_rate <= 0 or self.stakes is None:
            return []
        caught: List[int] = []
        cache: Dict[int, np.ndarray] = {}
        for report in reports:
            rng = self._rng(commitment.round_id, report.verifier, salt=2)
            coins = rng.random(len(report.sampled_leaves))
            for leaf, coin in zip(report.sampled_leaves, coins):
                if coin >= self.reaudit_rate:
                    continue
                if leaf not in cache:
                    e, _, sl = commitment.leaf_coords(leaf)
                    cache[leaf] = np.asarray(recompute_fn(e, sl))
                expected = attestation_digest(commitment.round_id,
                                              report.verifier, cache[leaf])
                if report.attestations.get(leaf) != expected:
                    amount = float(self.stakes[report.verifier]
                                   * self.verifier_slash_fraction)
                    self.stakes[report.verifier] -= amount
                    self.lazy_slashes.append(LazySlashEvent(
                        round_id=commitment.round_id,
                        verifier=report.verifier, leaf_index=leaf,
                        amount=amount))
                    self.stats["reaudit_slashes"] += 1
                    caught.append(report.verifier)
                    break                  # one slash per (round, verifier)
        return caught

    def detection_probability(self, corrupted_leaves: int,
                              honest_verifiers: Optional[int] = None) -> float:
        """Analytic bound: P[>=1 corrupted leaf sampled by an honest
        verifier].

        Uniform pool: ``1 - (1-audit_rate)^(k*v)``.  Stake-weighted
        pool: each verifier's per-leaf rate is its ``rate_of``, so the
        bound is ``1 - prod_v (1-rate_v)^k`` over the honest verifiers —
        and with only a *count* of honest verifiers given, the v
        LOWEST-rate verifiers are assumed honest (the conservative
        bound: any other honest set detects at least as well)."""
        v = (self.num_verifiers if honest_verifiers is None
             else honest_verifiers)
        if self.stakes is None:
            return 1.0 - (1.0 - self.audit_rate) ** (corrupted_leaves * v)
        rates = sorted(self.rate_of(i) for i in range(self.num_verifiers))
        miss = 1.0
        for r in rates[:v]:
            miss *= (1.0 - r) ** corrupted_leaves
        return 1.0 - miss
