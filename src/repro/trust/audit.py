"""The verifier pool: sampled recompute and fraud proofs.

Each verifier independently samples committed leaves with probability
``audit_rate``, fetches the expert that produced the leaf from the
storage layer by CID (content-addressed, so a tampered replica is
self-evident), recomputes the chunk on the published task, and compares
digests.  A mismatch yields a ``FraudProof``: the claimed leaf chunk plus
its Merkle path — enough for anyone holding the on-chain root to confirm
(a) the executor really committed that leaf and (b) the honest recompute
disagrees.  An executor corrupting ``k`` leaves is caught by one honest
verifier with probability ``1 - (1-audit_rate)**k``; with ``v``
independent honest verifiers the exponent becomes ``k*v``.

Lazy verifiers (rubber-stampers that skip their recompute) are modeled
with ``lazy_prob`` — they sample leaves but never raise proofs, which is
how audit-evasion scenarios are expressed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.trust.commitments import (MerklePath, MerkleTree, RoundCommitment,
                                     leaf_digest)

# recompute_fn(expert_index, batch_slice) -> honest output chunk
RecomputeFn = Callable[[int, slice], np.ndarray]


@dataclasses.dataclass(frozen=True)
class FraudProof:
    round_id: int
    executor: int
    leaf_index: int
    expert: int
    claimed_chunk: np.ndarray               # the committed (bad) leaf data
    path: MerklePath
    claimed_digest: str
    recomputed_digest: str
    verifier: int = -1

    def compact_size_bytes(self) -> int:
        """On-wire size: one chunk + log2(leaves) siblings (32B each)."""
        return self.claimed_chunk.nbytes + 32 * len(self.path.siblings)


@dataclasses.dataclass
class AuditReport:
    """One verifier pass over one round commitment."""
    round_id: int
    verifier: int
    sampled_leaves: List[int]
    fraud_proofs: List[FraudProof]
    recomputed_leaves: int = 0
    lazy: bool = False

    @property
    def clean(self) -> bool:
        return not self.fraud_proofs


def verify_fraud_proof(root: str, proof: FraudProof,
                       recompute_fn: Optional[RecomputeFn] = None,
                       batch_slice: Optional[slice] = None) -> bool:
    """Anyone-can-check verdict on a fraud proof.

    Confirms (1) the claimed chunk is really committed under ``root``
    (Merkle path), and (2) its digest differs from the honest recompute.
    When ``recompute_fn`` is given the recompute is redone here (the
    court's own computation); otherwise the proof's recorded
    ``recomputed_digest`` is trusted (a verifier-signed attestation).
    """
    claimed = leaf_digest(proof.claimed_chunk)
    if claimed != proof.claimed_digest:
        return False
    if not MerkleTree.verify(root, claimed, proof.path):
        return False                      # not actually committed: griefing
    if recompute_fn is not None and batch_slice is not None:
        honest = leaf_digest(np.asarray(recompute_fn(proof.expert,
                                                     batch_slice)))
        return honest != claimed
    return proof.recomputed_digest != claimed


class VerifierPool:
    """``num_verifiers`` independent auditors with a shared audit rate.

    Deterministic given ``seed`` and the round id, so audit schedules are
    reproducible (and an executor cannot predict them without the seed —
    the simulation analogue of a VRF-drawn audit lottery).
    """

    def __init__(self, num_verifiers: int = 3, audit_rate: float = 0.1,
                 lazy_prob: float = 0.0, seed: int = 0):
        self.num_verifiers = num_verifiers
        self.audit_rate = float(audit_rate)
        self.lazy_prob = float(lazy_prob)
        self._seed = seed

    def _rng(self, round_id: int, verifier: int,
             salt: int = 0) -> np.random.Generator:
        return np.random.default_rng(
            ((self._seed * 1_000_003 + round_id) * 97 + verifier) * 31 + salt)

    def sample_leaves(self, round_id: int, verifier: int,
                      num_leaves: int) -> List[int]:
        rng = self._rng(round_id, verifier)
        keep = rng.random(num_leaves) < self.audit_rate
        return [int(i) for i in np.nonzero(keep)[0]]

    def audit_one(self, commitment: RoundCommitment,
                  recompute_fn: RecomputeFn, verifier: int) -> AuditReport:
        """One verifier's pass: sample, recompute, emit fraud proofs."""
        # distinct stream from sample_leaves: the lazy coin must not be
        # correlated with which leaves get sampled (a shared first draw
        # would silently lower leaf 0's effective audit rate)
        lazy = bool(self._rng(commitment.round_id, verifier,
                              salt=1).random() < self.lazy_prob)
        sampled = self.sample_leaves(commitment.round_id, verifier,
                                     commitment.num_leaves)
        report = AuditReport(round_id=commitment.round_id, verifier=verifier,
                             sampled_leaves=sampled, fraud_proofs=[],
                             lazy=lazy)
        if lazy:
            return report                  # rubber-stamp: no recompute
        tree = commitment.tree()
        for leaf in sampled:
            e, _, sl = commitment.leaf_coords(leaf)
            honest = leaf_digest(np.asarray(recompute_fn(e, sl)))
            report.recomputed_leaves += 1
            claimed = commitment.leaf_digests[leaf]
            if honest != claimed:
                report.fraud_proofs.append(FraudProof(
                    round_id=commitment.round_id,
                    executor=commitment.executor, leaf_index=leaf, expert=e,
                    claimed_chunk=commitment.leaf_chunk(leaf),
                    path=tree.prove(leaf), claimed_digest=claimed,
                    recomputed_digest=honest, verifier=verifier))
        return report

    def audit(self, commitment: RoundCommitment,
              recompute_fn: RecomputeFn,
              verifiers: Optional[Sequence[int]] = None) -> List[AuditReport]:
        ids = range(self.num_verifiers) if verifiers is None else verifiers
        return [self.audit_one(commitment, recompute_fn, v) for v in ids]

    def detection_probability(self, corrupted_leaves: int,
                              honest_verifiers: Optional[int] = None) -> float:
        """Analytic bound: P[>=1 corrupted leaf sampled by an honest
        verifier] = 1 - (1-audit_rate)^(k*v)."""
        v = (self.num_verifiers if honest_verifiers is None
             else honest_verifiers)
        return 1.0 - (1.0 - self.audit_rate) ** (corrupted_leaves * v)
