"""Unified train/prefill/decode step builders for every architecture.

``make_step(cfg, kind)`` returns (step_fn, describe) where step_fn's
signature depends on kind:

- kind="train":   (params, opt_state, batch)      -> (params, opt_state, metrics)
- kind="prefill": (params, batch)                 -> logits
- kind="decode":  (params, caches, batch)         -> (next_token, caches)

The same functions are jitted for CPU-scale runs (mesh=None) and lowered
against ShapeDtypeStructs for the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.trusted_moe import make_trust
from repro.models import encdec
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import Sharder, logical_rules


def model_forward(params, batch, cfg: ModelConfig, shard=None, trust=None,
                  remat=True, unroll=False):
    """Dispatch on architecture family.  Returns (logits, aux, labels)."""
    if cfg.is_encoder_decoder:
        logits, aux = encdec.forward_train(params, batch["frames"],
                                           batch["tokens"], cfg, shard=shard,
                                           remat=remat, unroll=unroll)
        return logits, aux, batch.get("labels")
    prefix = batch.get("patches")
    logits, aux = tfm.forward_train(params, batch["tokens"], cfg,
                                    shard=shard, trust=trust,
                                    prefix_embeds=prefix, remat=remat,
                                    unroll=unroll)
    labels = batch.get("labels")
    if prefix is not None and labels is not None:
        # VLM: no loss on the image-prefix region
        ignore = jnp.full(prefix.shape[:2], -1, jnp.int32)
        labels = jnp.concatenate([ignore, labels], axis=1)
    return logits, aux, labels


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    mesh=None, attack=None, remat=True, unroll=False):
    shard = Sharder(mesh, logical_rules(mesh, cfg), fsdp=True,
                    attack=attack) if mesh is not None else None
    trust = None
    if cfg.redundancy.mode != "off" and mesh is not None:
        expert_sharded = (cfg.num_experts % mesh.devices.shape[-1] == 0)
        trust = make_trust(mesh, cfg.redundancy, expert_sharded, attack)

    def loss_and_grad(params, mb):
        def loss_fn(p):
            logits, aux, labels = model_forward(p, mb, cfg, shard, trust,
                                                remat, unroll)
            loss = tfm.lm_loss(logits, labels) + aux
            return loss, aux
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    K = max(cfg.train_microbatches, 1)

    def train_step(params, opt_state, batch):
        if K == 1:
            (loss, aux), grads = loss_and_grad(params, batch)
        else:
            # gradient accumulation: scan over K microbatches (activation
            # memory / K; f32 grad accumulator shards like the params)
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((K, x.shape[0] // K) + x.shape[1:]),
                batch)
            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(acc, mb):
                acc_g, acc_loss, acc_aux = acc
                (loss, aux), grads = loss_and_grad(params, mb)
                acc_g = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / K, acc_g, grads)
                return (acc_g, acc_loss + loss / K, acc_aux + aux / K), None

            (grads, loss, aux), _ = jax.lax.scan(
                mb_step, (acc0, jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32)), micro)
        params, opt_state, om = adamw.update(opt_cfg, grads, opt_state,
                                             params)
        metrics = {"loss": loss, "aux_loss": aux, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None, unroll=False):
    from repro.sharding import use_fsdp
    shard = Sharder(mesh, logical_rules(mesh, cfg),
                    fsdp=use_fsdp(cfg, "prefill",
                                  mesh.devices.shape[-1])) \
        if mesh is not None else None

    def prefill_step(params, batch):
        logits, _aux, _ = model_forward(params, batch, cfg, shard,
                                        trust=None, remat=False,
                                        unroll=unroll)
        return logits[:, -1:].argmax(axis=-1)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, unroll=False,
                     expert_stats=False):
    """``expert_stats=True`` (decoder-only MoE models) makes the step
    also return the per-MoE-layer routed-token counts — what the serving
    engine's edge expert cache resolves activated experts from.

    The batch may carry ``pos`` as a scalar (every row at the same depth)
    or a (B,) vector, and an optional (B,) bool ``active`` mask: inactive
    rows run the padded compute but leave their caches untouched — the
    fixed-shape contract continuous batching compiles once against."""
    from repro.sharding import use_fsdp
    shard = Sharder(mesh, logical_rules(mesh, cfg),
                    fsdp=use_fsdp(cfg, "decode",
                                  mesh.devices.shape[-1])) \
        if mesh is not None else None

    def decode_step(params, caches, batch):
        tokens, pos = batch["tokens"], batch["pos"]
        active = batch.get("active")
        if cfg.is_encoder_decoder:
            if active is not None:
                raise NotImplementedError(
                    "active-slot masking targets decoder-only archs")
            logits, caches = encdec.forward_decode(params, caches, tokens,
                                                   pos, cfg, shard=shard,
                                                   unroll=unroll)
        elif expert_stats:
            logits, caches, stats = tfm.forward_decode(
                params, caches, tokens, pos, cfg, shard=shard,
                unroll=unroll, expert_stats=True, write_mask=active)
            return logits[:, -1].argmax(axis=-1), caches, stats
        else:
            logits, caches = tfm.forward_decode(params, caches, tokens, pos,
                                                cfg, shard=shard,
                                                unroll=unroll,
                                                write_mask=active)
        return logits[:, -1].argmax(axis=-1), caches

    return decode_step


def make_serve_chunk_step(cfg: ModelConfig, mesh=None, unroll=False,
                          expert_stats=False):
    """Fused serving macro-step for the engine: one compiled call runs C
    engine ticks (``tfm.forward_serve_chunk`` — a ``lax.scan`` of masked
    greedy decode micro-steps) in which prefilling slots chunk-consume
    their prompts while decoding slots keep generating autoregressively.
    Long prompts cost ceil(len/C) dispatches instead of len, in-flight
    decode is never stalled behind a token-by-token prompt feed, and
    per-call overhead amortizes over the chunk.

    batch: ``tokens`` (B, C) int32, ``start`` (B,) int32 (last generated
    token per slot), ``pos`` (B,) int32, ``lengths`` (B,) int32 (prompt
    columns consumed), ``adv`` (B,) int32 (micro-steps the slot advances
    at all; 0 = idle padding).  Returns (out_tokens (C, B),
    caches[, stats])."""
    from repro.sharding import use_fsdp
    if cfg.is_encoder_decoder:
        raise NotImplementedError("serve chunk drives decoder-only archs")
    shard = Sharder(mesh, logical_rules(mesh, cfg),
                    fsdp=use_fsdp(cfg, "decode",
                                  mesh.devices.shape[-1])) \
        if mesh is not None else None

    def serve_chunk_step(params, caches, batch):
        return tfm.forward_serve_chunk(
            params, caches, batch["tokens"], batch["start"], batch["pos"],
            batch["lengths"], batch["adv"], cfg, shard=shard,
            unroll=unroll, expert_stats=expert_stats)

    return serve_chunk_step


def make_step(cfg: ModelConfig, kind: str, mesh=None,
              opt_cfg: Optional[adamw.AdamWConfig] = None, remat=True,
              unroll=False):
    if kind == "train":
        return make_train_step(cfg, opt_cfg or adamw.AdamWConfig(), mesh,
                               remat=remat, unroll=unroll)
    if kind == "prefill":
        return make_prefill_step(cfg, mesh, unroll=unroll)
    if kind == "decode":
        return make_decode_step(cfg, mesh, unroll=unroll)
    raise ValueError(kind)


# ------------------------------------------------------- federated edge
def make_fed_local_step(num_experts: int, top_k: int, lr: float,
                        apply_all):
    """Jitted local SGD update for one federated edge (``repro.fed``).

    The edge runs the full-bank dense MoE forward (gate top-k mixture
    over ``apply_all``'s (N, B, C) outputs) but its gradient is masked
    to the experts it OWNS: unowned experts receive exactly zero update,
    so the edge's published delta is zero (and chunk-dedups away) off
    its expert subset.  The gate is trained by every edge.

    Returns ``step(params, x, y, owned) -> (params, loss)`` where
    ``params = {"gate", "experts"}``, ``x`` is (B, in_dim), ``y`` (B,)
    int labels and ``owned`` a float (N,) ownership mask.
    """
    from repro.core import experts as ex

    def moe_loss(params, x, y):
        logits = ex.gate_apply(params["gate"], x)
        w, _ = ex.sparse_gate_weights(logits, top_k)
        outs = apply_all(params["experts"], x)        # (N, B, C)
        mix = jnp.einsum("bn,nbc->bc", w, outs)
        logp = jax.nn.log_softmax(mix)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    def local_step(params, x, y, owned):
        loss, grads = jax.value_and_grad(moe_loss)(params, x, y)

        def mask_expert(g):
            shape = (num_experts,) + (1,) * (g.ndim - 1)
            return g * owned.reshape(shape)

        new = {
            "gate": jax.tree_util.tree_map(
                lambda p, g: p - lr * g, params["gate"], grads["gate"]),
            "experts": jax.tree_util.tree_map(
                lambda p, g: p - lr * mask_expert(g),
                params["experts"], grads["experts"]),
        }
        return new, loss

    return jax.jit(local_step)
