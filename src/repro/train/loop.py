"""Training loop (CPU-scale demo driver and integration-test harness)."""
from __future__ import annotations

import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np

from repro.models import encdec, transformer as tfm
from repro.models.builder import materialize
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.step import make_train_step


def init_model(cfg: ModelConfig, seed: int = 0, dtype=None):
    import jax.numpy as jnp
    decl = (encdec.encdec_decl(cfg) if cfg.is_encoder_decoder
            else tfm.model_decl(cfg))
    return materialize(decl, jax.random.PRNGKey(seed),
                       dtype or jnp.float32)


def train(cfg: ModelConfig, batches: Iterator[dict], steps: int, *,
          opt_cfg: Optional[adamw.AdamWConfig] = None, seed: int = 0,
          mesh=None, log_every: int = 10, remat=False,
          callback: Optional[Callable] = None):
    """Returns (params, history). ``batches`` yields dicts with tokens/
    labels (+frames/patches per family)."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=steps)
    params = init_model(cfg, seed)
    opt_state = adamw.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, mesh, remat=remat))
    history = []
    t0 = time.time()
    for step in range(steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(np.asarray(v)) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            if callback:
                callback(m)
    return params, history
