"""Deterministic synthetic datasets (the container is offline).

- ``make_image_dataset``: Fashion-MNIST-like (28x28x1, 10 classes) and
  CIFAR-10-like (32x32x3, 10 classes) class-conditional data: per-class
  smoothed templates + per-sample noise + random per-sample contrast.
  Shapes/cardinalities match the real datasets; learnable by the paper's
  MLP/CNN experts, so the *relative* robustness conclusions carry.

- ``lm_batches``: token streams with a planted bigram structure
  (next = perm[cur] w.p. 0.8) so language-model training measurably
  reduces loss.

- ``serving_requests``: batched request generator for the serving engine.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageSpec:
    name: str
    height: int
    width: int
    channels: int
    num_classes: int = 10


FMNIST = ImageSpec("fashion-mnist-like", 28, 28, 1)
CIFAR10 = ImageSpec("cifar10-like", 32, 32, 3)


def _smooth(x: np.ndarray, iters: int = 8) -> np.ndarray:
    """Neighbor-averaging smoothing along H, W (keeps templates low-freq)."""
    for _ in range(iters):
        x = (x + np.roll(x, 1, 0) + np.roll(x, -1, 0)
             + np.roll(x, 1, 1) + np.roll(x, -1, 1)) / 5.0
    return x


def make_image_dataset(spec: ImageSpec, n_train: int = 10_000,
                       n_test: int = 2_000, seed: int = 0,
                       noise: float = 0.35):
    """Returns (x_train, y_train, x_test, y_test) as numpy arrays.
    Images in [-1, 1]-ish, labels int32."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(
        size=(spec.num_classes, spec.height, spec.width, spec.channels))
    templates = np.stack([_smooth(t) for t in templates]).astype(np.float32)
    templates /= np.abs(templates).max(axis=(1, 2, 3), keepdims=True)

    def sample(n):
        y = rng.integers(0, spec.num_classes, size=n).astype(np.int32)
        contrast = rng.uniform(0.7, 1.3, size=(n, 1, 1, 1)).astype(np.float32)
        x = templates[y] * contrast + noise * rng.normal(
            size=(n, spec.height, spec.width, spec.channels)).astype(np.float32)
        return x.astype(np.float32), y

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te


def dirichlet_shards(labels, num_shards: int, *, alpha: float = 0.3,
                     seed: int = 0, min_per_shard: int = 1):
    """Deterministic non-IID partition of a labeled dataset: every
    class's sample indices are split across shards by Dirichlet(alpha)
    proportions (small alpha -> each shard dominated by a few classes —
    the federated heterogeneity the FL-MoE papers benchmark on).

    Returns a list of ``num_shards`` sorted int64 index arrays that
    exactly partition ``range(len(labels))``; identical across runs for
    the same (labels, num_shards, alpha, seed).  Shards that the draw
    left below ``min_per_shard`` samples steal from the largest shard so
    every edge can train."""
    labels = np.asarray(labels)
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    rng = np.random.default_rng(seed)
    shards: list = [[] for _ in range(num_shards)]
    for cls in np.unique(labels):
        idx = np.flatnonzero(labels == cls)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_shards, alpha))
        counts = np.floor(props * len(idx)).astype(int)
        order = np.argsort(-props, kind="stable")
        counts[order[:len(idx) - counts.sum()]] += 1
        off = 0
        for s in range(num_shards):
            shards[s].extend(idx[off:off + counts[s]].tolist())
            off += counts[s]
    out = [np.asarray(sorted(ids), dtype=np.int64) for ids in shards]
    for s in range(num_shards):
        while len(out[s]) < min(min_per_shard, len(labels) // num_shards):
            donor = int(np.argmax([len(a) for a in out]))
            out[s] = np.sort(np.append(out[s], out[donor][-1]))
            out[donor] = out[donor][:-1]
    return out


def lm_batches(vocab_size: int, batch: int, seq: int, *, seed: int = 0,
               p_structured: float = 0.8) -> Iterator[dict]:
    """Infinite iterator of {tokens, labels} with planted bigram structure."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(vocab_size)
    while True:
        toks = np.empty((batch, seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, vocab_size, size=batch)
        for t in range(seq):
            structured = rng.random(batch) < p_structured
            nxt = np.where(structured, perm[toks[:, t]],
                           rng.integers(0, vocab_size, size=batch))
            toks[:, t + 1] = nxt
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}


def serving_requests(vocab_size: int, num_requests: int, *,
                     max_prompt: int = 64, max_new: int = 16,
                     seed: int = 0) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    for rid in range(num_requests):
        plen = int(rng.integers(4, max_prompt))
        yield {"id": rid,
               "prompt": rng.integers(0, vocab_size, size=plen).astype(np.int32),
               "max_new_tokens": int(rng.integers(1, max_new))}
