"""One federated edge device: local shard, local expert subset, deltas.

An edge holds a fixed Dirichlet shard of the training set
(``data.synthetic.dirichlet_shards``) and OWNS a small subset of the
expert bank.  Each round it pulls the coordinator's global parameters,
runs a few steps of local SGD with the gradient masked to its owned
experts (``train.step.make_fed_local_step``), and publishes the
resulting weight **delta** — not the weights — as one versioned object
``fed/delta/{edge}`` through ``ExpertStore.put_version``.  The masked
delta is zero off the edge's expert subset, so the all-zero chunks
dedup against every other edge's upload and the per-round network cost
scales with experts-per-edge, not bank size.

Poisoning attacks live HERE (the adversary is an edge, or an
aggregator colluding with one): ``attack="grad_scale"`` multiplies the
honest delta by ``scale`` (magnitude poisoning), ``"sign_flip"``
negates and scales it (directed poisoning).  Attacks only perturb the
published delta — local training itself is always honest, so the
defended aggregation rule is the only thing standing between a poison
and the global model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class DeltaRecord:
    """What the aggregator knows about one received delta.  The manifest
    CID is what gets committed on-chain — auditors re-fetch the delta by
    CID, so a record is exactly one aggregation input."""
    edge: int
    round_id: int                  # round the delta arrived in
    base_round: int                # global version it was computed against
    manifest_cid: str
    num_samples: int               # FedAvg weight (shard size)
    arrival_s: float               # modeled arrival offset within round
    loss: float                    # edge's final local training loss


class FedEdge:
    """Local trainer for one edge."""

    def __init__(self, edge_id: int, x, y, owned: np.ndarray, store,
                 local_step, *, local_steps: int, local_batch: int,
                 seed: int):
        self.edge_id = edge_id
        self.x = np.asarray(x, np.float32)
        self.y = np.asarray(y, np.int32)
        self.owned = np.asarray(owned, np.float32)      # (N,) mask
        self.store = store
        self.local_step = local_step
        self.local_steps = local_steps
        self.local_batch = local_batch
        self.seed = seed

    @property
    def num_samples(self) -> int:
        return len(self.x)

    def local_update(self, global_params, round_id: int, *,
                     attack: Optional[str] = None,
                     attack_scale: float = 1.0) -> Tuple[dict, float]:
        """Train locally from ``global_params``; return ``(delta_tree,
        final_loss)`` with the delta a float32 numpy pytree.  Seeded by
        (seed, edge, round) only — a rollback replay that re-runs this
        round reproduces the delta bit-for-bit."""
        rng = np.random.default_rng([self.seed, 3, self.edge_id, round_id])
        params = global_params
        owned = self.owned
        loss = 0.0
        for _ in range(self.local_steps):
            idx = rng.integers(0, len(self.x),
                               size=min(self.local_batch, len(self.x)))
            params, loss = self.local_step(
                params, self.x[idx], self.y[idx], owned)
        delta = jax.tree_util.tree_map(
            lambda new, old: np.asarray(new, np.float32)
            - np.asarray(old, np.float32),
            params, global_params)
        if attack == "grad_scale":
            delta = jax.tree_util.tree_map(
                lambda d: np.asarray(d * attack_scale, np.float32), delta)
        elif attack == "sign_flip":
            delta = jax.tree_util.tree_map(
                lambda d: np.asarray(-attack_scale * d, np.float32), delta)
        elif attack is not None and attack != "none":
            raise ValueError(f"unknown update attack {attack!r}")
        return delta, float(loss)

    def publish(self, delta, round_id: int):
        """Upload the round's delta as ``fed/delta/{edge}`` version
        ``round_id`` (chunk-dedup path; zero chunks are shared across
        all edges).  Returns the chunk manifest."""
        return self.store.put_version(
            f"fed/delta/{self.edge_id}", delta, round_id)
