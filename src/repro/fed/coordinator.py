"""Federated B-MoE training rounds with ledger-verified aggregation.

One ``FedCoordinator`` round:

1. **Plan** — every non-evicted edge draws (dropout?, speed) from a
   seeded per-(round, edge) stream.  Dropped edges go silent for the
   round; slow edges model stragglers (``straggler_factor`` x compute
   time, plus always-slow ``slow_edges``).
2. **Local training** — each participating edge trains its Dirichlet
   shard with the expert-masked local step and publishes its weight
   delta through the chunk-dedup store (``fed/delta/{edge}`` @ round).
3. **Deadline** — deltas whose modeled arrival (compute + upload
   seconds) beats ``deadline_s`` are received; the rest straggle.  A
   straggler's delta is carried into the next round (``late_policy=
   "carry"``) or dropped; ``evict_after`` consecutive late rounds evicts
   the edge so the round clock NEVER waits on a sick device.
4. **Quorum** — fewer than ``min_quorum`` received deltas makes the
   round a committed no-op (global parameters unchanged, received deltas
   carry forward); the clock still advances.
5. **Verified aggregation** — the executor (rotating bonded edge) runs
   the aggregation rule and commits a Merkle root over the resulting
   ``(N + 1, P)`` parameter rows; the round block also carries
   ``aggregation_root`` — one root binding (participant set, per-edge
   delta manifest CIDs, result root).  Delta manifests are retained for
   the challenge window.  ``VerifierPool`` auditors later recompute the
   aggregation from the committed manifests off the critical path; a
   dishonest aggregator (result substitution, or skipping the poison
   screen for a colluding edge) becomes a confirmed fraud proof, and the
   court (``resolve_by_recompute``) slashes it and rolls back: the
   coordinator restores the round's snapshot and re-executes every
   voided round honestly — the paper's claim that aggregation needs no
   trusted server, only a bonded one.

The adversary model is split across layers on purpose: poisoned
*updates* are the aggregation rule's problem (clip + cosine screen —
``fed.aggregate``), a poisoned *aggregator* is the trust layer's
problem (commit/audit/slash/rollback).  A colluding aggregator that
"forgets" to screen an accomplice's poison is caught by the second
layer: auditors recompute with the honest rule, the roots differ, the
fraud proof lands.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import experts as ex
from repro.core.consensus import ProofOfWork
from repro.core.ledger import Ledger, digest_tree
from repro.core.reputation import ReputationConfig, ReputationLedger
from repro.data.synthetic import dirichlet_shards
from repro.fed.aggregate import (aggregate, aggregation_root,
                                 aggregation_task_digest, commit_rows,
                                 flat_to_tree, make_recompute, tree_to_flat)
from repro.fed.edge import DeltaRecord, FedEdge
from repro.models.builder import materialize
from repro.obs import CounterGroup, Observability
from repro.storage import ExpertStore, NetworkCostModel, StorageNetwork
from repro.train.step import make_fed_local_step
from repro.trust.protocol import (TERMINAL_PHASES, OptimisticProtocol,
                                  RoundPhase, TrustConfig)


@dataclasses.dataclass(frozen=True)
class FedAttack:
    """What the adversary controls this run."""
    malicious_edges: Tuple[int, ...] = ()
    update_attack: str = "none"        # none | grad_scale | sign_flip
    scale: float = 20.0                # poison magnitude multiplier
    dishonest_aggregator: bool = False
    # substitute: commit honest-looking garbage instead of the real
    #   aggregate.  unscreened: run plain FedAvg (no clip, no screen) so
    #   a colluding edge's poison lands — both diverge from the
    #   committed rule and are provable by recompute.
    aggregator_mode: str = "substitute"
    substitute_std: float = 0.1


@dataclasses.dataclass(frozen=True)
class FedConfig:
    # population / model
    num_edges: int = 8
    num_experts: int = 8
    experts_per_edge: int = 2
    top_k: int = 2
    in_dim: int = 784
    hidden: int = 32
    num_classes: int = 10
    lr: float = 0.2
    local_steps: int = 4
    local_batch: int = 64
    alpha: float = 0.5                 # Dirichlet non-IID concentration
    seed: int = 0
    # aggregation
    rule: str = "defended"             # defended | fedavg
    clip_mult: float = 3.0
    cos_min: float = 0.0
    min_quorum: int = 2
    # robustness injection (modeled round clock, deterministic)
    deadline_s: float = 1.0
    base_step_s: float = 0.02          # modeled seconds per local step
    straggler_prob: float = 0.0
    straggler_factor: float = 25.0
    slow_edges: Tuple[int, ...] = ()   # always-straggling edges
    dropout_prob: float = 0.0
    evict_after: int = 3               # consecutive late rounds -> evict
    late_policy: str = "carry"         # carry | drop
    # verification / chain
    verify: str = "optimistic"         # optimistic | off
    trust: TrustConfig = dataclasses.field(
        default_factory=lambda: TrustConfig(chunks_per_expert=4))
    attack: FedAttack = dataclasses.field(default_factory=FedAttack)
    pow_difficulty: int = 6
    # storage
    storage_nodes: int = 4
    replication: int = 2
    chunk_bytes: int = 1 << 14


class FedCoordinator:
    """Runs federated rounds; owns the global model, the chain, the
    store and the trust protocol (namespace ``trust.fed``)."""

    def __init__(self, cfg: FedConfig, x, y,
                 obs: Optional[Observability] = None):
        if cfg.experts_per_edge < 1:
            raise ValueError("experts_per_edge must be >= 1")
        self.cfg = cfg
        self.obs = obs if obs is not None else Observability()
        key = jax.random.PRNGKey(cfg.seed)
        kg, ke = jax.random.split(key)
        experts, self.apply_all = ex.make_expert_bank(
            "mlp", cfg.num_experts, ke, in_dim=cfg.in_dim,
            hidden=cfg.hidden, out=cfg.num_classes)
        gate = materialize(ex.gate_decl(cfg.in_dim, cfg.num_experts), kg)
        self.global_params = {"gate": gate, "experts": experts}
        # storage + chain
        self.storage = StorageNetwork(
            num_nodes=cfg.storage_nodes, replication=cfg.replication,
            seed=cfg.seed, cost=NetworkCostModel(),
            metrics=self.obs.metrics)
        self.store = ExpertStore(self.storage, chunk_bytes=cfg.chunk_bytes,
                                 metrics=self.obs.metrics)
        self.ledger = Ledger()
        self.pow = ProofOfWork(cfg.num_edges,
                               difficulty_bits=cfg.pow_difficulty,
                               seed=cfg.seed)
        # trust
        if cfg.verify == "optimistic":
            self.reputation = ReputationLedger(cfg.num_edges,
                                               ReputationConfig())
            self.protocol: Optional[OptimisticProtocol] = OptimisticProtocol(
                cfg.trust, cfg.num_edges, reputation=self.reputation,
                chained=True, metrics=self.obs.metrics,
                namespace="trust.fed")
        else:
            self.reputation = None
            self.protocol = None
        # edges: Dirichlet shards + rotating expert ownership
        y = np.asarray(y)
        xflat = np.asarray(x, np.float32).reshape(len(y), -1)
        shards = dirichlet_shards(y, cfg.num_edges, alpha=cfg.alpha,
                                  seed=cfg.seed)
        local_step = make_fed_local_step(cfg.num_experts, cfg.top_k,
                                         cfg.lr, self.apply_all)
        self.edges: List[FedEdge] = []
        for m in range(cfg.num_edges):
            owned = np.zeros(cfg.num_experts, np.float32)
            for j in range(cfg.experts_per_edge):
                owned[(m + j * cfg.num_edges // cfg.experts_per_edge)
                      % cfg.num_experts] = 1.0
            self.edges.append(FedEdge(
                m, xflat[shards[m]], y[shards[m]], owned, self.store,
                local_step, local_steps=cfg.local_steps,
                local_batch=cfg.local_batch, seed=cfg.seed))
        self._delta_like = jax.tree_util.tree_map(
            lambda a: np.zeros(a.shape, np.float32), self.global_params)
        # round state
        self.round = 0
        self._carry: List[DeltaRecord] = []
        self._evicted: set = set()
        self._late_streak: Dict[int, int] = {m: 0
                                             for m in range(cfg.num_edges)}
        self._round_ctx: Dict[int, dict] = {}       # snapshots + closures
        self._retained: Dict[int, List[str]] = {}   # rid -> manifest cids
        self.stats = CounterGroup(
            {"rounds": 0, "deltas_received": 0, "stragglers": 0,
             "dropouts": 0, "evictions": 0, "carried_deltas": 0,
             "quorum_failures": 0, "rejected_updates": 0, "retries": 0,
             "convictions": 0, "replayed_rounds": 0},
            self.obs.metrics, "fed")
        self._eval_fn = None

    # ------------------------------------------------------------- plan
    def _round_plan(self, rid: int) -> List[Tuple[int, bool, float]]:
        """(edge, dropped, speed) per non-evicted edge — a pure function
        of (cfg, rid, evicted-set), so a rollback replay that restored
        the eviction state reproduces the round exactly."""
        cfg = self.cfg
        plan = []
        for m in range(cfg.num_edges):
            if m in self._evicted:
                continue
            rng = np.random.default_rng([cfg.seed, 7, rid, m])
            dropped = bool(rng.random() < cfg.dropout_prob)
            slow = (m in cfg.slow_edges
                    or bool(rng.random() < cfg.straggler_prob))
            speed = (cfg.straggler_factor if slow
                     else float(rng.uniform(0.6, 1.4)))
            plan.append((m, dropped, speed))
        return plan

    def _attack_for(self, m: int) -> Optional[str]:
        atk = self.cfg.attack
        if m in atk.malicious_edges and atk.update_attack != "none":
            return atk.update_attack
        return None

    # ------------------------------------------------------------ round
    def run_round(self) -> dict:
        rid = self.round
        with self.obs.span("fed-round", metric="fed.round_s", round=rid):
            summary = self._execute_round(rid, honest=False)
            if self.protocol is not None:
                summary["trust"] = self._drain_trust(rid)
                self.protocol.advance(rid)
            self._prune_closed_rounds()
        self.round += 1
        self.stats["rounds"] += 1
        return summary

    def _execute_round(self, rid: int, honest: bool) -> dict:
        """Run one round.  ``honest=True`` is the rollback-replay path:
        no attack, no commitment, no chain blocks, no counters — just the
        honest state transition the convicted executor should have
        produced."""
        cfg = self.cfg
        book = not honest
        ctx = {"base": self.global_params,
               "carry_in": list(self._carry),
               "evicted": set(self._evicted),
               "late": dict(self._late_streak)}
        plan = self._round_plan(rid)
        # ---- local training + publication
        produced: List[DeltaRecord] = []
        dropouts, stragglers = [], []
        with self.obs.span("fed-local-train", metric="fed.train_s",
                           round=rid, edges=len(plan)):
            for m, dropped, speed in plan:
                if dropped:
                    dropouts.append(m)
                    if book:
                        self.stats["dropouts"] += 1
                    continue
                edge = self.edges[m]
                attack = None if honest else self._attack_for(m)
                delta, loss = edge.local_update(
                    self.global_params, rid, attack=attack,
                    attack_scale=cfg.attack.scale)
                manifest = edge.publish(delta, rid)
                arrival = (cfg.local_steps * cfg.base_step_s * speed
                           + self.storage.cost.seconds(
                               manifest.total_bytes))
                produced.append(DeltaRecord(
                    edge=m, round_id=rid, base_round=rid,
                    manifest_cid=manifest.manifest_cid,
                    num_samples=edge.num_samples, arrival_s=arrival,
                    loss=loss))
        # ---- deadline: received now vs straggled
        fresh: List[DeltaRecord] = []
        late: List[DeltaRecord] = []
        for rec in produced:
            (fresh if rec.arrival_s <= cfg.deadline_s
             else late).append(rec)
        # a fresh arrival supersedes the same edge's stale carried delta
        # (never aggregate one edge twice — double-weighting would also
        # let a poisoner's carried+fresh copies gang up on the median)
        fresh_edges = {rec.edge for rec in fresh}
        received = []
        for rec in self._carry:
            if rec.edge in fresh_edges:
                self.store.release(rec.manifest_cid)
            else:
                received.append(rec)
        self._carry = []
        received.extend(fresh)
        on_time = {rec.edge for rec in fresh}
        for rec in late:
            stragglers.append(rec.edge)
            if book:
                self.stats["stragglers"] += 1
            self._late_streak[rec.edge] += 1
            if self._late_streak[rec.edge] >= cfg.evict_after:
                self._evicted.add(rec.edge)
                if book:
                    self.stats["evictions"] += 1
            elif cfg.late_policy == "carry":
                # lands in the NEXT round's received set; retained so the
                # edge's next-round publish cannot GC it out from under
                # the carry queue (every record in ``_carry`` holds
                # exactly one retention ref)
                self.store.retain(rec.manifest_cid)
                self._carry.append(rec)
                if book:
                    self.stats["carried_deltas"] += 1
        for m in on_time:
            self._late_streak[m] = 0
        summary = {"round": rid, "participants": [m for m, _, _ in plan],
                   "received": [rec.edge for rec in received],
                   "stragglers": stragglers, "dropouts": dropouts,
                   "evicted": sorted(self._evicted), "quorum": True,
                   "rejected": [], "executor": None}
        if book:
            self.stats["deltas_received"] += len(received)
        # ---- quorum gate
        if len(received) < cfg.min_quorum:
            summary["quorum"] = False
            # received deltas are not lost: they carry forward.  Fresh
            # arrivals (produced this round) enter the carry queue for
            # the first time and take their retention ref; carried-in
            # records keep the ref they already hold.
            for rec in received:
                if rec.round_id == rid:
                    self.store.retain(rec.manifest_cid)
            self._carry.extend(received)
            if book:
                self.stats["quorum_failures"] += 1
                self._mine({"kind": "fed_round", "round": rid,
                            "quorum": False,
                            "received": summary["received"],
                            "stragglers": stragglers,
                            "dropouts": dropouts})
            ctx["received"] = []
            self._round_ctx[rid] = ctx
            return summary
        # ---- aggregation (the committed computation)
        received.sort(key=lambda rec: (rec.edge, rec.base_round))
        with self.obs.span("fed-aggregate", metric="fed.aggregate_s",
                           round=rid, deltas=len(received)):
            before = self.storage.stats["retries"]
            deltas = [self.store.fetch_manifest(
                self.store.manifest_by_cid(rec.manifest_cid),
                self._delta_like) for rec in received]
            if book:
                self.stats["retries"] += (self.storage.stats["retries"]
                                          - before)
            weights = [rec.num_samples for rec in received]
            honest_new, info = aggregate(
                ctx["base"], deltas, weights, rule=cfg.rule,
                clip_mult=cfg.clip_mult, cos_min=cfg.cos_min)
        summary["rejected"] = [received[i].edge for i in info.rejected]
        if book:
            self.stats["rejected_updates"] += len(info.rejected)
        executor = (self.protocol.pick_executor(rid)
                    if self.protocol is not None
                    else rid % cfg.num_edges)
        summary["executor"] = executor
        claimed_new = honest_new
        atk = cfg.attack
        if (book and atk.dishonest_aggregator
                and executor in atk.malicious_edges):
            if atk.aggregator_mode == "substitute":
                rng = np.random.default_rng([cfg.seed, 13, rid])
                flat = tree_to_flat(honest_new)
                flat = flat + rng.normal(
                    0.0, atk.substitute_std, size=flat.shape
                ).astype(np.float32)
                claimed_new = flat_to_tree(flat, honest_new)
            elif atk.aggregator_mode == "unscreened":
                claimed_new, _ = aggregate(
                    ctx["base"], deltas, weights, rule="fedavg")
            else:
                raise ValueError(
                    f"unknown aggregator_mode {atk.aggregator_mode!r}")
        # ---- commit + schedule audit (never on the replay path: the
        # convicted round keeps its original commitment and verdict)
        cids = [rec.manifest_cid for rec in received]
        if book and self.protocol is not None:
            rows = commit_rows(claimed_new, cfg.num_experts)
            task = aggregation_task_digest(
                rid, [rec.edge for rec in received], cids, cfg.rule,
                cfg.clip_mult, cfg.cos_min, digest_tree(ctx["base"]))
            state = self.protocol.commit(rid, executor, rows,
                                         task_digest=task)
            recompute = make_recompute(
                self.store, ctx["base"], received, self._delta_like,
                cfg.num_experts, rule=cfg.rule, clip_mult=cfg.clip_mult,
                cos_min=cfg.cos_min)
            self.protocol.schedule_audit(rid, recompute)
            ctx["recompute"] = recompute
            for cid in cids:
                self.store.retain(cid)
            self._retained[rid] = cids
            agg_root = aggregation_root([rec.edge for rec in received],
                                        cids, state.commitment.root)
            summary["agg_root"] = agg_root
            if book:
                self._mine({"kind": "fed_round", "round": rid,
                            "quorum": True, "executor": executor,
                            "agg_root": agg_root[:16],
                            "result_root": state.commitment.root[:16],
                            "received": summary["received"],
                            "delta_cids": [c[:16] for c in cids],
                            "rejected": summary["rejected"],
                            "stragglers": stragglers,
                            "dropouts": dropouts})
        elif book:
            self._mine({"kind": "fed_round", "round": rid,
                        "quorum": True, "executor": executor,
                        "received": summary["received"],
                        "rejected": summary["rejected"],
                        "stragglers": stragglers, "dropouts": dropouts})
        # a consumed carried record gives up its carry-queue ref — the
        # round's own commit retention (above) now keeps it auditable
        for rec in received:
            if rec.round_id < rid:
                self.store.release(rec.manifest_cid)
        # ---- adopt the (claimed) new global state, optimistically
        self.global_params = jax.tree_util.tree_map(
            lambda a: np.asarray(a, np.float32), claimed_new)
        self._eval_fn = None
        ctx["received"] = received
        self._round_ctx[rid] = ctx
        return summary

    # ------------------------------------------------------------ trust
    def _drain_trust(self, now: Optional[int]) -> dict:
        """Audit drain -> court -> chained rollback replay -> rollback
        blocks.  Audits run off-path (concurrent with the next round's
        training in deployment), so their seconds are excluded from the
        enclosing round span's metric."""
        p = self.protocol
        out = {"audited": [], "convicted": [], "invalidated": []}
        jobs = p.pop_audit_jobs(now)
        if jobs:
            with self.obs.span("fed-audit-drain", metric="fed.audit_s",
                               off_path=True, drained=len(jobs)):
                for job in jobs:
                    reports = p.verifiers.audit(
                        p.rounds[job.round_id].commitment,
                        job.recompute_fn)
                    p.apply_reports(job.round_id, reports,
                                    job.recompute_fn)
                    out["audited"].append(job.round_id)
        challenged = sorted(
            rid for rid in out["audited"]
            if p.rounds[rid].phase is RoundPhase.CHALLENGED)
        n_rollbacks = len(p.rollbacks)
        for rid in challenged:
            if p.rounds[rid].phase is not RoundPhase.CHALLENGED:
                continue               # voided by an earlier conviction
            state = p.resolve_by_recompute(
                rid, self._round_ctx[rid]["recompute"])
            if state.phase is RoundPhase.ROLLED_BACK:
                out["convicted"].append(rid)
        for rec in p.rollbacks[n_rollbacks:]:
            out["invalidated"].extend(rec.invalidated)
        if out["convicted"]:
            self.stats["convictions"] += len(out["convicted"])
            with self.obs.span("fed-rollback-replay",
                               metric="fed.chain_s",
                               convicted=len(out["convicted"])):
                self._replay_chain(min(out["convicted"]))
            for rec in p.rollbacks[n_rollbacks:]:
                self._mine({"kind": "rollback", "domain": "fed",
                            "rollback_of": rec.round_id,
                            "executor": rec.executor,
                            "chain": [rec.round_id] + rec.invalidated,
                            "invalidated": rec.invalidated,
                            "slashed": [rec.executor],
                            "at_round": self.round})
        return out

    def _replay_chain(self, first: int) -> None:
        """Restore the snapshot entering the first convicted round and
        re-execute it and every later non-terminal-finalized round
        honestly (deltas are reproducible from seeds; ``put_version``
        replaces the voided delta versions in place)."""
        ctx = self._round_ctx[first]
        self.global_params = ctx["base"]
        # rebalance carry-queue retention: the abandoned lineage's queue
        # gives up its refs, the restored queue takes fresh ones (its
        # manifests are still alive under round ``first``'s commit
        # retention, which outlives the replay)
        for rec in self._carry:
            self.store.release(rec.manifest_cid)
        for rec in ctx["carry_in"]:
            self.store.retain(rec.manifest_cid)
        self._carry = list(ctx["carry_in"])
        self._evicted = set(ctx["evicted"])
        self._late_streak = dict(ctx["late"])
        self._eval_fn = None
        for rid in sorted(r for r in self._round_ctx if r >= first):
            self._execute_round(rid, honest=True)
            self.stats["replayed_rounds"] += 1

    # ----------------------------------------------------------- finish
    def flush_trust(self) -> dict:
        """Close every open challenge window (end of run)."""
        if self.protocol is None:
            return {}
        out = self._drain_trust(None)
        horizon = self.protocol.clock + self.cfg.trust.challenge_window
        out["finalized"] = self.protocol.advance(horizon)
        self._prune_closed_rounds()
        return out

    def _prune_closed_rounds(self) -> None:
        """Release delta-manifest retention (and drop replay snapshots)
        for rounds that reached a terminal phase — their challenge
        window is settled, auditors no longer need the inputs."""
        if self.protocol is None:
            horizon = self.round
            closed = [rid for rid in self._round_ctx if rid < horizon]
        else:
            closed = [rid for rid in self._round_ctx
                      if (st := self.protocol.rounds.get(rid)) is not None
                      and st.phase in TERMINAL_PHASES]
            closed += [rid for rid in self._round_ctx
                       if rid not in self.protocol.rounds
                       and rid < self.round]       # quorum no-ops
        for rid in closed:
            for cid in self._retained.pop(rid, []):
                self.store.release(cid)
            self._round_ctx.pop(rid, None)

    # ------------------------------------------------------------- eval
    def evaluate(self, x, y, batch: int = 512) -> float:
        """Top-1 accuracy of the current global model."""
        if self._eval_fn is None:
            params = jax.tree_util.tree_map(np.asarray, self.global_params)

            @jax.jit
            def fwd(xb):
                logits = ex.gate_apply(params["gate"], xb)
                w, _ = ex.sparse_gate_weights(logits, self.cfg.top_k)
                outs = self.apply_all(params["experts"], xb)
                import jax.numpy as jnp
                return jnp.einsum("bn,nbc->bc", w, outs)

            self._eval_fn = fwd
        y = np.asarray(y)
        xflat = np.asarray(x, np.float32).reshape(len(y), -1)
        correct = 0
        for i in range(0, len(y), batch):
            pred = np.argmax(np.asarray(self._eval_fn(xflat[i:i + batch])),
                             axis=1)
            correct += int((pred == y[i:i + batch]).sum())
        return correct / max(len(y), 1)

    # ------------------------------------------------------------ chain
    def _mine(self, payload: dict):
        if self.obs.enabled:
            payload = dict(payload, trace_id=self.obs.trace.trace_id,
                           span_id=self.obs.trace.current_span_id())
        block = self.pow.mine(len(self.ledger.blocks),
                              self.ledger.head.hash, payload)
        self.ledger.append(block)
        return block

    # ---------------------------------------------------------- reports
    def obs_report(self) -> dict:
        report = {"rounds": self.round,
                  "fed": dict(self.stats),
                  "metrics": self.obs.metrics.snapshot(),
                  "storage": {"network": dict(self.storage.stats),
                              "store": dict(self.store.stats)},
                  "chain": {"blocks": len(self.ledger.blocks),
                            "valid": self.ledger.verify_chain()}}
        if self.protocol is not None:
            report["trust"] = dict(self.protocol.stats)
        return report
