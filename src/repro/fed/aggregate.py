"""Deterministic federated aggregation — the committed computation.

Everything here is plain float32/float64 numpy: the aggregation an
executor commits must be bit-reproducible by any auditor holding the
same inputs (the per-edge delta manifests retained in the chunk store),
so no jit, no device math, no wall-clock anywhere on this path.

Two rules:

- ``fedavg``: the undefended baseline — sample-count-weighted average of
  every received delta.  One gradient-scaled poison is enough to wreck
  the global model.
- ``defended``: median-norm clipping (a delta's global scale is bounded
  by ``clip_mult`` x the received median norm — caps gradient-scaling
  influence) followed by a coordinate-median cosine screen (a delta
  pointing *against* the received median direction — the sign-flip
  attack — is rejected outright).  The surviving set is fedavg'd with
  renormalized weights.

Conservation invariant (property-tested): the aggregated delta is a
convex combination of the accepted (clipped) deltas — the mixing
coefficients always sum to 1 over the accepted subset, whatever subset
of edges actually arrived.  An empty accepted set aggregates to the
zero delta (the round is a no-op, never a crash).

``commit_rows`` flattens an aggregated parameter set into the
``(num_experts + 1, P)`` tensor the aggregator commits through
``commit_outputs`` (row ``e`` = expert ``e``'s parameters, last row =
the gate, zero-padded): Merkle leaves are contiguous parameter chunks,
and a fraud proof pinpoints the expert whose aggregated weights were
tampered with.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.ledger import digest_bytes
from repro.trust.commitments import MerkleTree


def tree_to_flat(tree) -> np.ndarray:
    """Flatten a pytree of arrays into one float32 vector (tree_leaves
    order — deterministic for a fixed tree structure)."""
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    return np.concatenate(
        [np.asarray(leaf, np.float32).ravel() for leaf in leaves])


def flat_to_tree(flat: np.ndarray, like):
    """Inverse of ``tree_to_flat`` against a template tree."""
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, off = [], 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        out.append(np.asarray(flat[off:off + n],
                              np.float32).reshape(leaf.shape))
        off += n
    if off != len(flat):
        raise ValueError(f"flat vector has {len(flat)} entries, template "
                         f"needs {off}")
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class AggregationInfo:
    """What the rule decided, for the round block and the attack bench."""
    accepted: List[int]                # indices into the received list
    rejected: List[int]                # screened out (cosine test)
    clip: List[float]                  # per-delta scale factor applied
    coeffs: List[float]                # mixing weight per received delta
    #                                    (0 for rejected; sums to 1 over
    #                                     accepted unless all rejected)
    norms: List[float]                 # pre-clip delta norms


def aggregate(base, deltas: Sequence, weights: Sequence[float], *,
              rule: str = "defended", clip_mult: float = 3.0,
              cos_min: float = 0.0) -> Tuple[Dict, AggregationInfo]:
    """Aggregate ``deltas`` (pytrees matching ``base``) onto ``base``.

    Returns ``(new_params, info)`` with ``new_params`` an all-float32
    numpy pytree.  Deterministic: float64 accumulation, float32 result.
    """
    if not deltas:
        flat = tree_to_flat(base).astype(np.float64)
        return flat_to_tree(flat.astype(np.float32), base), AggregationInfo(
            accepted=[], rejected=[], clip=[], coeffs=[], norms=[])
    if len(deltas) != len(weights):
        raise ValueError(f"{len(deltas)} deltas, {len(weights)} weights")
    flats = np.stack([tree_to_flat(d) for d in deltas]).astype(np.float64)
    w = np.asarray(weights, np.float64)
    m = len(deltas)
    norms = np.linalg.norm(flats, axis=1)
    if rule == "fedavg":
        clip = np.ones(m)
        accepted = list(range(m))
    elif rule == "defended":
        med = float(np.median(norms))
        clip = np.ones(m)
        if med > 0:
            clip = np.minimum(1.0, clip_mult * med
                              / np.maximum(norms, 1e-12))
        clipped = flats * clip[:, None]
        mu = np.median(clipped, axis=0)
        mu_norm = float(np.linalg.norm(mu))
        accepted = []
        for i in range(m):
            ni = float(np.linalg.norm(clipped[i]))
            if ni == 0.0 or mu_norm == 0.0:
                cos = 1.0              # a zero delta (or degenerate
                #                        median) carries no direction to
                #                        screen against — keep it
            else:
                cos = float(clipped[i] @ mu) / (ni * mu_norm)
            if cos >= cos_min:
                accepted.append(i)
        flats = clipped
    else:
        raise ValueError(f"unknown aggregation rule {rule!r}")
    coeffs = np.zeros(m)
    if accepted:
        wa = w[accepted]
        total = float(wa.sum())
        coeffs[accepted] = (wa / total if total > 0
                            else np.full(len(accepted),
                                         1.0 / len(accepted)))
    agg = (coeffs[:, None] * flats).sum(axis=0)
    new_flat = tree_to_flat(base).astype(np.float64) + agg
    info = AggregationInfo(
        accepted=accepted,
        rejected=[i for i in range(m) if i not in accepted],
        clip=[float(c) for c in clip],
        coeffs=[float(c) for c in coeffs],
        norms=[float(n) for n in norms])
    return flat_to_tree(new_flat.astype(np.float32), base), info


# ------------------------------------------------------- commitment view
def commit_rows(params, num_experts: int) -> np.ndarray:
    """The aggregated result as the ``(N + 1, P)`` float32 tensor the
    aggregator commits: row ``e`` is expert ``e``'s flattened parameters,
    the last row is the flattened gate, both zero-padded to the common
    width ``P``.  Chunking the P axis gives Merkle leaves that are
    contiguous parameter slices of one object — a fraud proof names the
    expert (or the gate) whose aggregated weights are wrong."""
    import jax
    eleaves = [np.asarray(leaf, np.float32)
               for leaf in jax.tree_util.tree_leaves(params["experts"])]
    expert_rows = [np.concatenate([leaf[e].ravel() for leaf in eleaves])
                   for e in range(num_experts)]
    gate_row = tree_to_flat(params["gate"])
    width = max(len(expert_rows[0]), len(gate_row))
    rows = np.zeros((num_experts + 1, width), np.float32)
    for e, row in enumerate(expert_rows):
        rows[e, :len(row)] = row
    rows[num_experts, :len(gate_row)] = gate_row
    return rows


def make_recompute(store, base, records, like, num_experts: int, *,
                   rule: str, clip_mult: float, cos_min: float):
    """Eager ``RecomputeFn`` for auditing one aggregation round: fetch
    every participant's delta by its COMMITTED manifest CID (retained for
    the challenge window), re-run the rule, and serve the requested slice
    of the recomputed ``commit_rows``.  The full recompute is cached —
    per-leaf audit cost after the first sampled leaf is a slice."""
    cache: Dict[str, np.ndarray] = {}

    def recompute(e: int, sl: slice) -> np.ndarray:
        rows = cache.get("rows")
        if rows is None:
            deltas = [store.fetch_manifest(
                store.manifest_by_cid(rec.manifest_cid), like)
                for rec in records]
            new, _ = aggregate(base, deltas,
                               [rec.num_samples for rec in records],
                               rule=rule, clip_mult=clip_mult,
                               cos_min=cos_min)
            rows = commit_rows(new, num_experts)
            cache["rows"] = rows
        return rows[e, sl]

    return recompute


def aggregation_root(participants: Sequence[int],
                     manifest_cids: Sequence[str],
                     result_root: str) -> str:
    """The on-chain aggregation commitment: one Merkle root over
    (participant set, per-edge delta manifest CIDs, aggregated-result
    commitment root) — anyone holding the round block can check that an
    auditor's inputs are exactly the committed ones."""
    leaves = [digest_bytes(b"fed-participants:"
                           + ",".join(str(p) for p in participants).encode())]
    leaves += [digest_bytes(b"fed-delta:" + cid.encode())
               for cid in manifest_cids]
    leaves.append(digest_bytes(b"fed-result:" + result_root.encode()))
    return MerkleTree(leaves).root


def aggregation_task_digest(round_id: int, participants: Sequence[int],
                            manifest_cids: Sequence[str], rule: str,
                            clip_mult: float, cos_min: float,
                            base_digest: str) -> str:
    """Binds the committed computation: which deltas, which rule, which
    base parameters.  Travels in the result commitment's task digest."""
    blob = "|".join([
        f"round={round_id}", f"rule={rule}", f"clip={clip_mult!r}",
        f"cos={cos_min!r}", f"base={base_digest}",
        "participants=" + ",".join(str(p) for p in participants),
        "cids=" + ",".join(manifest_cids)])
    return digest_bytes(b"fed-task:" + blob.encode())
