"""repro.fed — federated B-MoE edge training with verified aggregation.

Edges train local expert subsets on non-IID Dirichlet shards and
publish weight deltas through the chunk-dedup store; a bonded
aggregator commits a Merkle root over (participants, delta manifest
CIDs, aggregated result) and the trust layer's auditors recompute the
aggregation off-path — dishonest aggregation becomes a fraud proof,
slash and chained rollback.  Rounds tolerate stragglers (deadline +
carry/evict), dropouts (quorum aggregation) and poisoned updates
(median-norm clip + cosine screen).  See ``fed/coordinator.py`` for the
round lifecycle and ``trust/README.md`` ("Verified aggregation").
"""
from repro.fed.aggregate import (AggregationInfo, aggregate,
                                 aggregation_root, aggregation_task_digest,
                                 commit_rows, flat_to_tree, make_recompute,
                                 tree_to_flat)
from repro.fed.coordinator import FedAttack, FedConfig, FedCoordinator
from repro.fed.edge import DeltaRecord, FedEdge

__all__ = [
    "AggregationInfo", "aggregate", "aggregation_root",
    "aggregation_task_digest", "commit_rows", "flat_to_tree",
    "make_recompute", "tree_to_flat",
    "FedAttack", "FedConfig", "FedCoordinator",
    "DeltaRecord", "FedEdge",
]
