"""Pallas TPU kernel: blockwise online-softmax (flash) attention for
training/prefill, with causal + sliding-window masking, GQA, and logit
softcap.

Layout: q (B, H, Sq, D), k/v (B, KH, Sk, D).  Grid (B*H, nq, nk) with
the kv dimension innermost: running max / denominator / accumulator live
in VMEM scratch across kv steps; output is written on the last kv step.
Block sizes default to (128, 128) — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, softcap, bq, bk, nk):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                     # (bq, D)
    k = k_ref[0, 0]                                     # (bk, D)
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qi * bq + jnp.arange(bq)
    kpos = ki * bk + jnp.arange(bk)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    bq=128, bk=128, interpret=None):
    """q: (B, H, Sq, D); k, v: (B, KH, Sk, D) -> (B, H, Sq, D)."""
    interpret = resolve_interpret(interpret)
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    bq, bk = min(bq, Sq), min(bk, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError("seq lengths must divide block sizes")
    nq, nk = Sq // bq, Sk // bk
    grid = (B * H, nq, nk)
    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
