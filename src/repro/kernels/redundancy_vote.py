"""Pallas TPU kernel: pairwise-agreement counting for the B-MoE
redundancy consensus (the paper's Step 3 hot spot).

For each expert, R published copies of its result must be compared
pairwise to find the majority-consistent one.  The heavy part is the
elementwise comparison reduce over the result tensor (R^2 x T compares);
this kernel tiles T through VMEM and accumulates the (R, R) agreement
counts across grid steps.  The winner selection (argmax + gather) is a
tiny jnp epilogue in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret

DEFAULT_TILE = 1024


def _agree_kernel(pub_ref, out_ref, *, atol: float):
    t = pl.program_id(1)
    blk = pub_ref[0]                                   # (M, Tt)
    agree = (jnp.abs(blk[:, None, :] - blk[None, :, :]) <= atol)
    counts = agree.sum(axis=-1).astype(jnp.int32)      # (M, M)

    @pl.when(t == 0)
    def _init():
        out_ref[0] = counts

    @pl.when(t != 0)
    def _acc():
        out_ref[0] = out_ref[0] + counts


def pairwise_agreement(pub: jax.Array, *, atol: float = 0.0,
                       tile: int = DEFAULT_TILE,
                       interpret: bool | None = None) -> jax.Array:
    """pub: (E, M, T) -> (E, M, M) int32 agreement counts.

    Padding note: T is zero-padded to a tile multiple; padded positions
    agree for *every* pair, adding a constant to all counts — harmless
    for the argmax and corrected in ops.redundancy_vote's exact-match
    test (counts == padded_T  <=>  agree on all real elements).
    """
    interpret = resolve_interpret(interpret)
    E, M, T = pub.shape
    tile = min(tile, max(T, 1))
    pad = (-T) % tile
    if pad:
        pub = jnp.pad(pub, ((0, 0), (0, 0), (0, pad)))
    Tp = T + pad
    grid = (E, Tp // tile)
    return pl.pallas_call(
        functools.partial(_agree_kernel, atol=atol),
        grid=grid,
        in_specs=[pl.BlockSpec((1, M, tile), lambda e, t: (e, 0, t))],
        out_specs=pl.BlockSpec((1, M, M), lambda e, t: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((E, M, M), jnp.int32),
        interpret=interpret,
    )(pub)
