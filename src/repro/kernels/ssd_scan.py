"""Pallas TPU kernel: Mamba-2 chunked SSD scan (arXiv:2405.21060).

Grid (B, H, nchunks) with the chunk dimension innermost and the carried
(P, N) state in VMEM scratch: each step evaluates the within-chunk dual
(attention-like) form on a (Q, P) tile and advances the inter-chunk
state recurrence.  Chunk length Q defaults to 128 (MXU/VPU aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, y_ref, state_ref, *,
                Q):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0]                                     # (Q, P)
    dt = dt_ref[0, 0]                                   # (Q,)
    da = da_ref[0, 0]                                   # (Q,)
    Bm = b_ref[0]                                       # (Q, N)
    Cm = c_ref[0]                                       # (Q, N)

    cum = jnp.cumsum(da)                                # (Q,)
    seg = cum[:, None] - cum[None, :]                   # (Q, Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask before exp: above-diagonal seg is positive (overflow risk)
    L = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32) \
        * L * dt[None, :]
    y_intra = jnp.dot(scores, x, preferred_element_type=jnp.float32)
    state = state_ref[...]                              # (P, N)
    y_inter = jnp.exp(cum)[:, None] * jnp.dot(
        Cm, state.T, preferred_element_type=jnp.float32)
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cum[-1]
    w = jnp.exp(total - cum) * dt                       # (Q,)
    ds = jnp.dot((w[:, None] * x).T, Bm,
                 preferred_element_type=jnp.float32)    # (P, N)
    state_ref[...] = jnp.exp(total) * state + ds


def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk=128, interpret=None):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); Bmat/Cmat: (B, S, N).
    Returns y: (B, S, H, P) (f32).  State starts at zero (training)."""
    interpret = resolve_interpret(interpret)
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        raise ValueError(f"S={S} not divisible by chunk={Q}")
    nchunks = S // Q
    da = dt * A[None, None, :]
    # layouts: (B, H, S, P), (B, H, S), (B, S, N)
    xt = jnp.moveaxis(x, 2, 1)
    dtt = jnp.moveaxis(dt, 2, 1)
    dat = jnp.moveaxis(da, 2, 1)
    from jax.experimental.pallas import tpu as pltpu

    grid = (Bsz, H, nchunks)
    y = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, S, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, dat, Bmat, Cmat)
    return jnp.moveaxis(y, 1, 2)
