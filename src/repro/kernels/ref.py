"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the per-kernel allclose sweeps AND the
portable fallback used when not running on TPU (CPU tests, GSPMD
dry-run lowering).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------- redundancy vote
def pairwise_agreement_ref(pub: jax.Array, atol: float = 0.0) -> jax.Array:
    """pub: (E, M, T). Returns (E, M, M) int32 — for each expert e, the
    number of elements on which copies i and j agree (within atol)."""
    diff = jnp.abs(pub[:, :, None, :] - pub[:, None, :, :])
    return (diff <= atol).sum(axis=-1).astype(jnp.int32)


def redundancy_vote_ref(pub: jax.Array, atol: float = 0.0):
    """pub: (E, M, *tail) — expert e's result as published by edge m.

    Replica-level majority vote (paper Step 3): the accepted copy of
    expert e is the one agreeing (on every element) with the largest
    coalition.  Returns (trusted (E, *tail), support (E,) int32).
    """
    E, M = pub.shape[:2]
    flat = pub.reshape(E, M, -1)
    T = flat.shape[-1]
    counts = pairwise_agreement_ref(flat, atol)          # (E, M, M)
    full_agree = (counts == T).astype(jnp.int32)         # exact-copy match
    support_per = full_agree.sum(axis=-1)                # (E, M)
    winner = support_per.argmax(axis=-1)                 # (E,)
    trusted = jnp.take_along_axis(
        flat, winner[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    support = jnp.take_along_axis(support_per, winner[:, None], axis=1)[:, 0]
    return trusted.reshape((E,) + pub.shape[2:]), support


def redundancy_vote_with_flags_ref(pub: jax.Array, atol: float = 0.0):
    """Like redundancy_vote_ref but also returns the per-copy agreement
    flags (E, M): which edge's copy matched the accepted (majority) one —
    the signal the reputation layer consumes (paper §VI-B/D)."""
    E, M = pub.shape[:2]
    flat = pub.reshape(E, M, -1)
    T = flat.shape[-1]
    counts = pairwise_agreement_ref(flat, atol)
    full_agree = (counts == T).astype(jnp.int32)
    support_per = full_agree.sum(axis=-1)
    winner = support_per.argmax(axis=-1)
    trusted = jnp.take_along_axis(
        flat, winner[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    support = jnp.take_along_axis(support_per, winner[:, None], axis=1)[:, 0]
    flags = jnp.take_along_axis(
        full_agree, winner[:, None, None], axis=1)[:, 0]   # (E, M)
    return trusted.reshape((E,) + pub.shape[2:]), support, flags


def redundancy_vote_masked_ref(pub: jax.Array, active: jax.Array,
                               atol: float = 0.0):
    """Vote restricted to ``active`` copies (reputation exclusion,
    paper §VI-D): excluded edges neither count toward majorities nor can
    be elected.  active: (M,) {0,1}.  Returns (trusted, support, flags)."""
    E, M = pub.shape[:2]
    flat = pub.reshape(E, M, -1)
    T = flat.shape[-1]
    counts = pairwise_agreement_ref(flat, atol)
    full_agree = (counts == T).astype(jnp.int32)
    a = active.astype(jnp.int32)
    support_per = (full_agree * a[None, None, :]).sum(axis=-1)   # (E, M)
    score = support_per * a[None, :] - (1 - a[None, :])          # bar excluded
    winner = score.argmax(axis=-1)
    trusted = jnp.take_along_axis(
        flat, winner[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    support = jnp.take_along_axis(support_per, winner[:, None], axis=1)[:, 0]
    flags = jnp.take_along_axis(
        full_agree, winner[:, None, None], axis=1)[:, 0] * a[None, :]
    return trusted.reshape((E,) + pub.shape[2:]), support, flags


# ------------------------------------------------- grouped expert GEMM
def moe_gemm_ref(buf: jax.Array, w: jax.Array) -> jax.Array:
    """buf: (E, C, d), w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", buf, w,
                      preferred_element_type=jnp.float32).astype(buf.dtype)


def moe_mlp_ref(buf, w_gate, w_up, w_down):
    """Full routed-expert SwiGLU: (E,C,d) -> (E,C,d)."""
    h = jax.nn.silu(moe_gemm_ref(buf, w_gate).astype(jnp.float32)) * \
        moe_gemm_ref(buf, w_up).astype(jnp.float32)
    return moe_gemm_ref(h.astype(buf.dtype), w_down)


# ------------------------------------------------- batched audit recompute
def audit_mlp_ref(params, x: jax.Array, gid: jax.Array) -> jax.Array:
    """Grouped gather-MLP oracle: out[s] = mlp(params[gid[s]], x[s]).

    params: stacked {w1 (E,d,h), b1 (E,h), w2 (E,h,o), b2 (E,o)};
    x: (S, C, d); gid: (S,) int32.  This is bit-identical to applying
    the per-expert MLP chunk-by-chunk (the eager audit oracle), which is
    what lets the batched auditor reproduce the executor's leaf digests
    exactly.
    """
    gathered = jax.tree_util.tree_map(lambda a: a[gid], params)

    def one(p, xc):
        h = jax.nn.relu(xc @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    return jax.vmap(one)(gathered, x)


# ------------------------------------------------- flash attention
def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """Naive softmax attention oracle. q: (B,Sq,H,D), k/v: (B,Sk,KH,D)."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    qh = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


# ------------------------------------------------- SSD scan
def ssd_scan_ref(x, dt, A, Bmat, Cmat, state0):
    """Naive sequential SSM recurrence oracle.

    x: (B,S,H,P), dt: (B,S,H), A: (H,), Bmat/Cmat: (B,S,N),
    state0: (B,H,P,N).  y_t = C_t . h_t,  h_t = exp(dt_t A) h_{t-1}
    + dt_t * x_t (outer) B_t.  Returns (y (B,S,H,P), state)."""
    def step(state, inp):
        xt, dtt, Bt, Ct = inp
        decay = jnp.exp(dtt * A)                         # (B, H)
        ds = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, Bt)
        state = state * decay[:, :, None, None] + ds
        y = jnp.einsum("bn,bhpn->bhp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state
