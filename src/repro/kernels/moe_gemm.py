"""Pallas TPU kernel: grouped expert GEMM — the dominant FLOPs of every
MoE architecture (llama4-maverick, qwen2-moe, bmoe-paper).

Computes out[e] = buf[e] @ w[e] for all experts with MXU-aligned
(128 x 128) tiles, accumulating over the contraction dim in an f32 VMEM
block.  Capacity-bucketed token buffers (E, C, d) come from the
scatter-dispatch in repro.models.moe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _mm_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0] += jnp.dot(x_ref[0], w_ref[0],
                        preferred_element_type=jnp.float32)


def moe_gemm(buf: jax.Array, w: jax.Array, *, block_c: int = 128,
             block_d: int = 128, block_f: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """buf: (E, C, d), w: (E, d, f) -> (E, C, f) (f32 accumulate, cast to
    buf dtype)."""
    interpret = resolve_interpret(interpret)
    E, C, d = buf.shape
    _, _, f = w.shape
    block_c, block_d, block_f = (min(block_c, C), min(block_d, d),
                                 min(block_f, f))

    def pad_to(x, axis, b):
        p = (-x.shape[axis]) % b
        if p:
            pads = [(0, 0)] * x.ndim
            pads[axis] = (0, p)
            x = jnp.pad(x, pads)
        return x

    bufp = pad_to(pad_to(buf, 1, block_c), 2, block_d)
    wp = pad_to(pad_to(w, 1, block_d), 2, block_f)
    Cp, dp, fp = bufp.shape[1], bufp.shape[2], wp.shape[2]

    grid = (E, Cp // block_c, fp // block_f, dp // block_d)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, block_d, block_f), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, fp), jnp.float32),
        interpret=interpret,
    )(bufp, wp)
    return out[:, :C, :f].astype(buf.dtype)
