"""Pallas TPU kernel: grouped gather-GEMM for batched audit recompute.

Audit recompute evaluates the paper's 2-layer MLP expert on every
sampled (expert, chunk) pair of a round commitment.  The eager auditor
dispatches one apply per pair; this kernel takes the whole padded batch
of sampled chunks (S, C, d) plus a per-sample group index and fuses the
full expert — relu(x @ w1[g] + b1[g]) @ w2[g] + b2[g] — in one pass:
layer-1 partial products accumulate over the contraction dim in an f32
VMEM scratch block, and the epilogue (bias, relu, layer-2 GEMM, bias)
runs when the last d-block lands, so the hidden activations never leave
VMEM.  Expert weights are gathered per sample with a scalar-prefetched
index (``PrefetchScalarGridSpec``), the same mechanism a
capacity-bucketed MoE dispatch uses — duplicate group ids are fine and
simply re-stream the same weight block.

Validated on CPU with interpret=True against ``ref.audit_mlp_ref``
(tests/test_kernels.py); the compiled path targets the MXU with the
feature dims padded to lane multiples by the wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _audit_mlp_kernel(gid_ref, x_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                      o_ref, h_ref):
    del gid_ref                      # consumed by the index_maps
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h_ref[...] += jnp.dot(x_ref[0], w1_ref[0],
                          preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(1) - 1)
    def _epilogue():
        h = jnp.maximum(h_ref[...] + b1_ref[0], 0.0)
        o_ref[0] = (jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32)
                    + b2_ref[0])


def _pad_axis(x, axis: int, mult: int):
    p = (-x.shape[axis]) % mult
    if p:
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, p)
        x = jnp.pad(x, pads)
    return x


def audit_mlp(params, x: jax.Array, gid: jax.Array, *, block_d: int = 256,
              interpret: bool | None = None) -> jax.Array:
    """Fused grouped 2-layer MLP: out[s] = mlp(params[gid[s]], x[s]).

    params: dict with stacked ``w1 (E, d, h)``, ``b1 (E, h)``,
    ``w2 (E, h, o)``, ``b2 (E, o)``; x: (S, C, d) padded sample chunks;
    gid: (S,) int32 expert index per sample.  Returns (S, C, o) f32.
    """
    interpret = resolve_interpret(interpret)
    w1, b1, w2, b2 = params["w1"], params["b1"], params["w2"], params["b2"]
    S, C, d = x.shape
    o = w2.shape[-1]
    block_d = min(block_d, d)

    xp = _pad_axis(_pad_axis(x, 1, 8), 2, block_d)
    w1p = _pad_axis(w1, 1, block_d)
    w2p = _pad_axis(w2, 2, 128)
    b2p = _pad_axis(b2, 1, 128)
    Cp, dp = xp.shape[1], xp.shape[2]
    h = w1.shape[-1]
    op = w2p.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(S, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, Cp, block_d), lambda s, k, gid: (s, 0, k)),
            pl.BlockSpec((1, block_d, h), lambda s, k, gid: (gid[s], k, 0)),
            pl.BlockSpec((1, h), lambda s, k, gid: (gid[s], 0)),
            pl.BlockSpec((1, h, op), lambda s, k, gid: (gid[s], 0, 0)),
            pl.BlockSpec((1, op), lambda s, k, gid: (gid[s], 0)),
        ],
        out_specs=pl.BlockSpec((1, Cp, op), lambda s, k, gid: (s, 0, 0)),
        scratch_shapes=[pltpu.VMEM((Cp, h), jnp.float32)],
    )
    out = pl.pallas_call(
        _audit_mlp_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, Cp, op), jnp.float32),
        interpret=interpret,
    )(gid.astype(jnp.int32), xp, w1p, b1, w2p, b2p)
    return out[:, :C, :o]
