"""Pallas TPU kernel: RG-LRU linear recurrence h_t = a_t*h_{t-1} + b_t
(RecurrentGemma, arXiv:2402.19427).

Layout: (B, S, C).  Grid (B, C/Ct, S/Sq) with the sequence dimension
innermost: the carried hidden state (Ct lanes) lives in VMEM scratch
across sequence chunks; within a chunk the recurrence runs as a
``fori_loop`` over rows on the VPU (8x128 lanes).  This is the
TPU-native shape of the scan: lanes parallel, time sequential —
vs the log-depth associative scan used on the jnp path
(``models.rglru.rglru_scan``), which is the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _rglru_kernel(a_ref, b_ref, y_ref, h_ref, *, Sq):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                                       # (Sq, Ct)
    b = b_ref[0]

    def body(i, h):
        h = a[i] * h + b[i]
        pl.store(y_ref, (0, pl.dslice(i, 1), slice(None)), h[None])
        return h

    h_ref[...] = jax.lax.fori_loop(0, Sq, body, h_ref[...])


def rglru_scan_pallas(a, b, *, seq_block=128, chan_block=256,
                      interpret=None):
    """a, b: (B, S, C) f32 -> h: (B, S, C)."""
    interpret = resolve_interpret(interpret)
    B, S, C = a.shape
    Sq = min(seq_block, S)
    Ct = min(chan_block, C)
    if S % Sq or C % Ct:
        raise ValueError(f"S={S} % {Sq} or C={C} % {Ct} != 0")
    from jax.experimental.pallas import tpu as pltpu

    grid = (B, C // Ct, S // Sq)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, Sq=Sq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Sq, Ct), lambda bi, ci, si: (bi, si, ci)),
            pl.BlockSpec((1, Sq, Ct), lambda bi, ci, si: (bi, si, ci)),
        ],
        out_specs=pl.BlockSpec((1, Sq, Ct), lambda bi, ci, si: (bi, si, ci)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((Ct,), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
