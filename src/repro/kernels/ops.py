"""Public jit'd wrappers over the Pallas kernels with pure-jnp fallback.

``backend`` resolution:
- "ref"       : pure jnp oracle (default off-TPU — also what GSPMD
                lowers for the multi-pod dry-run)
- "pallas"    : compiled Pallas kernel (TPU target)
- "interpret" : Pallas kernel body interpreted on CPU (how kernels are
                validated in this container)

Set REPRO_KERNEL_BACKEND to override the default.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import audit_gemm as _ag
from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gemm as _mg
from repro.kernels import redundancy_vote as _rv
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ref
from repro.kernels.backend import default_backend
from repro.obs import annotate

__all__ = ["default_backend", "redundancy_vote", "moe_gemm", "audit_mlp",
           "flash_attention", "ssd_scan", "rglru_scan"]


# ------------------------------------------------------ redundancy vote
def redundancy_vote(pub: jax.Array, axis: int = 1, *, atol: float = 0.0,
                    backend: str | None = None):
    """Majority vote over redundant copies (paper Step 3).

    pub: (..., M, ...) with the replica axis at ``axis`` and the expert
    axis leading.  Canonical layout (E, M, *tail).  Returns
    (trusted (E, *tail), support (E,))."""
    if axis != 1:
        pub = jnp.moveaxis(pub, axis, 1)
    backend = backend or default_backend()
    if backend == "ref":
        return ref.redundancy_vote_ref(pub, atol)
    E, M = pub.shape[:2]
    flat = pub.reshape(E, M, -1)
    T = flat.shape[-1]
    counts = _rv.pairwise_agreement(
        flat.astype(jnp.float32), atol=atol,
        interpret=(backend == "interpret"))
    pad = (-T) % min(_rv.DEFAULT_TILE, max(T, 1))
    full_agree = (counts == T + pad).astype(jnp.int32)
    support_per = full_agree.sum(axis=-1)
    winner = support_per.argmax(axis=-1)
    trusted = jnp.take_along_axis(
        flat, winner[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    support = jnp.take_along_axis(support_per, winner[:, None], axis=1)[:, 0]
    return trusted.reshape((E,) + pub.shape[2:]), support


# ------------------------------------------------------ grouped GEMM
def moe_gemm(buf, w, *, backend: str | None = None):
    backend = backend or default_backend()
    with annotate(f"moe_gemm[{backend}]"):
        if backend == "ref":
            return ref.moe_gemm_ref(buf, w)
        return _mg.moe_gemm(buf, w, interpret=(backend == "interpret"))


# ------------------------------------------------------ batched audit
def audit_mlp(params, x, gid, *, backend: str | None = None):
    """Batched audit recompute: out[s] = mlp(params[gid[s]], x[s]).

    params: stacked {w1,b1,w2,b2} over the expert axis; x: (S, C, d)
    sampled chunks; gid: (S,) int32 expert per sample.  The ref backend
    is bit-identical to the eager per-chunk expert apply (what leaf
    digests are hashed from); the Pallas backend fuses both GEMMs and
    the relu in VMEM (validated allclose in tests/test_kernels.py).
    """
    backend = backend or default_backend()
    with annotate(f"audit_mlp[{backend}]"):
        if backend == "ref":
            return ref.audit_mlp_ref(params, x, gid)
        return _ag.audit_mlp(params, x, gid,
                             interpret=(backend == "interpret"))


# ------------------------------------------------------ attention
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    backend: str | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D) — model layout."""
    backend = backend or default_backend()
    if backend == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window,
                                 softcap=softcap)
    out = _fa.flash_attention(
        jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
        causal=causal, window=window, softcap=softcap,
        interpret=(backend == "interpret"))
    return jnp.moveaxis(out, 1, 2)


# ------------------------------------------------------ SSD scan
def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk=128, backend: str | None = None):
    backend = backend or default_backend()
    if backend == "ref":
        state0 = jnp.zeros((x.shape[0], x.shape[2], x.shape[3],
                            Bmat.shape[-1]), jnp.float32)
        y, _ = ref.ssd_scan_ref(x.astype(jnp.float32),
                                dt.astype(jnp.float32), A,
                                Bmat.astype(jnp.float32),
                                Cmat.astype(jnp.float32), state0)
        return y
    return _ssd.ssd_scan(x, dt, A, Bmat, Cmat, chunk=chunk,
                         interpret=(backend == "interpret"))


# ------------------------------------------------------ RG-LRU scan
def rglru_scan(a, b, *, backend: str | None = None):
    """h_t = a_t * h_{t-1} + b_t over axis 1; a, b: (B, S, C)."""
    backend = backend or default_backend()
    if backend == "ref":
        from repro.models.rglru import rglru_scan as _ref_scan
        return _ref_scan(a, b)
    return _rg.rglru_scan_pallas(a, b, interpret=(backend == "interpret"))
