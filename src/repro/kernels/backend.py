"""Central kernel-backend selection.

Every Pallas kernel in this package takes an ``interpret`` flag; before
this module existed each kernel hardcoded ``interpret=True`` as its
default, so a TPU run that called a kernel directly (not through the
``ops`` wrappers) silently interpreted the kernel body instead of
compiling it.  All kernels now default ``interpret=None`` and resolve it
here, so there is exactly ONE place that decides how a kernel executes:

- ``REPRO_KERNEL_BACKEND`` env var, when set, wins ("ref" | "pallas" |
  "interpret");
- otherwise "pallas" (compiled) on TPU, "ref" elsewhere.

``ops`` keeps its per-call ``backend=`` override on top of this default.
"""
from __future__ import annotations

import os


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    try:
        import jax
        if jax.devices()[0].platform == "tpu":
            return "pallas"
    except Exception:
        pass
    return "ref"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret`` flag: an explicit value wins;
    ``None`` defers to ``default_backend()`` — compiled on a "pallas"
    backend, interpreted everywhere else (the CPU validation mode)."""
    if interpret is None:
        return default_backend() != "pallas"
    return bool(interpret)
