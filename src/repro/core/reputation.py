"""Paper §VI future directions, implemented:

- §VI-B **reputation-aided hybrid consensus**: each blockchain/edge node
  carries a reputation score updated from consensus outcomes (agreeing
  with the accepted majority raises it; publishing rejected results
  slashes it).  Block-generation difficulty is inversely proportional to
  reputation — high-reputation nodes mine with fewer expected hashes
  (modeled as a reputation-scaled effective hash rate), which both
  speeds consensus and incentivizes honesty.

- §VI-C **workload balance**: an auxiliary-free gate-bias controller
  (DeepSeek-V3-style): experts with below-average load get a positive
  routing bias next round, pulling the activation distribution toward
  uniform without touching the loss.

- §VI-D **incentive mechanism**: per-round rewards for majority-consistent
  results, slashing for rejected ones; edges whose reputation falls below
  an exclusion threshold are dropped from task assignment (their expert
  is served by re-assignment), bounding the damage a persistent attacker
  can do even below the 50% coalition threshold.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class ReputationConfig:
    init: float = 0.5
    gain: float = 0.05           # reward for majority-consistent result
    slash: float = 0.20          # penalty for rejected result
    floor: float = 0.0
    ceil: float = 1.0
    exclusion_threshold: float = 0.15
    difficulty_scale: int = 4    # max difficulty-bit reduction at rep=1


class ReputationLedger:
    """Per-edge reputation from consensus outcomes (paper §VI-B/D)."""

    def __init__(self, num_edges: int, cfg: ReputationConfig = ReputationConfig()):
        self.cfg = cfg
        self.rep = np.full(num_edges, cfg.init)
        self.rewards = np.zeros(num_edges)
        self.history: List[np.ndarray] = []

    def update_from_flags(self, flags: np.ndarray):
        """flags: (E, M) 1 where edge m's copy of expert e's result matched
        the accepted majority."""
        agree_frac = np.asarray(flags, dtype=np.float64).mean(axis=0)  # (M,)
        delta = np.where(agree_frac >= 0.5,
                         self.cfg.gain * agree_frac,
                         -self.cfg.slash * (1.0 - agree_frac))
        self.rep = np.clip(self.rep + delta, self.cfg.floor, self.cfg.ceil)
        self.rewards += np.where(agree_frac >= 0.5, agree_frac, -1.0)
        self.history.append(self.rep.copy())

    @property
    def excluded(self) -> np.ndarray:
        return self.rep < self.cfg.exclusion_threshold

    def active_edges(self) -> List[int]:
        return [i for i, x in enumerate(self.excluded) if not x]

    def effective_power(self, base_power: Optional[Sequence[float]] = None):
        """Reputation-scaled mining power: difficulty inversely
        proportional to reputation == hash rate scaled by
        2**(difficulty_scale * rep)."""
        base = np.asarray(base_power if base_power is not None
                          else np.ones_like(self.rep), dtype=np.float64)
        return base * np.exp2(self.cfg.difficulty_scale * self.rep)


class WorkloadBalancer:
    """Auxiliary-free gate-bias controller (paper §VI-C).

    bias_i <- bias_i + eta * (mean_load - load_i); the bias is added to
    the gate logits before top-K, steering under-used experts into
    activation without gradient interference."""

    def __init__(self, num_experts: int, eta: float = 0.5):
        self.eta = eta
        self.bias = np.zeros(num_experts, dtype=np.float32)

    def update(self, activation_counts: np.ndarray):
        load = np.asarray(activation_counts, dtype=np.float64)
        total = load.sum()
        if total <= 0:
            return self.bias
        frac = load / total
        self.bias = (self.bias +
                     self.eta * (frac.mean() - frac)).astype(np.float32)
        return self.bias
