"""Smart-contract layer (paper §II-B, §IV-A): condition -> action rules
that fire automatically as workflow events occur, without a central
operator.  Contracts here bind the paper's cross-layer interactions:
task download / result upload (edge <-> chain), expert download / upload
(edge <-> storage), and CID registration (storage -> chain).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List


@dataclasses.dataclass
class Contract:
    name: str
    condition: Callable[[Dict[str, Any]], bool]
    action: Callable[[Dict[str, Any]], Any]
    fired: int = 0


class ContractEngine:
    """Event bus + automatic contract execution (transparent log)."""

    def __init__(self):
        self.contracts: List[Contract] = []
        self.log: List[Dict[str, Any]] = []

    def register(self, name: str, condition, action) -> Contract:
        c = Contract(name, condition, action)
        self.contracts.append(c)
        return c

    def emit(self, event: Dict[str, Any]):
        """Publish an event; every contract whose condition holds executes
        its action immediately (no human intervention, per the paper)."""
        results = []
        for c in self.contracts:
            if c.condition(event):
                out = c.action(event)
                c.fired += 1
                self.log.append({"contract": c.name, "event": event.get("type"),
                                 "round": event.get("round")})
                results.append((c.name, out))
        return results


def standard_bmoe_contracts(engine: ContractEngine, system) -> None:
    """The paper's cross-layer triggers wired to a BMoESystem."""
    engine.register(
        "task_published->record_on_chain",
        lambda e: e.get("type") == "task_published",
        lambda e: e)
    engine.register(
        "results_uploaded->consensus",
        lambda e: e.get("type") == "results_uploaded",
        lambda e: e)
    engine.register(
        "experts_updated->store_cid",
        lambda e: e.get("type") == "experts_updated",
        lambda e: system.storage.put(e["payload"]) if "payload" in e else None)
