"""Blockchain-layer consensus (paper §IV-A Step 3, §IV-B).

Two mechanisms:

- ``majority_vote``: the off-chain redundancy consensus — given the R
  copies of an expert's result published by the edges, accept the most
  consistent one.  Honest edges publish bit-identical results; colluding
  malicious edges publish identical *manipulated* results; the larger
  coalition wins (threshold 50%, paper §IV-B scenario 2).

- ``ProofOfWork``: on-chain block generation.  Difficulty is reduced vs
  real chains (this is a single-process simulation); the hash-target
  semantics match Bitcoin-style PoW, and mining power per node is
  configurable so the >50% on-chain attack (scenario 1) is testable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.ledger import Block, digest_array


# ----------------------------------------------------- majority vote
@dataclasses.dataclass
class VoteResult:
    winner: int                 # index of an edge in the majority coalition
    support: int                # size of the majority coalition
    total: int
    digests: List[str]
    accepted: bool              # support > total/2 (paper's threshold)


def majority_vote(results: Sequence[np.ndarray], atol: float = 0.0) -> VoteResult:
    """Pick the most consistent result among ``results`` (one per edge).

    Equality is digest-based when ``atol == 0`` (the paper's setting:
    honest results are bit-identical), else within-tolerance agreement
    counting (robust to nondeterministic accelerators).
    """
    n = len(results)
    if atol == 0.0:
        digests = [digest_array(r) for r in results]
        counts = {}
        for d in digests:
            counts[d] = counts.get(d, 0) + 1
        best = max(counts, key=counts.get)
        winner = digests.index(best)
        support = counts[best]
    else:
        digests = []
        agree = np.zeros((n, n), dtype=np.int32)
        for i in range(n):
            for j in range(n):
                agree[i, j] = np.allclose(results[i], results[j], atol=atol)
        support_per = agree.sum(axis=1)
        winner = int(support_per.argmax())
        support = int(support_per[winner])
    return VoteResult(winner=winner, support=int(support), total=n,
                      digests=digests, accepted=support * 2 > n)


def majority_tree_vote(trees: Sequence, digest_fn) -> VoteResult:
    """Vote over pytrees (e.g. updated expert parameters, paper Step 5)."""
    digests = [digest_fn(t) for t in trees]
    counts = {}
    for d in digests:
        counts[d] = counts.get(d, 0) + 1
    best = max(counts, key=counts.get)
    winner = digests.index(best)
    return VoteResult(winner=winner, support=counts[best], total=len(trees),
                      digests=digests, accepted=counts[best] * 2 > len(trees))


# ------------------------------------------------------------- PoW
class ProofOfWork:
    """Simulated PoW over the blockchain nodes.

    ``mining_power[i]`` = relative hash rate of node i.  ``mine`` picks
    the winning miner proportionally to power (the expected outcome of
    the race) and then *actually* grinds a nonce meeting the difficulty
    target, so block hashes are verifiable.
    """

    def __init__(self, num_nodes: int, difficulty_bits: int = 12,
                 mining_power: Sequence[float] | None = None, seed: int = 0):
        self.num_nodes = num_nodes
        self.difficulty_bits = difficulty_bits
        power = np.asarray(mining_power if mining_power is not None
                           else np.ones(num_nodes), dtype=np.float64)
        self.power = power / power.sum()
        self._rng = np.random.default_rng(seed)

    def _meets_target(self, block_hash: str) -> bool:
        return int(block_hash, 16) >> (256 - self.difficulty_bits) == 0

    def mine(self, index: int, prev_hash: str, payload: dict) -> Block:
        miner = int(self._rng.choice(self.num_nodes, p=self.power))
        block = Block(index=index, prev_hash=prev_hash, payload=payload,
                      miner=miner)
        nonce = 0
        while True:
            block.nonce = nonce
            if self._meets_target(block.hash):
                return block
            nonce += 1

    def verify(self, block: Block) -> bool:
        return self._meets_target(block.hash)
