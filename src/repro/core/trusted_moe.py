"""B-MoE at LM scale: redundant expert execution + consensus vote as a
first-class feature of the MoE transformer (DESIGN.md §4).

Mesh layout: (data, replica, model) — the ``replica`` axis carries the
paper's "edges that all compute the activated experts": the batch is
sharded over ``data`` only, so every replica holds an identical copy of
its group's tokens and computes the routed experts redundantly (r x
compute, exactly the paper's redundancy cost).  The consensus vote is a
shard_map over the mesh that communicates *only* across ``replica``:

- mode="faithful" (the paper): all_gather the full expert-output buffer
  across replicas, replica-level majority vote per expert.
  Collective bytes ~ (r-1) x |buffer| per device.
- mode="digest" (beyond-paper): all_gather scalar per-expert digests
  (tiny), each replica checks itself against the majority digest, and
  the trusted value is recovered with one masked psum
  (sum(ok * y) / sum(ok) — honest copies are identical, so the mean of
  the agreeing copies IS the honest value).  Collective bytes
  ~ 2(r-1)/r x |buffer| — about r/2 x less traffic, same detection
  power against the paper's Gaussian-manipulation adversary.

An optional in-graph attack (malicious replica indices + noise) lets the
robustness be tested end-to-end under jit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class LMAttack:
    """In-graph adversary for LM-scale robustness tests/benchmarks."""
    malicious_replicas: tuple = ()
    noise_std: float = 1.0
    colluding: bool = True
    seed: int = 0


def _shard_map(f, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (TypeError, AttributeError):  # older jax spelling
        from jax.experimental.shard_map import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _inject(y, attack: Optional[LMAttack]):
    if attack is None or not attack.malicious_replicas:
        return y
    rid = jax.lax.axis_index("replica")
    try:
        n_rep = jax.lax.axis_size("replica")
    except AttributeError:                 # older jax spelling
        n_rep = jax.lax.psum(1, "replica")
    mal = jnp.zeros((n_rep,), jnp.float32)
    mal = mal.at[jnp.array(attack.malicious_replicas, jnp.int32)].set(1.0)
    key = jax.random.PRNGKey(attack.seed)
    if not attack.colluding:
        key = jax.random.fold_in(key, rid)
    noise = jax.random.normal(key, y.shape, y.dtype)
    return y + attack.noise_std * noise * mal[rid]


def _vote_faithful(y, attack):
    """y: local (B, E, C, d) expert-output buffer block."""
    B, E, C, d = y.shape
    y = _inject(y, attack)
    ys = jax.lax.all_gather(y.reshape(B * E, C, d), "replica")  # (r,BE,C,d)
    pub = jnp.moveaxis(ys, 0, 1)                       # (BE, r, C, d)
    trusted, _support = kref.redundancy_vote_ref(pub)
    return trusted.reshape(B, E, C, d)


def _vote_digest(y, attack):
    """Digest vote + masked-psum recovery (beyond-paper)."""
    B, E, C, d = y.shape
    y = _inject(y, attack).reshape(B * E, C, d)
    # per-(group, expert) digest: projection onto a fixed pseudorandom
    # direction — Gaussian manipulation perturbs it w.p. 1
    v = jax.random.normal(jax.random.PRNGKey(0xB30E), (C, d), jnp.float32)
    dig = jnp.tensordot(y.astype(jnp.float32), v, axes=2)  # (BE,)
    digs = jax.lax.all_gather(dig, "replica")          # (r, BE) — tiny
    agree = (jnp.abs(digs[:, None, :] - digs[None, :, :]) <= 0.0)
    support = agree.sum(axis=1)                        # (r, BE)
    rid = jax.lax.axis_index("replica")
    majority = support.max(axis=0)                     # (BE,)
    # elect the lowest-indexed replica of the max-support coalition
    # (breaks r=2 ties deterministically, like the faithful argmax)
    winner = jnp.argmax(support == majority[None, :], axis=0)  # (BE,)
    ok = (jnp.abs(digs[rid] -
                  jnp.take_along_axis(digs, winner[None, :], axis=0)[0])
          <= 0.0).astype(y.dtype)
    n_ok = jax.lax.psum(ok, "replica")
    total = jax.lax.psum(y * ok[:, None, None], "replica")
    out = total / jnp.maximum(n_ok, 1.0)[:, None, None]
    return out.astype(y.dtype).reshape(B, E, C, d)


def make_trust(mesh: Optional[Mesh], rcfg, expert_sharded: bool,
               attack: Optional[LMAttack] = None):
    """Build the ``trust`` hook for repro.models.moe.moe_mlp.

    The hook receives the routed-expert output buffer (B, E, C, d);
    ``expert_sharded`` says whether its expert axis is sharded over
    "model" (llama4: 128 % 16 == 0) or replicated (qwen2-moe)."""
    if mesh is None or rcfg.mode == "off":
        return None
    if "replica" not in mesh.axis_names:
        raise ValueError("trusted mode needs a 'replica' mesh axis")
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names) or None
    spec = P(batch, "model" if expert_sharded else None, None, None)
    body = _vote_faithful if rcfg.mode == "faithful" else _vote_digest
    return _shard_map(functools.partial(body, attack=attack), mesh,
                      in_specs=(spec,), out_specs=spec)


def make_trusted_mesh(r: int, *, data: int = 16, model: int = 16,
                      multi_pod: bool = False):
    """(data/r, replica=r, model) mesh — same chip count as production."""
    if data % r:
        raise ValueError(f"redundancy r={r} must divide data={data}")
    if multi_pod:
        return jax.make_mesh((2, data // r, r, model),
                             ("pod", "data", "replica", "model"))
    return jax.make_mesh((data // r, r, model), ("data", "replica", "model"))
