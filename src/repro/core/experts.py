"""The paper's expert/gate models (§V-A(5)).

- Gating network: linear (flattened input -> N expert logits).
- MLP expert (Fashion-MNIST): two fully-connected layers, hidden 256, ReLU.
- CNN expert (CIFAR-10): three conv layers + two fully-connected layers.

Experts are stored stacked (leading N axis) and evaluated with ``vmap``
over the expert axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.builder import Leaf, materialize, stack


def gate_decl(in_dim: int, num_experts: int) -> dict:
    return {"w": Leaf((in_dim, num_experts), (None, None), scale=0.01),
            "b": Leaf((num_experts,), (None,), "zeros")}


def gate_apply(params, x):
    """x: (B, in_dim) -> logits (B, N)."""
    return x @ params["w"] + params["b"]


def mlp_expert_decl(in_dim: int, hidden: int = 256, out: int = 10) -> dict:
    return {
        "w1": Leaf((in_dim, hidden), (None, None)),
        "b1": Leaf((hidden,), (None,), "zeros"),
        "w2": Leaf((hidden, out), (None, None)),
        "b2": Leaf((out,), (None,), "zeros"),
    }


def mlp_expert_apply(params, x):
    """x: (B, in_dim) -> logits (B, out)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def cnn_expert_decl(in_ch: int = 3, out: int = 10) -> dict:
    """Three 3x3 stride-2 convs + two FC layers (paper §V-A(5); widths
    unspecified in the paper — sized for the CPU container)."""
    return {
        "c1": Leaf((3, 3, in_ch, 16), (None,) * 4),
        "c2": Leaf((3, 3, 16, 32), (None,) * 4),
        "c3": Leaf((3, 3, 32, 32), (None,) * 4),
        "w1": Leaf((4 * 4 * 32, 128), (None, None)),
        "b1": Leaf((128,), (None,), "zeros"),
        "w2": Leaf((128, out), (None, None)),
        "b2": Leaf((out,), (None,), "zeros"),
    }


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(2, 2), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def cnn_expert_apply(params, x):
    """x: (B, 32, 32, C) -> logits (B, out)."""
    h = jax.nn.relu(_conv(x, params["c1"]))
    h = jax.nn.relu(_conv(h, params["c2"]))
    h = jax.nn.relu(_conv(h, params["c3"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


@jax.custom_vjp
def mlp_expert_apply_grouped(params, buf):
    """buf: (N, C, d) capacity buckets -> (N, C, out): every expert's
    2-layer MLP applied to its own bucket through the grouped GEMM route
    (``ops.moe_gemm``: Pallas kernel on TPU, einsum oracle elsewhere).

    The Pallas call has no built-in autodiff rule, so the backward pass
    is supplied explicitly (the grouped-GEMM transposes) — this is what
    lets the B-MoE *train* step run its hot path through the kernel.
    """
    h = jax.nn.relu(kops.moe_gemm(buf, params["w1"])
                    + params["b1"][:, None, :])
    return kops.moe_gemm(h, params["w2"]) + params["b2"][:, None, :]


def _mlp_grouped_fwd(params, buf):
    h = jax.nn.relu(kops.moe_gemm(buf, params["w1"])
                    + params["b1"][:, None, :])
    out = kops.moe_gemm(h, params["w2"]) + params["b2"][:, None, :]
    return out, (params["w1"], params["w2"], buf, h)


def _mlp_grouped_bwd(res, g):
    w1, w2, buf, h = res
    dw2 = jnp.einsum("ech,eco->eho", h, g)
    db2 = g.sum(axis=1)
    dh = jnp.einsum("eco,eho->ech", g, w2) * (h > 0)
    dw1 = jnp.einsum("ecd,ech->edh", buf, dh)
    db1 = dh.sum(axis=1)
    dbuf = jnp.einsum("ech,edh->ecd", dh, w1)
    return ({"w1": dw1, "b1": db1, "w2": dw2, "b2": db2}, dbuf)


mlp_expert_apply_grouped.defvjp(_mlp_grouped_fwd, _mlp_grouped_bwd)


def grouped_apply_fn(kind: str):
    """apply(stacked_params, buf (N, C, ...)) -> (N, C, out): each expert
    on its own capacity bucket — the sparse-dispatch counterpart of
    ``apply_all``.  The mlp bank routes through the grouped GEMM kernel;
    the cnn bank vmaps the per-expert apply over the bucket axis (still
    sparse: C = capacity rows instead of the full batch)."""
    if kind == "mlp":
        return mlp_expert_apply_grouped
    if kind == "cnn":
        return jax.vmap(cnn_expert_apply)
    raise ValueError(kind)


def make_expert_bank(kind: str, num_experts: int, key, *, in_dim: int = 784,
                     in_ch: int = 3, hidden: int = 256, out: int = 10):
    """Returns (stacked_params, apply_all) where apply_all(params, x) ->
    (N, B, out): every expert's output on the same batch."""
    if kind == "mlp":
        decl = stack(mlp_expert_decl(in_dim, hidden, out), num_experts,
                     axis_name=None)
        apply_one = mlp_expert_apply
    elif kind == "cnn":
        decl = stack(cnn_expert_decl(in_ch, out), num_experts,
                     axis_name=None)
        apply_one = cnn_expert_apply
    else:
        raise ValueError(kind)
    params = materialize(decl, key)
    apply_all = jax.vmap(apply_one, in_axes=(0, None))
    return params, apply_all


def sparse_gate_weights(logits, k: int):
    """Paper's sparse top-K activation: softmax renormalized over the
    selected experts.  Returns dense weights (B, N) (zero off the top-K)
    and the top-K indices (B, k)."""
    topv, topi = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(topv, axis=-1)
    out = jnp.zeros_like(logits)
    out = out.at[jnp.arange(logits.shape[0])[:, None], topi].set(w)
    return out, topi
