"""The B-MoE system (paper §IV): task publisher + edge layer + blockchain
layer + storage layer, running the full Step 1-6 workflow for training
and the Step 1-3 (+6) workflow for inference.

Three frameworks are implemented behind one API:

- ``framework="traditional"``: the paper's baseline — edge i employs
  expert i; no redundancy, no consensus; malicious edges corrupt their
  own expert's results (and the gate must cope on its own, §III).
- ``framework="bmoe"``: every edge computes ALL activated experts
  (redundancy mechanism); the blockchain layer majority-votes the
  per-expert results, aggregates the trusted ones, and records the round
  in a PoW block; updated experts are hash-voted and stored by CID
  (Steps 4-5) during training.
- ``framework="optimistic"``: the commit-challenge-audit protocol from
  ``repro.trust`` — one rotating executor edge computes, commits a
  Merkle root over its per-expert output chunks on-chain, and the round
  is accepted optimistically; a verifier pool spot-checks sampled leaves
  (recompute against the stored expert by CID), confirmed fraud proofs
  slash the executor's stake, feed the reputation ledger, escalate the
  round to the full redundancy vote (the dispute court), and roll the
  round's parameter update back.  Expected verification recompute drops
  from O(M) to O(audit_rate) per round while keeping the same trust
  guarantee up to 1-(1-audit_rate)^k detection.

The numerics (expert compute, manipulation, majority vote, SGD) run as
one jitted step; the ledger/PoW/storage bookkeeping — and, for the
optimistic framework, the commit/audit/slash/rollback machinery — runs
per round in Python, mirroring the paper's on-chain/off-chain split.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import experts as ex
from repro.core.attacks import AttackConfig, round_attack_mask, poison_tree
from repro.core.consensus import ProofOfWork
from repro.core.ledger import Ledger, digest_array, digest_bytes, digest_tree
from repro.core.reputation import ReputationConfig, ReputationLedger, WorkloadBalancer
from repro.obs import Observability
from repro.storage import (ExpertCache, ExpertStore, GateEMA,
                           NetworkCostModel, StorageNetwork)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.moe import capacity_positions
from repro.models.moe_ep import _shard_map
from repro.trust.audit import pack_audit_batch, pack_audit_batch_multi
from repro.trust.commitments import chunk_bounds
from repro.trust.da import DataAvailabilityAuditor
from repro.trust.protocol import (TERMINAL_PHASES, AuditJob,
                                  OptimisticProtocol, RoundPhase,
                                  TrustConfig)


@dataclasses.dataclass(frozen=True)
class BMoEConfig:
    num_experts: int = 10           # N (paper §V)
    num_edges: int = 10             # M
    top_k: int = 3                  # K
    expert_kind: str = "mlp"        # mlp (fmnist) | cnn (cifar)
    in_dim: int = 784
    in_ch: int = 1
    num_classes: int = 10
    lr: float = 0.01
    framework: str = "bmoe"         # bmoe | traditional | optimistic
    # execution model of the expert layer (paper §II: sparse gating
    # "lowers computational overhead"):
    # - "sparse" (default): top-k scatter-dispatch into per-expert
    #   capacity buckets + grouped GEMM (ops.moe_gemm route) + gather-
    #   combine — expert compute scales with top_k/num_experts;
    # - "dense": every expert over the full batch (the pre-sparse
    #   reference oracle; top-k gating only zeroes combine weights).
    dispatch: str = "sparse"
    capacity_factor: float = 1.25   # bucket slots per expert, as a
    #                                 multiple of the balanced share
    #                                 B*top_k/num_experts (overflow drops)
    # device-mesh execution (the distributed edge network made real):
    # "on" runs every round's jitted step under an edge mesh
    # (launch.mesh.make_edge_mesh) — the expert bank is sharded so each
    # simulated edge device owns an E/msize slice, sparse dispatch
    # crosses shards via all_to_all (wire bytes per device independent
    # of E), and the trust layer goes shard-local: each edge hashes only
    # its own buckets (root = Merkle reduction over shard roots) and
    # audit recompute runs on the owning shard.  Outputs, commitments,
    # audit verdicts, and rollback replays are BIT-IDENTICAL to the
    # "off" single-device oracle (tests/test_mesh_bmoe.py).
    mesh: str = "off"               # on | off
    mesh_shards: Optional[int] = None  # edge devices (None: widest fit)
    attack: AttackConfig = dataclasses.field(default_factory=AttackConfig)
    pow_difficulty: int = 8
    num_chain_nodes: int = 8
    bandwidth_bytes_per_s: float = 125e6   # 1 Gbps edge links
    # chunked storage / edge cache (repro.storage): every round uploads
    # the *changed* experts as a new chunk-manifest version (chunk-level
    # dedup against the previous version) and the edge resolves the
    # round's bank through a bounded LRU ExpertCache instead of keeping
    # the whole bank resident.  "off" keeps the bank in memory — the
    # pre-cache oracle (bit-identical outputs, pinned in
    # tests/test_expert_cache.py).
    edge_cache: str = "on"          # on | off
    edge_cache_bytes: Optional[int] = None  # cache byte budget (None: unbounded)
    chunk_bytes: int = 1 << 16      # storage chunk size
    prefetch_topk: int = 0          # EMA-prefetch this many hot experts
    num_storage_nodes: int = 4
    storage_replication: int = 2
    # data-availability challenges (repro.trust.da): per-chunk sampling
    # rate at which replica nodes are challenged to produce committed
    # chunks each optimistic round; a withheld chunk past the challenge
    # window slashes the storage node (da_slash ledger block)
    da_rate: float = 0.05
    seed: int = 0
    # paper §VI extensions (see repro.core.reputation)
    reputation: Optional[ReputationConfig] = None       # §VI-B/D
    workload_balance: bool = False                      # §VI-C
    balance_eta: float = 0.5
    # optimistic framework knobs (see repro.trust)
    trust: Optional[TrustConfig] = None


class BMoESystem:
    """One instantiation of Fig. 3. See module docstring."""

    # phase-seconds metrics behind the legacy ``_timers`` keys: every
    # wall-clock second the system books flows through a span into the
    # obs registry, and the old dict is a read-only view of it
    _TIMER_METRICS = {"compute": "bmoe.compute_s",
                      "consensus": "bmoe.consensus_s",
                      "chain": "bmoe.chain_s",
                      "audit": "bmoe.audit_s",
                      "audit_infer": "bmoe.audit_infer_s",
                      "storage": "bmoe.storage_s"}

    def __init__(self, cfg: BMoEConfig, obs: Optional[Observability] = None):
        self.cfg = cfg
        # the one observability bundle of the run: every layer below
        # (storage network/store/cache, trust protocols, DA auditor)
        # records into its registry, and spans opened here mark the
        # round phases on its tracer.  Default: tracing off, metrics on.
        self.obs = obs if obs is not None else Observability()
        key = jax.random.PRNGKey(cfg.seed)
        kg, ke = jax.random.split(key)
        gate_in = cfg.in_dim if cfg.expert_kind == "mlp" else 32 * 32 * cfg.in_ch
        from repro.models.builder import materialize
        self.gate = materialize(ex.gate_decl(gate_in, cfg.num_experts), kg)
        self.experts, self._apply_all = ex.make_expert_bank(
            cfg.expert_kind, cfg.num_experts, ke, in_dim=cfg.in_dim,
            in_ch=cfg.in_ch, out=cfg.num_classes)
        self._apply_grouped = ex.grouped_apply_fn(cfg.expert_kind)
        # mesh execution (see BMoEConfig.mesh): shard the expert bank
        # over the edge mesh's model axis so each simulated edge device
        # owns a contiguous E/msize expert slice; the jitted steps then
        # run the all_to_all dispatch path (_mesh_sparse_forward)
        self.device_mesh = None
        self.mesh_shards = 1
        self._bank_sharding = None
        if cfg.mesh == "on":
            if cfg.dispatch != "sparse":
                raise ValueError(
                    "mesh='on' runs the all_to_all sparse dispatch; dense "
                    "dispatch has no per-expert buckets to exchange — set "
                    "dispatch='sparse'")
            from jax.sharding import PartitionSpec
            from repro.launch.mesh import make_edge_mesh
            from repro.sharding import Sharder
            self.device_mesh = make_edge_mesh(cfg.num_experts,
                                              shards=cfg.mesh_shards)
            axes = dict(zip(self.device_mesh.axis_names,
                            self.device_mesh.devices.shape))
            self.mesh_shards = axes["model"]
            sharder = Sharder(self.device_mesh, rules={"experts": "model"})
            self._bank_sharding = sharder.named(PartitionSpec("model"))
            self.experts = jax.device_put(self.experts, self._bank_sharding)
        self.ledger = Ledger()
        self.storage = StorageNetwork(
            num_nodes=cfg.num_storage_nodes,
            replication=cfg.storage_replication, seed=cfg.seed,
            cost=NetworkCostModel(
                bandwidth_bytes_per_s=cfg.bandwidth_bytes_per_s),
            metrics=self.obs.metrics)
        # the storage layer proper: versioned per-expert chunk manifests
        # (version v = the bank state entering round v; only changed
        # experts re-upload, and unchanged chunks dedup away), plus the
        # edge-side cache the executor resolves activated experts through
        self.expert_store = ExpertStore(self.storage,
                                        chunk_bytes=cfg.chunk_bytes,
                                        metrics=self.obs.metrics)
        self.edge_cache = (ExpertCache(self.expert_store,
                                       cfg.edge_cache_bytes,
                                       metrics=self.obs.metrics)
                           if cfg.edge_cache == "on" else None)
        self.gate_ema = GateEMA(cfg.num_experts)
        self._expert_like = jax.tree_util.tree_map(
            lambda a: np.asarray(a[0]), self.experts)
        self._bank_version = -1
        self._resolved_bank = None      # device bank memo, keyed by the
        self._resolved_key = None       # resolved manifest cids
        self._publish_bank(None, 0)     # genesis bank: every expert, v0
        self.pow = ProofOfWork(cfg.num_chain_nodes,
                               difficulty_bits=cfg.pow_difficulty,
                               seed=cfg.seed)
        self.round = 0
        if cfg.framework == "optimistic" and cfg.reputation is None:
            # exclusion of slashed executors needs a reputation ledger
            self.reputation = ReputationLedger(cfg.num_edges,
                                               ReputationConfig())
        else:
            self.reputation = (ReputationLedger(cfg.num_edges, cfg.reputation)
                               if cfg.reputation else None)
        self.balancer = (WorkloadBalancer(cfg.num_experts, cfg.balance_eta)
                         if cfg.workload_balance else None)
        self.activation_counts = np.zeros(cfg.num_experts)
        self.activation_total = 0
        # manifest CIDs of the expert versions each open optimistic round
        # committed against — retained in the store while the round's
        # challenge window is open (the data-availability contract) and
        # released once it closes (superseded versions are then GC'd)
        self._audit_cids: Dict[int, List[str]] = {}
        # pipelined-scheduling state: per-pending-round snapshots (the
        # (gate, experts) the executor was handed, the task, and the keys
        # needed to replay the round honestly after a chained rollback)
        self._round_ctx: Dict[int, Dict] = {}
        # batch-inference pipeline (lazily created on the first optimistic
        # infer): its own round clock, shared stakes/court/reputation
        self._infer_protocol: Optional[OptimisticProtocol] = None
        self._infer_round = 0
        self._infer_ctx: Dict[int, Dict] = {}
        self._infer_audit_cids: Dict[int, List[str]] = {}
        self.infer_log: List[Dict] = []
        # "audit" (bmoe.audit_s) collects verifier recompute/hash/fetch
        # seconds drained under pipelined scheduling: work that
        # deployment runs on the verifier pool concurrently with later
        # rounds, i.e. OFF the round loop's critical path — the drain
        # span is opened ``off_path=True``, so every enclosing phase
        # metric (consensus) natively excludes it.  Synchronous
        # scheduling keeps audits on the critical path, inside
        # "consensus".
        # "audit_infer" keeps the inference pipeline's drains out of the
        # per-training-round latency decomposition
        # "storage": expert-version publication + edge-cache bank
        # resolution seconds (host wall-clock; the *modeled* transfer
        # time lives in storage_report(), on the network cost model)
        for name in self._TIMER_METRICS.values():
            self.obs.metrics.counter(name)
        self.obs.metrics.counter("bmoe.round_s")
        # verification-compute ledger, in units of (expert evaluations x
        # samples): base = the one canonical execution, verify = recompute
        # done purely to check it (redundant copies / audits), escalate =
        # dispute-court full votes.  The jitted simulation broadcasts
        # instead of physically recomputing, so cost is counted, not timed.
        self.verify_stats = {"base_evals": 0.0, "verify_evals": 0.0,
                             "escalate_evals": 0.0, "rounds": 0}
        self.trust_cfg: Optional[TrustConfig] = None
        self.protocol: Optional[OptimisticProtocol] = None
        self.da: Optional[DataAvailabilityAuditor] = None
        if cfg.framework == "optimistic":
            self.trust_cfg = cfg.trust or TrustConfig(seed=cfg.seed)
            self.protocol = OptimisticProtocol(self.trust_cfg, cfg.num_edges,
                                               self.reputation,
                                               metrics=self.obs.metrics,
                                               namespace="trust.train")
            if cfg.da_rate > 0:
                # storage nodes post their own bonds: a replica that
                # cannot produce a committed chunk inside the challenge
                # window is slashed (see repro.trust.da)
                self.da = DataAvailabilityAuditor(
                    self.storage, num_nodes=cfg.num_storage_nodes,
                    window=self.trust_cfg.challenge_window,
                    sample_rate=cfg.da_rate, seed=cfg.seed,
                    metrics=self.obs.metrics)
            self._apply_one = (ex.mlp_expert_apply if cfg.expert_kind == "mlp"
                               else ex.cnn_expert_apply)
            # one grouped jitted call recomputes every sampled (expert,
            # chunk) pair of a round: the mlp bank routes through the
            # audit kernel (Pallas on TPU, bit-identical gathered-vmap
            # ref on CPU); other expert kinds use the generic gather
            if cfg.expert_kind == "mlp":
                self._batched_recompute_call = jax.jit(
                    lambda bank, xd, idx, gid:
                        kops.audit_mlp(bank, xd[idx], gid))
            else:
                def _gather_apply(bank, xd, idx, gid):
                    p = jax.tree_util.tree_map(lambda a: a[gid], bank)
                    return jax.vmap(self._apply_one)(p, xd[idx])
                self._batched_recompute_call = jax.jit(_gather_apply)
        if self.mesh_shards > 1 and self.trust_cfg is not None:
            # shard-local commitments reduce shard subtree roots into the
            # flat round root; the reduction is bit-identical only when
            # each shard's subtree is a complete subtree of the flat
            # tree, i.e. leaves per shard is a power of two
            lps = (cfg.num_experts // self.mesh_shards) \
                * self.trust_cfg.chunks_per_expert
            if lps & (lps - 1):
                raise ValueError(
                    f"shard-local commitments need a power-of-two leaf "
                    f"count per edge: (num_experts/mesh_shards) * "
                    f"chunks_per_expert = ({cfg.num_experts}/"
                    f"{self.mesh_shards}) * "
                    f"{self.trust_cfg.chunks_per_expert} = {lps}; adjust "
                    f"mesh_shards or TrustConfig.chunks_per_expert")
        self._train_step = jax.jit(functools.partial(
            _train_step, cfg=cfg, apply_all=self._apply_all,
            apply_grouped=self._apply_grouped, mesh=self.device_mesh,
            mesh_shards=self.mesh_shards))
        self._infer_step = jax.jit(functools.partial(
            _infer_step, cfg=cfg, apply_all=self._apply_all,
            apply_grouped=self._apply_grouped, mesh=self.device_mesh,
            mesh_shards=self.mesh_shards))
        # host-side routing re-derivation for sparse commitments: the
        # committed routing indices are what let auditors re-build the
        # exact capacity buckets the executor filled
        self._routing_call = jax.jit(functools.partial(_route_for_commit,
                                                       cfg=cfg))

    # ------------------------------------------------------------ api
    def train_round(self, x, y, *, attack: Optional[AttackConfig] = None):
        """One full Step 1-6 round on one published task (batch)."""
        cfg = self.cfg
        atk = attack if attack is not None else cfg.attack
        rkey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 17),
                                  self.round)
        mask_e = round_attack_mask(atk, cfg.num_edges, rkey)
        executor = (self.protocol.pick_executor(self.round)
                    if cfg.framework == "optimistic" else 0)
        gate_bias, active = self._controls()
        # the round span carries the on-path round seconds (off-path
        # audit drains nested below are excluded natively); every phase
        # below is its child, so one traced round decomposes into
        # fetch -> dispatch -> [publish/consensus/chain] spans whose
        # metric sums are exactly the legacy latency_report components
        with self.obs.span("round", metric="bmoe.round_s",
                           round=self.round, kind="train",
                           framework=cfg.framework, executor=executor):
            # Step 2 (storage -> edge): the executor edge resolves this
            # round's bank through its cache — activated experts pinned
            # and refreshed at the committed version, misses fetched
            # chunk-by-chunk from the storage layer (bit-identical to the
            # resident bank: pinned in tests/test_expert_cache.py)
            with self.obs.span("fetch", metric="bmoe.storage_s",
                               round=self.round):
                bank = self._resolve_bank(x, gate_bias)
            prev = (self.gate, bank)

            with self.obs.span("dispatch", metric="bmoe.compute_s",
                               round=self.round):
                (self.gate, self.experts, metrics) = self._train_step(
                    self.gate, bank, x, y, mask_e,
                    jax.random.fold_in(rkey, 1), atk.noise_std,
                    jnp.asarray(atk.colluding), gate_bias, active,
                    jnp.int32(executor))
                metrics = jax.tree_util.tree_map(np.asarray, metrics)
            self.gate_ema.update(metrics["activation"])

            batch = int(x.shape[0])
            payload = {
                "round": self.round, "kind": "train",
                "task": digest_array(np.asarray(x)[:8]),
                "loss": float(metrics["loss"]),
            }
            # cost ledger in expert-evaluation units (one unit = one
            # expert evaluated on one row of what it actually computes:
            # the full batch under dense dispatch, its capacity bucket
            # under sparse — the optimistic commitment covers exactly
            # that buffer), so base/verify/escalate are all measured
            # with the same yardstick
            self.verify_stats["rounds"] += 1
            if cfg.framework == "traditional":
                self.verify_stats["base_evals"] += cfg.top_k * batch
            else:
                self.verify_stats["base_evals"] += self._exec_evals(batch)
            if cfg.framework != "optimistic":
                # Step 5, chunked: publish the updated experts as new
                # manifest versions (only routed experts changed;
                # unchanged chunks dedup away).  The optimistic path
                # publishes after its commit/audit bookkeeping instead —
                # round r's audits must be able to retain the version-r
                # manifests first.
                with self.obs.span("publish", metric="bmoe.storage_s",
                                   round=self.round):
                    self._publish_bank(metrics["activation"],
                                       self.round + 1)
                payload["bank_root"] = self._bank_root()[:16]
            if cfg.framework == "bmoe":
                # the redundancy mechanism IS the verification: M-1 extra
                # copies of the same execution
                self.verify_stats["verify_evals"] += \
                    (cfg.num_edges - 1) * self._exec_evals(batch)
                # Step 4-5: edges vote on the updated experts' hashes;
                # the accepted bank's storage root is in the payload.
                with self.obs.span("consensus", metric="bmoe.consensus_s",
                                   round=self.round):
                    payload["trusted_supports"] = \
                        metrics["support"].tolist()
                    self._expert_hash_vote(atk, rkey, payload)
                # Step 6: block generation under PoW.
                with self.obs.span("chain", metric="bmoe.chain_s",
                                   round=self.round):
                    self._mine(payload)
            elif cfg.framework == "optimistic":
                # commit -> optimistic accept -> async audit -> maybe
                # rollback.  The pipelined audit drain inside opens an
                # off_path span, so its seconds land in bmoe.audit_s and
                # are excluded from this consensus span's metric — the
                # span algebra that replaced the old hand subtraction.
                with self.obs.span("consensus", metric="bmoe.consensus_s",
                                   round=self.round):
                    metrics = self._optimistic_round(
                        x, y, atk, mask_e, rkey, executor, prev, metrics,
                        payload, gate_bias, active)
                payload["loss"] = float(metrics["loss"])
                with self.obs.span("publish", metric="bmoe.storage_s",
                                   round=self.round):
                    if not payload.get("rolled_back"):
                        # a rolled-back round's honest replay already
                        # republished the voided versions (including
                        # this round's successor)
                        self._publish_bank(metrics["activation"],
                                           self.round + 1)
                payload["bank_root"] = self._bank_root()[:16]
                with self.obs.span("chain", metric="bmoe.chain_s",
                                   round=self.round):
                    self._mine(payload)
            self._update_controllers(metrics)
            self.activation_counts += metrics["activation"]
            self.activation_total += batch * cfg.top_k
            self.round += 1
        return metrics

    def infer(self, x, *, attack: Optional[AttackConfig] = None,
              commit: bool = True):
        """Steps 1-3 (+6): forward only, no updates (paper: 4-5 skipped).

        Under ``framework="optimistic"`` batch inference runs through the
        same commit-challenge-audit pipeline as training rounds, at batch
        granularity: a rotating executor's claimed per-expert outputs are
        Merkle-committed, the logits are returned immediately (the
        optimistic view), and the audit drains off the critical path on a
        separate inference round clock (shared stake book/court — an
        inference conviction slashes and excludes the executor from BOTH
        rotations).  ``pending_inference()`` lists rounds still inside
        their window; ``infer_log`` records commits/revocations;
        ``flush_trust()`` settles everything.  A corrupted round is
        caught w.p. 1-(1-audit_rate)^k ~= 1 for full-tensor corruption.

        ``commit=False`` is a side-effect-free probe of the finalized
        (honest) view: no commitment, no audit round, no shared-state
        mutation — what ``evaluate`` uses, so measuring accuracy never
        perturbs the trust experiment.  The per-tick protocol for
        streaming inference lives in ``ServingEngine`` verified
        sessions.
        """
        cfg = self.cfg
        atk = attack if attack is not None else cfg.attack
        rkey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 91),
                                  self.round + 1_000_000)
        gate_bias, active = self._controls()
        if cfg.framework != "optimistic" or not commit:
            # non-optimistic frameworks serve their (possibly attacked)
            # consensus view; the optimistic probe serves the finalized
            # honest view (corruption would be audited out anyway)
            mask_e = (round_attack_mask(atk, cfg.num_edges, rkey)
                      if cfg.framework != "optimistic"
                      else jnp.zeros(cfg.num_edges, jnp.float32))
            bank = self._resolve_bank(x, gate_bias)
            logits, activation, support = self._infer_step(
                self.gate, bank, x, mask_e,
                jax.random.fold_in(rkey, 1), atk.noise_std,
                jnp.asarray(atk.colluding), gate_bias, active, jnp.int32(0))
            return (np.asarray(logits), np.asarray(activation),
                    np.asarray(support))

        proto = self._ensure_infer_protocol()
        rid = self._infer_round
        self._infer_round += 1
        # each inference round draws its own attack lottery — without
        # folding in rid, back-to-back infer() calls would replay one
        # perfectly correlated mask and void the per-round-independent
        # detection bound
        rkey = jax.random.fold_in(rkey, rid)
        mask_e = round_attack_mask(atk, cfg.num_edges, rkey)
        executor = proto.pick_executor(rid)
        # trace-only spans (no phase metric: the legacy decomposition
        # never booked inference wall-clock outside the audit drains) —
        # a traced run still sees the full fetch/dispatch/commit shape
        with self.obs.span("infer-round", round=rid, kind="infer",
                           executor=executor):
            with self.obs.span("fetch", round=rid):
                bank = self._resolve_bank(x, gate_bias)
            version = self._bank_version
            with self.obs.span("dispatch", round=rid):
                logits, activation, support = self._infer_step(
                    self.gate, bank, x, mask_e, jax.random.fold_in(rkey, 1),
                    atk.noise_std, jnp.asarray(atk.colluding), gate_bias,
                    active, jnp.int32(executor))
            self.gate_ema.update(np.asarray(activation))
            xin = np.asarray(x if cfg.expert_kind == "cnn"
                             else np.asarray(x).reshape(len(x), -1))
            row_index, bounds = self._commitment_layout(
                self.gate, x, xin.shape[0], gate_bias)
            tc = self.trust_cfg
            with self.obs.span("commit", round=rid,
                               executor=executor) as csp:
                honest = self._eager_outputs(bank, xin, bounds, row_index)
                attacked = bool(np.asarray(mask_e)[executor] > 0)
                state = self._commit_round(proto, rid, executor, honest,
                                           attacked, atk, 1_000_000 + rid,
                                           digest_array(xin[:8]), row_index)
                csp.set(root=state.commitment.root[:16])
        # data-availability contract: the versions this inference round
        # committed against stay retained until its window closes
        manifests = self._retain_round_manifests(version)
        self._infer_audit_cids[rid] = manifests
        self._infer_ctx[rid] = {
            "prev": (self.gate, bank), "xin": xin, "honest": honest,
            "executor": executor, "mask_e": np.asarray(mask_e), "atk": atk,
            "active": active, "manifests": manifests,
        }
        recompute_fn = self._make_recompute(xin, manifests, row_index)
        batch_fn = (self._make_batched_recompute(bank, xin, manifests,
                                                 row_index)
                    if tc.audit_backend == "batched" else None)
        proto.schedule_audit(rid, recompute_fn, batch_fn)
        self.infer_log.append({"event": "commit", "round": rid,
                               "executor": executor,
                               "root": state.commitment.root[:16]})

        drain_now = None if tc.scheduling == "synchronous" else rid
        summary = self._drain_trust(proto, self._infer_ctx,
                                    self._infer_audit_cids, drain_now,
                                    "infer")
        self._record_infer_verdicts(summary)
        for frid in proto.advance(rid):
            self.infer_log.append({"event": "finalize", "round": frid})
        self._prune_closed_rounds(proto, self._infer_ctx,
                                  self._infer_audit_cids)
        return np.asarray(logits), np.asarray(activation), np.asarray(support)

    def evaluate(self, x, y, *, attack: Optional[AttackConfig] = None,
                 batch: int = 1000) -> float:
        correct = 0
        for i in range(0, len(x), batch):
            # commit=False: an accuracy probe must not mint inference
            # rounds, pay commitments, or slash anyone
            logits, _, _ = self.infer(x[i:i + batch], attack=attack,
                                      commit=False)
            correct += int((logits.argmax(-1) == np.asarray(y[i:i + batch])).sum())
        return correct / len(x)

    def _controls(self):
        cfg = self.cfg
        gate_bias = jnp.asarray(self.balancer.bias) if self.balancer \
            else jnp.zeros(cfg.num_experts, jnp.float32)
        if self.reputation is not None:
            active = jnp.asarray(
                (~self.reputation.excluded).astype(np.float32))
        else:
            active = jnp.ones(cfg.num_edges, jnp.float32)
        return gate_bias, active

    def _update_controllers(self, metrics):
        if self.balancer is not None:
            self.balancer.update(metrics["activation"])
        # optimistic rounds feed reputation through confirmed fraud proofs
        # (slashing), not per-round agreement flags
        if (self.reputation is not None and "flags" in metrics
                and self.cfg.framework != "optimistic"):
            self.reputation.update_from_flags(metrics["flags"])

    @property
    def activation_ratio(self) -> np.ndarray:
        return self.activation_counts / max(self.activation_total, 1)

    # -------------------------------------------------------- internals
    def _expert_hash_vote(self, atk: AttackConfig, rkey, payload):
        """Paper Step 5: each edge uploads the updated experts' hashes; the
        chain accepts the majority; poisoned uploads are rejected."""
        cfg = self.cfg
        honest_digest = digest_tree(self.experts)
        uploads = []
        for m in range(cfg.num_edges):
            if atk.poison_params and m in atk.malicious_edges:
                poisoned = poison_tree(self.experts,
                                       jax.random.fold_in(rkey, 100 + (0 if
                                       atk.colluding else m)),
                                       atk.noise_std)
                uploads.append(digest_tree(poisoned))
            else:
                uploads.append(honest_digest)
        counts: Dict[str, int] = {}
        for d in uploads:
            counts[d] = counts.get(d, 0) + 1
        winner = max(counts, key=counts.get)
        payload["expert_hash"] = winner[:16]
        payload["expert_hash_support"] = counts[winner]
        payload["expert_hash_accepted"] = counts[winner] * 2 > cfg.num_edges
        if winner != honest_digest and payload["expert_hash_accepted"]:
            # majority is malicious: chain is misled (paper §IV-B, >50%)
            payload["chain_misled"] = True
        # Step 5 storage happens per round through the versioned chunk
        # store (``_publish_bank``); the block's ``bank_root`` already
        # binds the accepted bank's per-expert manifest roots on-chain.

    def _mine(self, payload):
        tr = self.obs.trace
        if tr.enabled:
            # block -> trace correlation (see trust/README.md): every
            # block mined while tracing names the trace and the innermost
            # open span it was mined under.  Only when tracing — a
            # disabled run's payloads (and so its block hashes) stay
            # bit-identical to the pre-obs chain.
            payload["trace_id"] = tr.trace_id
            payload["span_id"] = tr.current_span_id()
        block = self.pow.mine(len(self.ledger.blocks), self.ledger.head.hash,
                              payload)
        self.ledger.append(block)

    def _exec_evals(self, batch: int) -> float:
        """Expert-evaluation cost of one canonical execution: every
        expert over the full batch (dense) or over its capacity bucket
        (sparse — the grouped GEMM's real row count, padding included)."""
        cfg = self.cfg
        rows = (sparse_capacity(cfg, batch) if cfg.dispatch == "sparse"
                else batch)
        return cfg.num_experts * rows

    # ----------------------------------------------------- storage layer
    @staticmethod
    def _object_id(e: int) -> str:
        return f"expert/{e}"

    def _activated_experts(self, x, gate_bias) -> List[int]:
        """The experts the gate routes this batch to — what the edge must
        hold current versions of before computing.  The rest of the bank
        is provably unchanged on-storage: an unrouted expert's combine
        weight is zero everywhere, so it receives zero gradient and its
        previous version still serves (pinned in
        tests/test_expert_cache.py)."""
        eid, _, _ = self._routing_call(self.gate, x, gate_bias)
        return [int(e) for e in np.unique(np.asarray(eid))]

    def _resolve_bank(self, x, gate_bias):
        """Edge-side bank resolution (paper: the edge layer "employs the
        activated experts downloaded from the storage layer"): activated
        experts are pinned and resolved at the current version through
        the bounded ``ExpertCache`` — a miss or a stale entry fetches the
        expert chunk-by-chunk (CID-verified) from the storage network.
        The assembled device bank is memoized on the resolved manifest
        CIDs, so repeated inference against an unchanged bank costs no
        transfer and no re-stack.  ``edge_cache="off"`` keeps the bank
        resident — the pre-cache oracle, bit-identical by construction
        (the chunk round-trip preserves every byte)."""
        if self.edge_cache is None:
            return self.experts
        cfg, cache = self.cfg, self.edge_cache
        version = self._bank_version
        ids = [self._object_id(e)
               for e in self._activated_experts(x, gate_bias)]
        cache.pin(ids)
        try:
            if cfg.prefetch_topk:
                hot = [self._object_id(e)
                       for e in self.gate_ema.ranking()[:cfg.prefetch_topk]]
                cache.prefetch(hot, version, lambda oid: self._expert_like)
            rows = [cache.get(self._object_id(e), version,
                              self._expert_like)
                    for e in range(cfg.num_experts)]
        finally:
            cache.unpin(ids)
        key = tuple(
            self.expert_store.manifest_cid(self._object_id(e), version)
            for e in range(cfg.num_experts))
        if key != self._resolved_key:
            # host-side stack first, ONE device put per leaf — straight
            # into the edge-shard layout under mesh execution
            put = (functools.partial(jax.device_put,
                                     device=self._bank_sharding)
                   if self._bank_sharding is not None else jnp.asarray)
            self._resolved_bank = jax.tree_util.tree_map(
                lambda *ls: put(np.stack(ls)), *rows)
            self._resolved_key = key
        return self._resolved_bank

    def _publish_bank(self, activation, version: int) -> None:
        """Step 5, chunked: upload a new manifest version for every
        expert the round routed to (``activation=None``: the whole bank —
        genesis).  Unchanged chunks of a changed expert dedup away inside
        ``put_version``; untouched experts keep serving from their
        previous version."""
        cfg = self.cfg
        changed = (list(range(cfg.num_experts)) if activation is None else
                   [int(e) for e in
                    np.nonzero(np.asarray(activation) > 0)[0]])
        if not changed:
            self._bank_version = max(self._bank_version, version)
            return
        if len(changed) > 2:
            # one device->host transfer for the whole bank, slice in host
            # memory (beats a per-expert gather dispatch per leaf)
            host = jax.tree_util.tree_map(np.asarray, self.experts)
            pick = lambda a, e: a[e]
        else:
            host = self.experts
            pick = lambda a, e: np.asarray(a[e])
        for e in changed:
            tree_e = jax.tree_util.tree_map(lambda a: pick(a, e), host)
            self.expert_store.put_version(self._object_id(e), tree_e,
                                          version)
        self._bank_version = max(self._bank_version, version)

    def _bank_root(self) -> str:
        """One digest binding the current bank's per-expert manifest
        roots — the storage commitment a round's block records."""
        roots = "".join(
            self.expert_store.manifest(self._object_id(e),
                                       self._bank_version).root
            for e in range(self.cfg.num_experts))
        return digest_bytes(roots.encode())

    def _fetch_expert_manifest(self, manifest_cid: str):
        """Auditor-side fetch: the exact expert version a round
        committed against, named by its retained manifest CID (NOT a
        version-number lookup — a chained-rollback replay republishes
        voided version tags, and an open round's auditors must keep
        fetching what was actually committed).  Every chunk is
        CID-verified (a corrupted replica is skipped — verified refetch
        from a healthy one) and reassembled chunk-for-chunk."""
        return self.expert_store.fetch_manifest(
            self.expert_store.manifest_by_cid(manifest_cid),
            self._expert_like)

    def _retain_round_manifests(self, version: int) -> List[str]:
        """Pin the manifests a round committed against for the length of
        its challenge window (the data-availability contract: auditors
        must be able to fetch them until the round is terminal)."""
        cids = []
        for e in range(self.cfg.num_experts):
            cid = self.expert_store.manifest_cid(self._object_id(e),
                                                 version)
            self.expert_store.retain(cid)
            cids.append(cid)
        return cids

    def _run_da(self, now: Optional[int],
                manifest_cids: Optional[List[str]] = None) -> None:
        """One data-availability beat: challenge replica nodes for
        sampled chunks of the given manifests, close past-due challenges
        (``now=None``: all), and mine one ``da_slash`` block per
        confirmed fault (withheld past the window, or a corrupted
        replica — the latter also repaired by verified refetch)."""
        if self.da is None:
            return
        n = len(self.da.faults)
        if manifest_cids:
            manifests = {}
            for cid in manifest_cids:
                man = self.expert_store.manifest_by_cid(cid)
                manifests[man.object_id] = man
            self.da.challenge_round(now, manifests)
        self.da.resolve(now)
        for f in self.da.faults[n:]:
            self._mine({"kind": "da_slash", "node": f.executor,
                        "object": f.object_id, "chunk": f.chunk_index,
                        "cid": f.cid[:16], "fault": f.kind,
                        "challenged_round": f.round_id})

    def storage_report(self) -> Dict:
        """Byte/transfer economy of the storage layer: network counters
        (with *modeled* transfer seconds on the deterministic cost
        model), chunk-dedup upload savings, edge-cache hit/miss/byte
        counters, DA challenge stats, and the host wall-clock spent on
        storage bookkeeping.  A thin view over ``obs_report()`` — every
        number is a live registry metric; keys unchanged from pre-obs."""
        return self.obs_report()["storage"]

    # ------------------------------------------- optimistic verification
    def _sparse_routing(self, gate, x, gate_bias):
        """Re-derive the round's routing from the snapshot state and
        build the ``(N, capacity)`` bucket->task-row index the executor
        publishes with a sparse commitment.  Empty slots point one past
        the batch (the zero sentinel row auditors append to the task),
        so a leaf recompute is a pure gather + grouped apply."""
        cfg = self.cfg
        eid, pos, keep = (np.asarray(a) for a in
                          self._routing_call(gate, x, gate_bias))
        batch = len(x)
        capacity = sparse_capacity(cfg, batch)
        row_index = np.full((cfg.num_experts, capacity), batch, np.int32)
        tok = np.repeat(np.arange(batch, dtype=np.int32), cfg.top_k)
        row_index[eid[keep], pos[keep]] = tok[keep]
        return row_index, capacity

    @staticmethod
    def _pad_task(xin, row_index):
        """The auditors' task view: under sparse dispatch, the batch plus
        one trailing zero row (what empty bucket slots recompute from)."""
        if row_index is None:
            return xin
        return np.concatenate([xin, np.zeros_like(xin[:1])], axis=0)

    def _eager_outputs(self, experts, xin, bounds, row_index=None):
        """The executor's commitment-building pass: every expert's output
        computed through the same recompute path the auditors use, so
        honest leaves recompute bit-identically.  For the mlp bank every
        (expert, chunk) leaf goes through ONE grouped ``audit_mlp`` call
        (the auditors' own kernel); other expert kinds fall back to the
        per-expert chunked apply.  With ``row_index`` (sparse dispatch)
        the chunks tile each expert's capacity bucket and the task rows
        come from the committed routing, so the pass computes — and the
        commitment covers — only the bucketed buffers."""
        cfg = self.cfg
        n_chunks = len(bounds) - 1
        xpad = self._pad_task(xin, row_index)
        if cfg.expert_kind == "mlp" and self.protocol is not None:
            slices = [slice(bounds[c], bounds[c + 1])
                      for c in range(n_chunks)]
            if self.mesh_shards > 1:
                # shard-local commitment building: each edge recomputes
                # (and will hash) only its own expert buckets — one
                # grouped call per edge over its local (E_l, capacity, C)
                # slice.  Per-sample arithmetic is identical to the
                # single-call path, so the assembled tensor (and every
                # leaf digest) is bitwise the oracle's.
                e_l = cfg.num_experts // self.mesh_shards
                xd = jnp.asarray(xpad)
                work = [(e, sl) for e in range(e_l) for sl in slices]
                parts = []
                for s in range(self.mesh_shards):
                    bank_s = jax.tree_util.tree_map(
                        lambda a: a[s * e_l:(s + 1) * e_l], experts)
                    rmap = (None if row_index is None
                            else row_index[s * e_l:(s + 1) * e_l])
                    idx, gid, n = pack_audit_batch(
                        [e for e, _ in work], [sl for _, sl in work],
                        row_map=rmap)
                    out = np.asarray(self._batched_recompute_call(
                        bank_s, xd, jnp.asarray(idx),
                        jnp.asarray(gid)))[:n]
                    parts.extend(np.concatenate(
                        [out[e * n_chunks + c][:bounds[c + 1] - bounds[c]]
                         for c in range(n_chunks)], axis=0)
                        for e in range(e_l))
                return np.stack(parts)
            work = [(e, sl) for e in range(cfg.num_experts)
                    for sl in slices]            # (e, c) row-major = leaf order
            idx, gid, n = pack_audit_batch([e for e, _ in work],
                                           [sl for _, sl in work],
                                           row_map=row_index)
            out = np.asarray(self._batched_recompute_call(
                experts, jnp.asarray(xpad), jnp.asarray(idx),
                jnp.asarray(gid)))[:n]
            parts = [np.concatenate(
                [out[e * n_chunks + c][:bounds[c + 1] - bounds[c]]
                 for c in range(n_chunks)], axis=0)
                for e in range(cfg.num_experts)]
            return np.stack(parts)
        parts = []
        for e in range(cfg.num_experts):
            p_e = jax.tree_util.tree_map(lambda a: a[e], experts)
            chunks = [np.asarray(self._apply_one(
                p_e, jnp.asarray(xpad[bounds[c]:bounds[c + 1]]
                                 if row_index is None
                                 else xpad[row_index[e,
                                                     bounds[c]:bounds[c + 1]]])))
                for c in range(n_chunks)]
            parts.append(np.concatenate(chunks, axis=0))
        return np.stack(parts)

    def _make_recompute(self, xin, manifests: List[str], row_index=None):
        """Auditor-side recompute: fetch the sampled expert from the
        storage layer by the *manifest the round committed against*
        (``manifests[e]`` — the CID list retained at commit, whose roots
        are bound on-chain; every chunk is CID-verified, so a tampered
        replica is self-evident and skipped) and recompute the audited
        chunk on the published task.  Under sparse dispatch the audited
        chunk is a slice of the expert's capacity bucket and the
        committed ``row_index`` maps its slots back to task rows (empty
        slots gather the zero sentinel) — auditors re-derive the
        executor's buckets from the commitment, never from the gate.
        The round retains its manifests at commit time and releases them
        when it reaches a terminal phase (the data-availability
        contract; superseded versions are then garbage collected, while
        the compact fraud proofs remain in the round state)."""
        cache: Dict[int, object] = {}
        xpad = self._pad_task(xin, row_index)

        def recompute(e: int, sl: slice):
            if e not in cache:
                cache[e] = self._fetch_expert_manifest(manifests[e])
            rows = xpad[sl] if row_index is None else xpad[row_index[e, sl]]
            return np.asarray(self._apply_one(cache[e], jnp.asarray(rows)))

        return recompute

    def _make_batched_recompute(self, experts, xin, manifests: List[str],
                                row_index=None):
        """Batched auditor recompute (``BatchRecomputeFn``): the same
        fetch-by-manifest semantics as ``_make_recompute`` — one
        chunk-verified storage fetch per sampled expert — but every
        sampled chunk of the round is then recomputed in ONE jitted
        grouped call instead of a Python-loop dispatch per (expert,
        slice).

        The fetch per sampled expert is preserved — every chunk is
        hash-verified against the committed manifest, so a fetched tree
        is guaranteed byte-identical to the expert version the round
        committed against (a tampered replica is skipped; a withheld
        chunk raises ``ChunkUnavailableError`` — the DA-challengeable
        fault).  That guarantee is what lets the grouped call read the
        already-device-resident bank and task directly: only the
        per-sample row indices and expert ids cross the host boundary,
        the expert and row gathers fuse into the kernel, the bank shape
        is constant, and the only jit-retrace axis is the sample count,
        bucketed to a multiple of 4.  Padding rows never reach the leaf
        hashes.

        The task transfer is deferred to the first call: under pipelined
        scheduling the host drains through the cross-round merged path
        (``_audit_jobs_merged``) and this closure is only the fallback
        for per-round drains, so building it must cost nothing."""
        fetched: set = set()
        xd_cache: List = []

        def fetch(e: int):
            if e not in fetched:
                self._fetch_expert_manifest(manifests[e])  # chunk-verified
                fetched.add(e)

        def batch_recompute(expert_ids, slices):
            for e in sorted({int(e) for e in expert_ids}):
                fetch(e)
            if not xd_cache:
                xd_cache.append(jnp.asarray(self._pad_task(xin, row_index)))
            if self.mesh_shards > 1:
                return self._sharded_batch_recompute(experts, xd_cache[0],
                                                     expert_ids, slices,
                                                     row_index)
            idx, gid, n = pack_audit_batch(expert_ids, slices,
                                           row_map=row_index)
            out = self._batched_recompute_call(experts, xd_cache[0],
                                               jnp.asarray(idx),
                                               jnp.asarray(gid))
            return np.asarray(out[:n])

        return batch_recompute

    def _shard_groups(self, expert_ids):
        """Sample indices grouped by the edge shard owning each sampled
        expert — mesh execution routes every audit recompute to the
        shard that holds the expert slice."""
        e_l = self.cfg.num_experts // self.mesh_shards
        groups: Dict[int, List[int]] = {}
        for i, e in enumerate(expert_ids):
            groups.setdefault(int(e) // e_l, []).append(i)
        return e_l, groups

    def _book_audit_rows(self, shard: int, slices, sel) -> None:
        """Per-shard real recompute rows (padding excluded) — the bench
        gate that shard-local audits cost each edge ~1/msize of the
        round's audited rows (benchmarks/mesh_bench.py)."""
        rows = int(sum(slices[i].stop - slices[i].start for i in sel))
        self.obs.metrics.counter("bmoe.mesh.audit_rows",
                                 shard=str(shard)).add(rows)

    def _sharded_batch_recompute(self, experts, xd, expert_ids, slices,
                                 row_index):
        """Shard-local audit recompute: each sampled leaf runs as part of
        the owning edge's grouped call over its local bank slice (local
        expert ids, shard-sliced routing).  Per-sample arithmetic is
        independent of the grouping, so the reassembled ``(S, Cmax, C)``
        tensor is bitwise the single-call path's — verdicts, fraud
        proofs, and attestations are unchanged."""
        e_l, groups = self._shard_groups(expert_ids)
        cmax = max(sl.stop - sl.start for sl in slices)
        out = None
        for s, sel in sorted(groups.items()):
            bank_s = jax.tree_util.tree_map(
                lambda a: a[s * e_l:(s + 1) * e_l], experts)
            rmap = (None if row_index is None
                    else row_index[s * e_l:(s + 1) * e_l])
            idx, gid, n = pack_audit_batch(
                [int(expert_ids[i]) - s * e_l for i in sel],
                [slices[i] for i in sel], row_map=rmap)
            part = np.asarray(self._batched_recompute_call(
                bank_s, xd, jnp.asarray(idx), jnp.asarray(gid)))[:n]
            if out is None:
                out = np.zeros((len(expert_ids), cmax) + part.shape[2:],
                               part.dtype)
            w = min(part.shape[1], cmax)
            for j, i in enumerate(sel):
                out[i, :w] = part[j, :w]
            self._book_audit_rows(s, slices, sel)
        return out

    def _commit_round(self, protocol, rid, executor, honest, attacked, atk,
                      seed_salt, task_digest, row_index=None):
        """Build the executor's claimed tensor (corrupted iff it attacks)
        and publish the round commitment — over the dense ``(N, B, C)``
        outputs, or (sparse dispatch) the capacity-bucketed buffers plus
        the routing indices auditors re-derive the buckets from."""
        claimed = honest
        if attacked:
            rng = np.random.default_rng(self.cfg.seed * 7919 + seed_salt)
            claimed = honest + atk.noise_std * rng.standard_normal(
                honest.shape).astype(honest.dtype)
        return protocol.commit(rid, executor, claimed,
                               task_digest=task_digest, row_index=row_index,
                               num_shards=self.mesh_shards)

    def _commitment_layout(self, gate, x, batch: int, gate_bias):
        """(row_index, bounds) of the round's commitment: bucket-chunk
        leaves under sparse dispatch, batch-chunk leaves under dense."""
        tc = self.trust_cfg
        if self.cfg.dispatch == "sparse":
            row_index, capacity = self._sparse_routing(gate, x, gate_bias)
            return row_index, chunk_bounds(capacity, tc.chunks_per_expert)
        return None, chunk_bounds(batch, tc.chunks_per_expert)

    def _court_publish(self, ctx, claimed, seed_salt):
        """The dispute court's input: every edge's copy of every expert's
        result — the paper's full redundancy matrix, reconstructed from
        the round snapshot and its attack pattern."""
        cfg = self.cfg
        honest, atk = ctx["honest"], ctx["atk"]
        pub = np.broadcast_to(
            honest[:, None],
            (cfg.num_experts, cfg.num_edges) + honest.shape[1:]).copy()
        att = np.asarray(ctx["mask_e"]) > 0
        if atk.colluding:
            pub[:, att] = claimed[:, None]     # coalition backs the executor
        else:
            rng = np.random.default_rng(cfg.seed * 104729 + seed_salt)
            for m in np.nonzero(att)[0]:
                pub[:, m] = honest + atk.noise_std * rng.standard_normal(
                    honest.shape).astype(honest.dtype)
        pub[:, ctx["executor"]] = claimed
        return pub

    def _audit_jobs_merged(self, protocol, ctx_store,
                           jobs: List[AuditJob]):
        """Audit a whole drained backlog through ONE grouped kernel call:
        the per-round expert-bank snapshots stack to ``(R*N, ...)``, the
        per-round tasks concatenate row-wise, and
        ``VerifierPool.audit_rounds`` fuses every sampled leaf of every
        drained round into a single recompute + one hash pass.  The
        fetch-by-manifest data-availability contract is kept per
        (round, sampled expert) — each fetch resolves the version that
        round committed against."""
        cfg = self.cfg
        ctxs = [ctx_store[j.round_id] for j in jobs]
        coms = [protocol.rounds[j.round_id].commitment for j in jobs]
        banks = [c["prev"][1] for c in ctxs]
        xins = [c["xin"] for c in ctxs]
        # pad multi-round drains to a FIXED (window+1)-slot layout —
        # constant stacked shapes, so the grouped kernel compiles once
        # per batch size instead of once per backlog size (padding slots
        # repeat round 0's bank and contribute zero task rows; no sample
        # ever indexes them).  Single-round drains keep the unpadded
        # per-round layout the synchronous scheduler always uses.
        row_maps = [c.row_index for c in coms]
        slots = (self.trust_cfg.challenge_window + 1 if len(jobs) > 1
                 else 1)
        slots = max(slots, len(jobs))
        # +1: every round's slot ends with at least one zero row — the
        # sentinel empty bucket slots of a sparse commitment gather from
        bmax = max(len(x) for x in xins) + 1
        row_off = np.arange(slots + 1) * bmax
        pad_banks = banks + [banks[0]] * (slots - len(banks))
        stacked_bank = jax.tree_util.tree_map(
            lambda *ls: jnp.concatenate([jnp.asarray(a) for a in ls], 0),
            *pad_banks)
        xpad = np.zeros((slots * bmax,) + xins[0].shape[1:],
                        xins[0].dtype)
        for k, x in enumerate(xins):
            xpad[k * bmax:k * bmax + len(x)] = x
        xcat = jnp.asarray(xpad)
        fetched: set = set()

        def fetch(k: int, e: int):
            if (k, e) in fetched:
                return
            self._fetch_expert_manifest(ctxs[k]["manifests"][e])
            fetched.add((k, e))

        def multi_fn(slot_ids, experts, slices):
            for k, e in sorted({(int(k), int(e))
                                for k, e in zip(slot_ids, experts)}):
                fetch(k, e)
            if self.mesh_shards > 1:
                return sharded_multi(slot_ids, experts, slices)
            # merged drains carry more (and more variable) samples than a
            # per-round audit: bucket to the next power of two so the
            # grouped call settles on O(1) compiled shapes
            bucket = 8
            while bucket < len(experts):
                bucket *= 2
            idx, gid, n = pack_audit_batch_multi(slot_ids, experts, slices,
                                                 row_off, cfg.num_experts,
                                                 bucket=bucket,
                                                 row_maps=row_maps)
            out = self._batched_recompute_call(stacked_bank, xcat,
                                               jnp.asarray(idx),
                                               jnp.asarray(gid))
            return np.asarray(out[:n])

        def sharded_multi(slot_ids, experts, slices):
            # the merged drain under mesh execution: every sampled leaf
            # still recomputes on the edge shard owning its expert — the
            # stacked (slots*N) bank restacks per shard to (slots*E_l)
            # with local expert ids and shard-sliced routing, and the
            # outputs reassemble into the one (S, Cmax, C) tensor
            # audit_rounds hashes (bitwise the unsharded call's rows)
            e_l, groups = self._shard_groups(experts)
            cmax = max(sl.stop - sl.start for sl in slices)
            out = None
            for s, sel in sorted(groups.items()):
                bank_s = jax.tree_util.tree_map(
                    lambda a: a.reshape((slots, cfg.num_experts)
                                        + a.shape[1:])
                    [:, s * e_l:(s + 1) * e_l]
                    .reshape((slots * e_l,) + a.shape[1:]),
                    stacked_bank)
                rmaps_s = [None if rm is None
                           else rm[s * e_l:(s + 1) * e_l]
                           for rm in row_maps]
                bucket = 8
                while bucket < len(sel):
                    bucket *= 2
                idx, gid, n = pack_audit_batch_multi(
                    [slot_ids[i] for i in sel],
                    [int(experts[i]) - s * e_l for i in sel],
                    [slices[i] for i in sel], row_off, e_l,
                    bucket=bucket, row_maps=rmaps_s)
                part = np.asarray(self._batched_recompute_call(
                    bank_s, xcat, jnp.asarray(idx), jnp.asarray(gid)))[:n]
                if out is None:
                    out = np.zeros((len(experts), cmax) + part.shape[2:],
                                   part.dtype)
                w = min(part.shape[1], cmax)
                for j, i in enumerate(sel):
                    out[i, :w] = part[j, :w]
                self._book_audit_rows(s, slices, sel)
            return out

        return protocol.verifiers.audit_rounds(coms, multi_fn)

    def _drain_trust(self, protocol, ctx_store, cid_store, now,
                     domain: str) -> Dict:
        """Drain the deferred-audit backlog: run every queued audit (one
        merged grouped call under the batched backend), court-resolve the
        challenged rounds in round order, and — for the training domain —
        roll back the whole optimistic chain built on a convicted round
        (restore the pre-fraud snapshot, re-execute every voided round
        honestly).  Emits one rollback block per conviction."""
        cfg, tc = self.cfg, self.trust_cfg
        jobs = protocol.pop_audit_jobs(now)
        summary: Dict = {"drained": [j.round_id for j in jobs],
                         "audited_leaves": 0, "fraud_proofs": 0,
                         "convicted": [], "slashed": [],
                         "replayed_metrics": None}
        if not jobs:
            return summary
        # verifier-pool work: concurrent with later rounds in deployment,
        # so off the critical path under pipelined scheduling — the
        # off_path span's seconds land in its own audit metric and are
        # natively excluded from every enclosing phase metric (the
        # consensus span of the committing round).  Courts + chain
        # replay below stay on the critical path — state must be
        # settled.  Synchronous scheduling keeps the drain on-path (no
        # metric: its time belongs to consensus, as before).
        off = tc.scheduling == "pipelined"
        metric = (("bmoe.audit_s" if domain == "train"
                   else "bmoe.audit_infer_s") if off else None)
        with self.obs.span("audit-drain", metric=metric, off_path=off,
                           domain=domain,
                           drained=[j.round_id for j in jobs]):
            if tc.audit_backend == "batched":
                reports_by_rid = self._audit_jobs_merged(protocol,
                                                         ctx_store, jobs)
            else:
                reports_by_rid = {
                    j.round_id: protocol.verifiers.audit(
                        protocol.rounds[j.round_id].commitment,
                        j.recompute_fn)
                    for j in jobs}
            for job in jobs:
                reports = reports_by_rid[job.round_id]
                protocol.apply_reports(job.round_id, reports,
                                       job.recompute_fn)
                audited = sum(r.recomputed_leaves for r in reports)
                com = protocol.rounds[job.round_id].commitment
                summary["audited_leaves"] += audited
                # rows_per_expert is the capacity bucket under sparse
                # dispatch: audit recompute shrinks with execution
                # compute
                self.verify_stats["verify_evals"] += \
                    audited * com.rows_per_expert \
                    / max(com.chunks_per_expert, 1)

        # courts fire in round order, so an early conviction invalidates
        # ACCEPTED descendants before their (clean) audits can finalize
        # them, while CHALLENGED descendants still get their own verdict
        n_rollbacks = len(protocol.rollbacks)
        # the stake book is shared across the train/infer protocols and
        # their round-id namespaces overlap — attribute slashes by the
        # events this drain books, never by round-id lookup
        n_events = len(protocol.stakes.events)
        challenged = sorted(
            j.round_id for j in jobs
            if protocol.rounds[j.round_id].phase is RoundPhase.CHALLENGED)
        for rid in challenged:
            state = protocol.rounds[rid]
            if state.phase is not RoundPhase.CHALLENGED:
                continue
            ctx = ctx_store[rid]
            with self.obs.span("court", domain=domain, round=rid,
                               executor=state.executor) as csp:
                pub = self._court_publish(ctx, state.commitment.claimed,
                                          rid)
                verdict = protocol.court.escalate(
                    rid, pub, state.executor,
                    active=np.asarray(ctx["active"]))
                state = protocol.resolve(rid, verdict)
                csp.set(verdict=state.phase.value)
            summary["fraud_proofs"] += len(state.proofs)
            self.verify_stats["escalate_evals"] += \
                cfg.num_edges * cfg.num_experts \
                * state.commitment.rows_per_expert
            for cid in cid_store.pop(rid, []):
                self.expert_store.release(cid)
            if state.phase is RoundPhase.ROLLED_BACK:
                summary["convicted"].append(rid)

        summary["slashed"] = sorted(
            {ev.edge for ev in protocol.stakes.events[n_events:]})
        if summary["convicted"] and domain == "train":
            with self.obs.span("rollback-replay",
                               convicted=summary["convicted"]):
                summary["replayed_metrics"] = self._replay_chain(
                    min(summary["convicted"]))
        for rec in protocol.rollbacks[n_rollbacks:]:
            self._mine({"kind": "rollback", "domain": domain,
                        "rollback_of": rec.round_id,
                        "executor": rec.executor,
                        "chain": [rec.round_id] + rec.invalidated,
                        "invalidated": rec.invalidated,
                        "slashed": [rec.executor],
                        "at_round": self.round})
        return summary

    def _replay_chain(self, first: int):
        """Chained rollback: restore the (gate, experts) snapshot the
        convicted round started from and re-execute every voided round —
        the convicted one plus its INVALIDATED descendants — honestly and
        in order, exactly one slash having been booked per conviction.
        Returns the replayed metrics of the newest round (the host's
        current round, when it is part of the chain)."""
        cfg = self.cfg
        chain = [rid for rid in sorted(self._round_ctx)
                 if rid >= first and self.protocol.rounds[rid].phase in
                 (RoundPhase.ROLLED_BACK, RoundPhase.INVALIDATED)]
        self.gate, self.experts = self._round_ctx[first]["prev"]
        metrics = None
        for rid in chain:
            ctx = self._round_ctx[rid]
            (self.gate, self.experts, metrics) = self._train_step(
                self.gate, self.experts, ctx["x"], ctx["y"],
                jnp.zeros_like(jnp.asarray(ctx["mask_e"])),
                jax.random.fold_in(ctx["rkey"], 1), ctx["atk"].noise_std,
                jnp.asarray(ctx["atk"].colluding), ctx["gate_bias"],
                ctx["active"], jnp.int32(ctx["executor"]))
            metrics = jax.tree_util.tree_map(np.asarray, metrics)
            self.verify_stats["base_evals"] += \
                self._exec_evals(len(ctx["xin"]))
            # the voided versions were built on revoked state: republish
            # each replayed round's honest successor version in place
            # (put_version replaces the same (object, version) tag).
            # Full-bank republish, not just the replay's routed experts:
            # the voided lineage may have routed (and published)
            # DIFFERENT experts at this version tag, and every one of
            # those must be overwritten — chunk dedup keeps the upload at
            # the actually-changed bytes.
            self._publish_bank(None, rid + 1)
        return metrics if chain and chain[-1] == self.round else None

    def _prune_closed_rounds(self, protocol, ctx_store, cid_store):
        """Release snapshots and retained version manifests of rounds
        that hit a terminal phase — a superseded version nobody retains
        is garbage collected from the storage network (the compact fraud
        proofs stay in the round state)."""
        for rid in list(ctx_store):
            if protocol.rounds[rid].phase in TERMINAL_PHASES:
                del ctx_store[rid]
                for cid in cid_store.pop(rid, []):
                    self.expert_store.release(cid)

    def _optimistic_round(self, x, y, atk, mask_e, rkey, executor, prev,
                          metrics, payload, gate_bias, active):
        """Commit -> optimistic accept -> async audit -> (challenge ->
        court -> slash + chained rollback) for one training round.

        Under ``scheduling="pipelined"`` (default) the round's audit is
        only *queued* here: the system proceeds to the next rounds on the
        optimistically-accepted state and the backlog drains in one
        grouped burst when the oldest window is about to close.  Fraud
        confirmed after descendants committed rolls the whole chain back
        (``_replay_chain``).  ``scheduling="synchronous"`` keeps the
        audit on the critical path — the pre-pipeline reference
        behavior.  Returns the round's final metrics (the honest
        re-execution's, if rolled back)."""
        cfg, tc = self.cfg, self.trust_cfg
        xin = np.asarray(x if cfg.expert_kind == "cnn"
                         else np.asarray(x).reshape(len(x), -1))
        batch = xin.shape[0]
        row_index, bounds = self._commitment_layout(prev[0], x, batch,
                                                    gate_bias)
        honest = self._eager_outputs(prev[1], xin, bounds, row_index)
        attacked = bool(np.asarray(mask_e)[executor] > 0)
        state = self._commit_round(self.protocol, self.round, executor,
                                   honest, attacked, atk, self.round,
                                   payload["task"], row_index)
        payload["commit_root"] = state.commitment.root[:16]
        if state.commitment.routing_digest:
            payload["routing"] = state.commitment.routing_digest[:16]
        payload["executor"] = executor
        # data-availability contract: retain the expert versions this
        # round committed against until its window closes, and challenge
        # replica nodes for sampled chunks of exactly those manifests
        manifests = self._retain_round_manifests(self.round)
        self._audit_cids[self.round] = manifests
        self._round_ctx[self.round] = {
            "prev": prev, "x": x, "y": y, "xin": xin, "honest": honest,
            "rkey": rkey, "executor": executor,
            "mask_e": np.asarray(mask_e), "atk": atk,
            "gate_bias": gate_bias, "active": active,
            "manifests": manifests,
        }
        self._run_da(self.round, manifests)
        recompute_fn = self._make_recompute(xin, manifests, row_index)
        batch_fn = (self._make_batched_recompute(prev[1], xin, manifests,
                                                 row_index)
                    if tc.audit_backend == "batched" else None)
        self.protocol.schedule_audit(self.round, recompute_fn, batch_fn)

        # synchronous: the audit lands in the commit round itself (the
        # reference oracle); pipelined: drain only once a window forces it
        drain_now = None if tc.scheduling == "synchronous" else self.round
        summary = self._drain_trust(self.protocol, self._round_ctx,
                                    self._audit_cids, drain_now, "train")
        payload["audited_leaves"] = summary["audited_leaves"]
        if summary["drained"]:
            payload["drained_rounds"] = summary["drained"]
        if summary["fraud_proofs"]:
            payload["fraud_proofs"] = summary["fraud_proofs"]
            payload["slashed"] = summary["slashed"]
        if summary["replayed_metrics"] is not None:
            payload["rolled_back"] = True
            metrics = summary["replayed_metrics"]

        # close windows in deadline order (sequential finality: never past
        # an unresolved dispute) and release closed rounds' evidence
        finalized = self.protocol.advance(self.round)
        if finalized:
            payload["finalized_rounds"] = finalized
        self._prune_closed_rounds(self.protocol, self._round_ctx,
                                  self._audit_cids)

        metrics = dict(metrics)
        metrics["rolled_back"] = np.float32(
            1.0 if payload.get("rolled_back") else 0.0)
        return metrics

    # ------------------------------------------------- pipeline flushing
    def flush_trust(self) -> Dict:
        """Close out the optimistic pipeline: run every still-queued audit
        (training and inference domains), court-resolve what they raise,
        and advance both clocks past the last open window so every
        committed round reaches a terminal phase.  Call at the end of a
        run (or before comparing two runs) — it is the pipelined
        equivalent of the synchronous scheduler's per-round settlement."""
        out: Dict = {}
        if self.protocol is None:
            return out
        summary = self._drain_trust(self.protocol, self._round_ctx,
                                    self._audit_cids, None, "train")
        if summary["convicted"]:
            out["rolled_back"] = summary["convicted"]
        horizon = self.protocol.clock + self.trust_cfg.challenge_window
        out["finalized"] = self.protocol.advance(horizon)
        self._prune_closed_rounds(self.protocol, self._round_ctx,
                                  self._audit_cids)
        self._run_da(None)               # close every open DA challenge
        if self._infer_protocol is not None:
            isummary = self._drain_trust(self._infer_protocol,
                                         self._infer_ctx,
                                         self._infer_audit_cids, None,
                                         "infer")
            self._record_infer_verdicts(isummary)
            ihorizon = (self._infer_protocol.clock
                        + self.trust_cfg.challenge_window)
            out["infer_finalized"] = self._infer_protocol.advance(ihorizon)
            for frid in out["infer_finalized"]:
                self.infer_log.append({"event": "finalize", "round": frid})
            self._prune_closed_rounds(self._infer_protocol, self._infer_ctx,
                                      self._infer_audit_cids)
        return out

    # -------------------------------------------- optimistic inference
    def _ensure_infer_protocol(self) -> OptimisticProtocol:
        if self._infer_protocol is None:
            # its own round clock/window, but the SAME stake book, court
            # and reputation ledger: one edge deposit backs both
            # workloads, and an inference conviction bars the executor
            # from the training rotation too
            # chained=False: inference batches run against frozen weights,
            # so rounds are independent — a conviction revokes only its
            # own round, never later in-flight batches
            self._infer_protocol = OptimisticProtocol(
                self.trust_cfg, self.cfg.num_edges, self.reputation,
                stakes=self.protocol.stakes, court=self.protocol.court,
                chained=False, metrics=self.obs.metrics,
                namespace="trust.infer")
        return self._infer_protocol

    def _record_infer_verdicts(self, summary: Dict) -> None:
        for rid in summary["convicted"]:
            self.infer_log.append({"event": "revoke", "round": rid,
                                   "executor":
                                       self._infer_protocol.rounds[rid]
                                       .executor})

    def pending_inference(self) -> List[int]:
        """Inference rounds still inside their challenge window."""
        return ([] if self._infer_protocol is None
                else self._infer_protocol.pending())

    # ------------------------------------------------- unified reporting
    @property
    def _timers(self) -> Dict[str, float]:
        """The legacy phase-timer dict, as a read-only view over the obs
        registry (same keys and values as the pre-obs ad-hoc dict).
        Writes happen only through spans — one measurement substrate."""
        m = self.obs.metrics
        return {k: float(m.value(n))
                for k, n in self._TIMER_METRICS.items()}

    def obs_report(self, expert_bytes: Optional[int] = None,
                   result_bytes: Optional[int] = None,
                   rounds: Optional[int] = None) -> Dict:
        """The unified observability entry point: one dict with every
        layer's numbers, all read from the single metrics registry.

        Sections: ``metrics`` (the flat registry snapshot),
        ``timers`` (legacy phase-seconds keys), ``storage`` (the exact
        ``storage_report()`` shape), ``verification`` (the exact
        ``verification_report()`` shape), and — when the byte/round
        arguments are given — ``latency`` (the exact ``latency_report()``
        shape).  The legacy report methods are thin views over this."""
        out: Dict = {
            "metrics": self.obs.metrics.snapshot(),
            "timers": dict(self._timers),
            "storage": {"network": dict(self.storage.stats),
                        "store": dict(self.expert_store.stats),
                        "cache": (dict(self.edge_cache.stats)
                                  if self.edge_cache else None),
                        "da": dict(self.da.stats) if self.da else None,
                        "wall_s": self._timers["storage"]},
            "verification": self.verification_report(),
        }
        if rounds is not None:
            out["latency"] = self._latency_section(
                expert_bytes or 0, result_bytes or 0, rounds)
        return out

    # ----------------------------------------------------- latency model
    def latency_report(self, expert_bytes: int, result_bytes: int,
                       rounds: int) -> Dict[str, float]:
        """Per-round latency decomposition (paper Fig. 4b is relative):
        measured compute/consensus/chain wall-clock + modeled comms.
        A thin view over ``obs_report()`` — keys unchanged from pre-obs."""
        return self.obs_report(expert_bytes, result_bytes,
                               rounds)["latency"]

    def _latency_section(self, expert_bytes: int, result_bytes: int,
                         rounds: int) -> Dict[str, float]:
        cfg = self.cfg
        bw = cfg.bandwidth_bytes_per_s
        if cfg.framework == "bmoe":
            # every edge downloads all K activated experts + uploads K results
            t_comm = (cfg.num_edges * cfg.top_k * expert_bytes
                      + cfg.num_edges * cfg.top_k * result_bytes) / bw
        elif cfg.framework == "optimistic":
            tc = self.trust_cfg
            # executor: K expert downloads + K result uploads + 32B root;
            # auditors: expected audit_rate of the N experts re-fetched
            # plus the sampled result chunks (audit_rate is the pool-wide
            # sampled fraction — already split across verifiers)
            audit_bytes = tc.audit_rate * (
                cfg.num_experts * expert_bytes + result_bytes)
            t_comm = (cfg.top_k * expert_bytes + cfg.top_k * result_bytes
                      + 32 + audit_bytes) / bw
        else:
            t_comm = cfg.top_k * result_bytes / bw
        r = max(rounds, 1)
        timers = self._timers
        return {
            "compute_s": timers["compute"] / r,
            "comm_s": t_comm,
            "consensus_s": timers["consensus"] / r,
            "chain_s": timers["chain"] / r,
            # verifier-pool audit seconds drained off the critical path
            # (pipelined scheduling only; synchronous audits sit inside
            # consensus_s) — reported separately, excluded from total_s
            "audit_offpath_s": timers["audit"] / r,
            # host wall-clock of the storage simulation (chunk hashing,
            # cache resolution) — reported separately, excluded from
            # total_s: the *transfer* time it simulates is already the
            # modeled comm_s term (see storage_report() for the cost-
            # model view)
            "storage_s": timers["storage"] / r,
            "total_s": timers["compute"] / r + t_comm
                       + timers["consensus"] / r
                       + timers["chain"] / r,
        }

    def verification_report(self) -> Dict[str, float]:
        """Per-round verification compute, in expert-evaluations x samples
        (the simulation broadcasts copies instead of physically paying for
        them, so redundancy/audit cost is counted, not wall-clocked)."""
        r = max(self.verify_stats["rounds"], 1)
        verify = self.verify_stats["verify_evals"]
        escalate = self.verify_stats["escalate_evals"]
        return {
            "base_evals_per_round": self.verify_stats["base_evals"] / r,
            "verify_evals_per_round": verify / r,
            "escalate_evals_per_round": escalate / r,
            "total_verification_per_round": (verify + escalate) / r,
        }


# ---------------------------------------------------------------- steps
def _flatten_for_gate(x):
    return x.reshape(x.shape[0], -1)


def sparse_capacity(cfg, batch: int) -> int:
    """Bucket slots per expert under sparse dispatch: the balanced share
    ``batch*top_k/num_experts`` scaled by ``capacity_factor``, rounded up
    to a multiple of 8 (GEMM-tile friendly) and capped at ``batch`` (an
    expert can receive at most one slot per token: top-k indices are
    distinct per token)."""
    cap = int(np.ceil(cfg.capacity_factor * batch * cfg.top_k
                      / cfg.num_experts))
    cap = min(-(-cap // 8) * 8, batch)
    return max(cap, 1)


def _sparse_dispatch(xin, topi, cfg, capacity):
    """Scatter the top-k assignments into per-expert capacity buckets.

    Returns (buf (N, capacity, *xin.shape[1:]), eid (B*k,), pos (B*k,),
    keep (B*k,)): slot ``pos[j]`` of expert ``eid[j]``'s bucket holds
    token ``j // k``'s input (overflowing assignments are dropped — the
    bucket row stays zero and the combine masks the slot out)."""
    B = xin.shape[0]
    eid = topi.reshape(-1)                              # (B*k,) row-major
    pos, keep, _ = capacity_positions(eid[None], cfg.num_experts, capacity)
    pos, keep = pos[0], keep[0]
    posc = jnp.where(keep, pos, capacity - 1)           # clamp drops
    kshape = (B * cfg.top_k,) + (1,) * (xin.ndim - 1)
    gath = jnp.repeat(xin, cfg.top_k, axis=0) \
        * keep.reshape(kshape).astype(xin.dtype)
    buf = jnp.zeros((cfg.num_experts, capacity) + xin.shape[1:],
                    xin.dtype).at[eid, posc].add(gath)
    return buf, eid, posc, keep


def _route_for_commit(gate, x, gate_bias, *, cfg):
    """The routing the executor publishes with a sparse commitment:
    exactly the gate + top-k + capacity-bucket assignment the forward
    uses, re-derived from the round's snapshot state."""
    flat = _flatten_for_gate(x)
    logits = ex.gate_apply(gate, flat) + gate_bias[None, :]
    _, topi = ex.sparse_gate_weights(logits, cfg.top_k)
    capacity = sparse_capacity(cfg, flat.shape[0])
    eid = topi.reshape(-1)
    pos, keep, _ = capacity_positions(eid[None], cfg.num_experts, capacity)
    return eid, pos[0], keep[0]


def _trust_outputs(outs, mask_e, key, noise_std, colluding, cfg, active,
                   executor, shard=None):
    """Framework-specific corruption + consensus over the per-expert
    output buffer ``outs`` (N, R, ...) — R is the full batch under dense
    dispatch, the capacity bucket under sparse (the vote and the attack
    surface shrink with the compute).

    ``shard=(sid, E_l)`` marks mesh execution: ``outs`` is edge ``sid``'s
    local expert slice ``(E_l, R, ...)``.  Corruption noise is then drawn
    at the full ``(N, R, ...)`` shape and sliced to the local experts —
    the counter-based PRNG makes every edge's corrupted bytes bitwise
    the single-device oracle's — and the consensus vote runs over the
    local experts only (the vote is per-expert independent, so local
    verdicts concatenate to exactly the global ones)."""
    n_local = outs.shape[0]
    full_shape = (cfg.num_experts,) + outs.shape[1:]

    def local(a):
        # barrier first: fusing the threefry/erfinv noise computation
        # into the corruption mul-add chain lets XLA contract the ops
        # shape-dependently (observed: last-ulp drift between the
        # (E_l, ...) mesh slice and the (N, ...) oracle); materializing
        # the full-shape draw makes the remaining slice + elementwise
        # chain bit-stable.  The draw is never differentiated (constant
        # w.r.t. params), so the missing optimization_barrier vjp rule
        # is moot.
        a = jax.lax.optimization_barrier(a)
        if shard is None:
            return a
        return jax.lax.dynamic_slice_in_dim(a, shard[0] * shard[1],
                                            shard[1], axis=0)

    if cfg.framework == "optimistic":
        # single-executor optimistic path: the round's result is whatever
        # the rotating executor published (corrupted iff it attacks);
        # verification happens off the jitted path (commit/audit/court)
        exec_flag = mask_e[executor]
        noise = local(jax.random.normal(key, full_shape, outs.dtype))
        trusted = outs + noise_std * noise * exec_flag
        support = jnp.full((n_local,), 1.0)
        flags = jnp.ones((n_local, cfg.num_edges), jnp.int32)
    elif cfg.framework == "traditional":
        # edge i employs expert i: manipulation hits expert i directly
        # (the sliced form below is manipulate_single restricted to the
        # local experts — same noise draw, same mask rows)
        mask_n = mask_e[:cfg.num_experts]
        noise = local(jax.random.normal(key, full_shape, outs.dtype))
        m = local(mask_n).reshape((n_local,) + (1,) * (outs.ndim - 1))
        trusted = outs + noise_std * noise * m
        support = jnp.full((n_local,), 1.0)
        flags = jnp.ones((n_local, cfg.num_edges), jnp.int32)
    else:
        # redundancy: every edge publishes every expert's result.  Each
        # edge's manipulated copy draws from its own folded key (the
        # colluding coalition folds a shared id, publishing identical
        # results), so only the (N, M, ...) publication tensor the vote
        # needs is materialized — not separate colluding + independent
        # noise tensors plus a full-size select.  The draw is vmapped
        # bare (optimization_barrier has no batching rule) and the
        # stacked tensor barriered before the slice + corruption
        # arithmetic — see ``local`` on why the barrier matters.
        def edge_noise(m):
            fid = jnp.where(colluding, 0, m)
            return jax.random.normal(jax.random.fold_in(key, fid),
                                     full_shape, outs.dtype)

        noise = jax.vmap(edge_noise)(jnp.arange(cfg.num_edges))
        noise = jax.lax.optimization_barrier(noise)      # (M, N, ...)
        if shard is not None:
            noise = jax.lax.dynamic_slice_in_dim(
                noise, shard[0] * shard[1], shard[1], axis=1)
        mshape = (1, cfg.num_edges) + (1,) * (outs.ndim - 1)
        pub = outs[:, None] + noise_std * jnp.moveaxis(noise, 0, 1) \
            * mask_e.reshape(mshape)                     # (N|E_l, M, ...)
        # Step 3: distributed consensus = majority vote over the M copies
        # (reputation-excluded edges barred from electorate, §VI-D)
        act = active if active is not None else jnp.ones(cfg.num_edges)
        trusted, support, flags = kref.redundancy_vote_masked_ref(pub, act)
    return trusted, support, flags


@jax.custom_vjp
def _grad_barrier(x):
    """Identity whose cotangent passes through an optimization barrier.

    Without it XLA fuses the ownership-mask reduction from the return
    all_to_all's transpose with the bias-gradient capacity reduce inside
    the expert vjp, summing the per-slot cotangents over (msize, cap)
    jointly — a different float association order than the oracle's
    plain cap reduce (observed: last-ulp drift on the experts' output
    bias after one SGD step, every other gradient bitwise equal).
    Materializing the cotangent here restores the oracle's reduction
    shape, and with it bit-identical parameter updates."""
    return x


def _grad_barrier_fwd(x):
    return x, None


def _grad_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


def _mesh_sparse_forward(experts, xin, topi, weights, capacity, mask_e, key,
                         noise_std, colluding, cfg, apply_grouped, active,
                         executor, mesh, msize):
    """Sparse dispatch across the edge mesh (BMoEConfig.mesh="on").

    Routing runs globally on the replicated gate — the identical ops the
    single-device oracle runs.  Each edge shard then scatters only its
    own token slice into a full-shape send buffer at the GLOBAL bucket
    positions, and the buffers cross the mesh via all_to_all; summing
    the per-shard partials is exact (every bucket slot has at most one
    nonzero contributor — its unique token — and 0+x is exact), so the
    local ``(E_l, capacity, C)`` buffers each edge computes its experts
    on are bitwise the oracle's bucket slices.  Per-device dispatch wire
    bytes are ~num_experts*capacity*C ~ capacity_factor*B*top_k*C —
    independent of the expert count (gated in benchmarks/mesh_bench.py).

    Trust corruption draws noise at the full ``(N, ...)`` shape and
    slices the local experts (see ``_trust_outputs``), so each edge's
    attacked bytes are bitwise the oracle's too.  The return all_to_all
    hands every token's combine rows back to the shard owning the token
    via the ``slot_src`` ownership map (derived from the replicated
    routing, so it needs no communication).

    Every non-bank input enters the shard_map REPLICATED and is sliced
    inside the body: the transpose then psums per-shard cotangents that
    are exact zeros outside each shard's slice, keeping the backward
    pass — and hence every parameter update — bit-identical to the
    oracle as well.  (The scalar *loss* is the one quantity allowed to
    differ in final ulps: its mean over the sharded output reduces in a
    different order.)"""
    N, k = cfg.num_experts, cfg.top_k
    E_l = N // msize
    B = xin.shape[0]
    B_l = -(-B // msize)
    B_pad = B_l * msize
    tail = xin.shape[1:]

    eid = topi.reshape(-1)                              # (B*k,) row-major
    pos, keep, _ = capacity_positions(eid[None], N, capacity)
    pos, keep = pos[0], keep[0]
    posc = jnp.where(keep, pos, capacity - 1)
    dropped = (B * k) - keep.sum().astype(jnp.float32)
    wk = jnp.take_along_axis(weights, topi, axis=1).reshape(-1)
    wk = wk * keep.astype(wk.dtype)

    # which token shard owns each filled bucket slot (-1: empty slot) —
    # token b lives on shard b // B_l, matching the slices below
    towner = jnp.repeat(jnp.arange(B, dtype=jnp.int32) // B_l, k)
    slot_src = jnp.full((N, capacity), -1, jnp.int32).at[eid, posc].max(
        jnp.where(keep, towner, -1), mode="drop")

    def padtok(a, fill):                                # (B*k,) -> (B_pad*k,)
        if B_pad == B:
            return a
        pad = jnp.full(((B_pad - B) * k,) + a.shape[1:], fill, a.dtype)
        return jnp.concatenate([a, pad], axis=0)

    xin_p = xin if B_pad == B else jnp.concatenate(
        [xin, jnp.zeros((B_pad - B,) + tail, xin.dtype)], axis=0)
    eid_p = padtok(eid, N)          # sentinel expert: dropped by the scatter
    posc_p = padtok(posc, capacity - 1)
    keep_p = padtok(keep, False)
    wk_p = padtok(wk, 0)

    def body(xr, eidr, posr, keepr, wkr, bank_l, src, mask_er, keyr,
             stdr, collr, activer, execr):
        sid = jax.lax.axis_index("model")
        lo = sid * B_l * k
        eidl = jax.lax.dynamic_slice_in_dim(eidr, lo, B_l * k)
        posl = jax.lax.dynamic_slice_in_dim(posr, lo, B_l * k)
        keepl = jax.lax.dynamic_slice_in_dim(keepr, lo, B_l * k)
        wkl = jax.lax.dynamic_slice_in_dim(wkr, lo, B_l * k)
        xl = jax.lax.dynamic_slice_in_dim(xr, sid * B_l, B_l)

        # scatter own tokens into the full-shape buffer at their GLOBAL
        # bucket positions, exchange, and sum the per-shard partials
        kshape = (B_l * k,) + (1,) * len(tail)
        gath = jnp.repeat(xl, k, axis=0) \
            * keepl.reshape(kshape).astype(xl.dtype)
        send = jnp.zeros((N, capacity) + tail, xl.dtype).at[
            eidl, posl].add(gath, mode="drop")
        recv = jax.lax.all_to_all(send.reshape((msize, E_l, capacity)
                                               + tail),
                                  "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        buf_l = recv.sum(axis=0)                    # (E_l, capacity, *tail)

        outs_l = apply_grouped(bank_l, buf_l)       # (E_l, capacity, C)
        outs_l = _grad_barrier(outs_l)
        trusted_l, support_l, flags_l = _trust_outputs(
            outs_l, mask_er, keyr, stdr, collr, cfg, activer, execr,
            shard=(sid, E_l))

        # return exchange: each trusted row goes back to the shard that
        # owns its token (ownership-masked so the sum at the receiver
        # again has at most one nonzero contributor per slot)
        src_l = jax.lax.dynamic_slice_in_dim(src, sid * E_l, E_l, axis=0)
        own = src_l[None] == jnp.arange(msize, dtype=jnp.int32)[:, None,
                                                                None]
        back = jnp.where(
            own.reshape((msize, E_l, capacity)
                        + (1,) * (trusted_l.ndim - 2)),
            trusted_l[None], jnp.zeros((), trusted_l.dtype))
        ret = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        ret = ret.reshape((N, capacity) + trusted_l.shape[2:])

        yk = ret.at[eidl, posl].get(mode="fill", fill_value=0) \
            * wkl[:, None]
        y_l = yk.reshape((B_l, k) + yk.shape[1:]).sum(axis=1)
        return y_l, support_l, flags_l

    rep = P()
    bank_specs = jax.tree_util.tree_map(lambda _: P("model"), experts)
    mapped = _shard_map(
        body, mesh,
        in_specs=(rep, rep, rep, rep, rep, bank_specs, rep, rep, rep,
                  rep, rep, rep, rep),
        out_specs=(P("model"), P("model"), P("model")))
    act = active if active is not None else jnp.ones(cfg.num_edges)
    y, support, flags = mapped(
        xin_p, eid_p, posc_p, keep_p, wk_p, experts, slot_src, mask_e,
        key, jnp.asarray(noise_std, jnp.float32), jnp.asarray(colluding),
        act, jnp.asarray(executor, jnp.int32))
    return y[:B], support, flags, dropped


def _moe_forward(gate, experts, x, mask_e, key, noise_std, colluding, cfg,
                 apply_all, apply_grouped, gate_bias=None, active=None,
                 executor=0, mesh=None, mesh_shards=1):
    """Shared forward: returns (trusted_out (B,C), weights (B,N),
    activation (N,), support (N,), flags (N,M), logits (B,N),
    dropped ()).  With ``mesh`` the sparse path runs sharded over the
    edge mesh (``_mesh_sparse_forward``) — bit-identical outputs."""
    flat = _flatten_for_gate(x)
    xin = x if cfg.expert_kind == "cnn" else flat
    logits = ex.gate_apply(gate, flat)
    if gate_bias is not None:  # §VI-C workload-balance bias (loss-free)
        logits = logits + jax.lax.stop_gradient(gate_bias)[None, :]
    weights, topi = ex.sparse_gate_weights(logits, cfg.top_k)
    B = xin.shape[0]

    if cfg.dispatch == "sparse":
        capacity = sparse_capacity(cfg, B)
        if mesh is not None:
            y, support, flags, dropped = _mesh_sparse_forward(
                experts, xin, topi, weights, capacity, mask_e, key,
                noise_std, colluding, cfg, apply_grouped, active,
                executor, mesh, mesh_shards)
        else:
            # top-k scatter-dispatch: only routed tokens reach an expert
            buf, eid, posc, keep = _sparse_dispatch(xin, topi, cfg,
                                                    capacity)
            outs = apply_grouped(experts, buf)          # (N, cap, C)
            dropped = (B * cfg.top_k) - keep.sum().astype(jnp.float32)
            trusted, support, flags = _trust_outputs(
                outs, mask_e, key, noise_std, colluding, cfg, active,
                executor)
            # aggregate with gate weights (paper: weighted sum over top-K)
            yk = trusted[eid, posc]                     # (B*k, C)
            wk = jnp.take_along_axis(weights, topi, axis=1).reshape(-1)
            wk = wk * keep.astype(wk.dtype)             # drops contribute 0
            y = (yk * wk[:, None]).reshape(B, cfg.top_k, -1).sum(axis=1)
    else:
        if mesh is not None:
            raise ValueError("mesh execution requires dispatch='sparse'")
        outs = apply_all(experts, xin)                  # (N, B, C)
        dropped = jnp.zeros((), jnp.float32)
        trusted, support, flags = _trust_outputs(outs, mask_e, key,
                                                 noise_std, colluding,
                                                 cfg, active, executor)
        y = jnp.einsum("bn,nbc->bc", weights, trusted)
    activation = (weights > 0).sum(axis=0).astype(jnp.float32)
    return y, weights, activation, support, flags, logits, dropped


def _train_step(gate, experts, x, y, mask_e, key, noise_std, colluding,
                gate_bias, active, executor, *, cfg, apply_all,
                apply_grouped, mesh=None, mesh_shards=1):
    def loss_fn(params):
        gate_p, experts_p = params
        out, w, activation, support, flags, _, dropped = _moe_forward(
            gate_p, experts_p, x, mask_e, key, noise_std, colluding, cfg,
            apply_all, apply_grouped, gate_bias, active, executor,
            mesh, mesh_shards)
        logp = jax.nn.log_softmax(out, axis=-1)
        loss = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
        return loss, (activation, support, flags, dropped)

    (loss, (activation, support, flags, dropped)), grads = \
        jax.value_and_grad(loss_fn, has_aux=True)((gate, experts))
    grads_e = grads[1]
    if mesh is not None:
        # keep bank grads (and therefore the updated bank) on the edge
        # mesh: without the constraint XLA materializes the replicated
        # grad as zero-padded shards + an all-reduce that scales with
        # the bank size, re-coupling wire bytes to the expert count.
        # Each element has exactly one contributing shard, so the
        # shard-local update is bitwise the same bank.
        bank_spec = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("model"))
        grads_e = jax.tree_util.tree_map(
            lambda g: jax.lax.with_sharding_constraint(g, bank_spec),
            grads_e)
    new_gate = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g, gate,
                                      grads[0])
    new_experts = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g,
                                         experts, grads_e)
    metrics = {"loss": loss, "activation": activation, "support": support,
               "flags": flags, "dropped": dropped}
    return new_gate, new_experts, metrics


def _infer_step(gate, experts, x, mask_e, key, noise_std, colluding,
                gate_bias, active, executor, *, cfg, apply_all,
                apply_grouped, mesh=None, mesh_shards=1):
    out, w, activation, support, flags, _, _ = _moe_forward(
        gate, experts, x, mask_e, key, noise_std, colluding, cfg, apply_all,
        apply_grouped, gate_bias, active, executor, mesh, mesh_shards)
    return out, activation, support
