"""The B-MoE system (paper §IV): task publisher + edge layer + blockchain
layer + storage layer, running the full Step 1-6 workflow for training
and the Step 1-3 (+6) workflow for inference.

Two frameworks are implemented behind one API:

- ``framework="traditional"``: the paper's baseline — edge i employs
  expert i; no redundancy, no consensus; malicious edges corrupt their
  own expert's results (and the gate must cope on its own, §III).
- ``framework="bmoe"``: every edge computes ALL activated experts
  (redundancy mechanism); the blockchain layer majority-votes the
  per-expert results, aggregates the trusted ones, and records the round
  in a PoW block; updated experts are hash-voted and stored by CID
  (Steps 4-5) during training.

The numerics (expert compute, manipulation, majority vote, SGD) run as
one jitted step; the ledger/PoW/storage bookkeeping runs per round in
Python, mirroring the paper's on-chain/off-chain split.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import experts as ex
from repro.core.attacks import AttackConfig, round_attack_mask, poison_tree
from repro.core.consensus import ProofOfWork, majority_tree_vote
from repro.core.ledger import Block, Ledger, digest_array, digest_tree
from repro.core.reputation import ReputationConfig, ReputationLedger, WorkloadBalancer
from repro.core.storage import StorageNetwork
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class BMoEConfig:
    num_experts: int = 10           # N (paper §V)
    num_edges: int = 10             # M
    top_k: int = 3                  # K
    expert_kind: str = "mlp"        # mlp (fmnist) | cnn (cifar)
    in_dim: int = 784
    in_ch: int = 1
    num_classes: int = 10
    lr: float = 0.01
    framework: str = "bmoe"         # bmoe | traditional
    attack: AttackConfig = AttackConfig()
    pow_difficulty: int = 8
    num_chain_nodes: int = 8
    store_every: int = 50           # expert->storage cadence (rounds)
    bandwidth_bytes_per_s: float = 125e6   # 1 Gbps edge links
    seed: int = 0
    # paper §VI extensions (see repro.core.reputation)
    reputation: Optional[ReputationConfig] = None       # §VI-B/D
    workload_balance: bool = False                      # §VI-C
    balance_eta: float = 0.5


class BMoESystem:
    """One instantiation of Fig. 3. See module docstring."""

    def __init__(self, cfg: BMoEConfig):
        self.cfg = cfg
        key = jax.random.PRNGKey(cfg.seed)
        kg, ke = jax.random.split(key)
        gate_in = cfg.in_dim if cfg.expert_kind == "mlp" else 32 * 32 * cfg.in_ch
        from repro.models.builder import materialize
        self.gate = materialize(ex.gate_decl(gate_in, cfg.num_experts), kg)
        self.experts, self._apply_all = ex.make_expert_bank(
            cfg.expert_kind, cfg.num_experts, ke, in_dim=cfg.in_dim,
            in_ch=cfg.in_ch, out=cfg.num_classes)
        self.ledger = Ledger()
        self.storage = StorageNetwork(num_nodes=4, replication=2,
                                      seed=cfg.seed)
        self.pow = ProofOfWork(cfg.num_chain_nodes,
                               difficulty_bits=cfg.pow_difficulty,
                               seed=cfg.seed)
        self.round = 0
        self.reputation = (ReputationLedger(cfg.num_edges, cfg.reputation)
                           if cfg.reputation else None)
        self.balancer = (WorkloadBalancer(cfg.num_experts, cfg.balance_eta)
                         if cfg.workload_balance else None)
        self.activation_counts = np.zeros(cfg.num_experts)
        self.activation_total = 0
        self._expert_cids: List[str] = []
        self._timers: Dict[str, float] = {"compute": 0.0, "consensus": 0.0,
                                          "chain": 0.0}
        self._train_step = jax.jit(functools.partial(
            _train_step, cfg=cfg, apply_all=self._apply_all))
        self._infer_step = jax.jit(functools.partial(
            _infer_step, cfg=cfg, apply_all=self._apply_all))

    # ------------------------------------------------------------ api
    def train_round(self, x, y, *, attack: Optional[AttackConfig] = None):
        """One full Step 1-6 round on one published task (batch)."""
        cfg = self.cfg
        atk = attack if attack is not None else cfg.attack
        rkey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 17),
                                  self.round)
        mask_e = round_attack_mask(atk, cfg.num_edges, rkey)

        gate_bias, active = self._controls()
        t0 = time.perf_counter()
        (self.gate, self.experts, metrics) = self._train_step(
            self.gate, self.experts, x, y, mask_e,
            jax.random.fold_in(rkey, 1), atk.noise_std,
            jnp.asarray(atk.colluding), gate_bias, active)
        metrics = jax.tree_util.tree_map(np.asarray, metrics)
        self._timers["compute"] += time.perf_counter() - t0
        self._update_controllers(metrics)

        self.activation_counts += metrics["activation"]
        self.activation_total += int(x.shape[0]) * cfg.top_k

        payload = {
            "round": self.round, "kind": "train",
            "task": digest_array(np.asarray(x)[:8]),
            "loss": float(metrics["loss"]),
        }
        if cfg.framework == "bmoe":
            # Step 4-5: edges upload updated experts; hash vote + storage.
            t0 = time.perf_counter()
            payload["trusted_supports"] = metrics["support"].tolist()
            self._expert_hash_vote(atk, rkey, payload)
            self._timers["consensus"] += time.perf_counter() - t0
            # Step 6: block generation under PoW.
            t0 = time.perf_counter()
            self._mine(payload)
            self._timers["chain"] += time.perf_counter() - t0
        self.round += 1
        return metrics

    def infer(self, x, *, attack: Optional[AttackConfig] = None):
        """Steps 1-3 (+6): forward only, no updates (paper: 4-5 skipped)."""
        cfg = self.cfg
        atk = attack if attack is not None else cfg.attack
        rkey = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 91),
                                  self.round + 1_000_000)
        mask_e = round_attack_mask(atk, cfg.num_edges, rkey)
        gate_bias, active = self._controls()
        logits, activation, support = self._infer_step(
            self.gate, self.experts, x, mask_e, jax.random.fold_in(rkey, 1),
            atk.noise_std, jnp.asarray(atk.colluding), gate_bias, active)
        return np.asarray(logits), np.asarray(activation), np.asarray(support)

    def evaluate(self, x, y, *, attack: Optional[AttackConfig] = None,
                 batch: int = 1000) -> float:
        correct = 0
        for i in range(0, len(x), batch):
            logits, _, _ = self.infer(x[i:i + batch], attack=attack)
            correct += int((logits.argmax(-1) == np.asarray(y[i:i + batch])).sum())
        return correct / len(x)

    def _controls(self):
        cfg = self.cfg
        gate_bias = jnp.asarray(self.balancer.bias) if self.balancer \
            else jnp.zeros(cfg.num_experts, jnp.float32)
        if self.reputation is not None:
            active = jnp.asarray(
                (~self.reputation.excluded).astype(np.float32))
        else:
            active = jnp.ones(cfg.num_edges, jnp.float32)
        return gate_bias, active

    def _update_controllers(self, metrics):
        if self.balancer is not None:
            self.balancer.update(metrics["activation"])
        if self.reputation is not None and "flags" in metrics:
            self.reputation.update_from_flags(metrics["flags"])

    @property
    def activation_ratio(self) -> np.ndarray:
        return self.activation_counts / max(self.activation_total, 1)

    # -------------------------------------------------------- internals
    def _expert_hash_vote(self, atk: AttackConfig, rkey, payload):
        """Paper Step 5: each edge uploads the updated experts' hashes; the
        chain accepts the majority; poisoned uploads are rejected."""
        cfg = self.cfg
        honest_digest = digest_tree(self.experts)
        uploads = []
        for m in range(cfg.num_edges):
            if atk.poison_params and m in atk.malicious_edges:
                poisoned = poison_tree(self.experts,
                                       jax.random.fold_in(rkey, 100 + (0 if
                                       atk.colluding else m)),
                                       atk.noise_std)
                uploads.append(digest_tree(poisoned))
            else:
                uploads.append(honest_digest)
        counts: Dict[str, int] = {}
        for d in uploads:
            counts[d] = counts.get(d, 0) + 1
        winner = max(counts, key=counts.get)
        payload["expert_hash"] = winner[:16]
        payload["expert_hash_support"] = counts[winner]
        payload["expert_hash_accepted"] = counts[winner] * 2 > cfg.num_edges
        if winner != honest_digest and payload["expert_hash_accepted"]:
            # majority is malicious: chain is misled (paper §IV-B, >50%)
            payload["chain_misled"] = True
        if self.round % cfg.store_every == 0:
            from repro.core.storage import serialize_tree
            cid = self.storage.put(serialize_tree(self.experts))
            self._expert_cids.append(cid)
            payload["expert_cid"] = cid[:16]

    def _mine(self, payload):
        block = self.pow.mine(len(self.ledger.blocks), self.ledger.head.hash,
                              payload)
        self.ledger.append(block)

    # ----------------------------------------------------- latency model
    def latency_report(self, expert_bytes: int, result_bytes: int,
                       rounds: int) -> Dict[str, float]:
        """Per-round latency decomposition (paper Fig. 4b is relative):
        measured compute/consensus/chain wall-clock + modeled comms."""
        cfg = self.cfg
        bw = cfg.bandwidth_bytes_per_s
        if cfg.framework == "bmoe":
            # every edge downloads all K activated experts + uploads K results
            t_comm = (cfg.num_edges * cfg.top_k * expert_bytes
                      + cfg.num_edges * cfg.top_k * result_bytes) / bw
        else:
            t_comm = cfg.top_k * result_bytes / bw
        r = max(rounds, 1)
        return {
            "compute_s": self._timers["compute"] / r,
            "comm_s": t_comm,
            "consensus_s": self._timers["consensus"] / r,
            "chain_s": self._timers["chain"] / r,
            "total_s": self._timers["compute"] / r + t_comm
                       + self._timers["consensus"] / r
                       + self._timers["chain"] / r,
        }


# ---------------------------------------------------------------- steps
def _flatten_for_gate(x):
    return x.reshape(x.shape[0], -1)


def _moe_forward(gate, experts, x, mask_e, key, noise_std, colluding, cfg,
                 apply_all, gate_bias=None, active=None):
    """Shared forward: returns (trusted_out (B,C), weights (B,N),
    activation (N,), support (N,), flags (N,M))."""
    B = x.shape[0]
    xin = x if cfg.expert_kind == "cnn" else _flatten_for_gate(x)
    logits = ex.gate_apply(gate, _flatten_for_gate(x))
    if gate_bias is not None:  # §VI-C workload-balance bias (loss-free)
        logits = logits + jax.lax.stop_gradient(gate_bias)[None, :]
    weights, topi = ex.sparse_gate_weights(logits, cfg.top_k)
    outs = apply_all(experts, xin)                      # (N, B, C)

    if cfg.framework == "traditional":
        # edge i employs expert i: manipulation hits expert i directly
        from repro.core.attacks import manipulate_single
        mask_n = mask_e[:cfg.num_experts]
        corrupted = manipulate_single(outs, mask_n, noise_std, key)
        trusted = corrupted                              # no consensus
        support = jnp.full((cfg.num_experts,), 1.0)
        flags = jnp.ones((cfg.num_experts, cfg.num_edges), jnp.int32)
    else:
        # redundancy: every edge publishes every expert's result
        from repro.core.attacks import manipulate_outputs
        pub = jnp.broadcast_to(outs[:, None], (cfg.num_experts,
                                               cfg.num_edges) + outs.shape[1:])
        # colluding vs independent manipulation, traced under jit
        noise_c = jax.random.normal(key, (cfg.num_experts, 1) + outs.shape[1:],
                                    outs.dtype)
        noise_i = jax.random.normal(jax.random.fold_in(key, 7), pub.shape,
                                    outs.dtype)
        noise = jnp.where(colluding, jnp.broadcast_to(noise_c, pub.shape),
                          noise_i)
        mshape = (1, cfg.num_edges) + (1,) * (pub.ndim - 2)
        pub = pub + noise_std * noise * mask_e.reshape(mshape)
        # Step 3: distributed consensus = majority vote over the M copies
        # (reputation-excluded edges barred from electorate, §VI-D)
        act = active if active is not None else jnp.ones(cfg.num_edges)
        trusted, support, flags = kref.redundancy_vote_masked_ref(pub, act)

    # aggregate with gate weights (paper: weighted sum over top-K)
    y = jnp.einsum("bn,nbc->bc", weights, trusted)
    activation = (weights > 0).sum(axis=0).astype(jnp.float32)
    return y, weights, activation, support, flags, logits


def _train_step(gate, experts, x, y, mask_e, key, noise_std, colluding,
                gate_bias, active, *, cfg, apply_all):
    def loss_fn(params):
        gate_p, experts_p = params
        out, w, activation, support, flags, _ = _moe_forward(
            gate_p, experts_p, x, mask_e, key, noise_std, colluding, cfg,
            apply_all, gate_bias, active)
        logp = jax.nn.log_softmax(out, axis=-1)
        loss = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
        return loss, (activation, support, flags)

    (loss, (activation, support, flags)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)((gate, experts))
    new_gate = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g, gate,
                                      grads[0])
    new_experts = jax.tree_util.tree_map(lambda p, g: p - cfg.lr * g,
                                         experts, grads[1])
    metrics = {"loss": loss, "activation": activation, "support": support,
               "flags": flags}
    return new_gate, new_experts, metrics


def _infer_step(gate, experts, x, mask_e, key, noise_std, colluding,
                gate_bias, active, *, cfg, apply_all):
    out, w, activation, support, flags, _ = _moe_forward(
        gate, experts, x, mask_e, key, noise_std, colluding, cfg, apply_all,
        gate_bias, active)
    return out, activation, support
