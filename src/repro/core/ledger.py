"""Hash-linked ledger (the blockchain layer's data structure).

Each block packages, per the paper's Step 6: the round's task id, the
trusted (majority-agreed) expert-output digests, the CIDs of the updated
experts (training only), the final MoE output digest, and the gating
network digest.  Blocks are linked by SHA-256; ``verify_chain`` detects
any tampering (the paper's tamper-proofing property).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional

import numpy as np


def digest_bytes(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def digest_array(x) -> str:
    a = np.asarray(x)
    return digest_bytes(a.tobytes() + str(a.shape).encode() +
                        str(a.dtype).encode())


def digest_tree(tree) -> str:
    """Deterministic digest of a pytree of arrays (expert params, etc.)."""
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    h = hashlib.sha256(str(treedef).encode())
    for leaf in leaves:
        h.update(digest_array(leaf).encode())
    return h.hexdigest()


@dataclasses.dataclass
class Block:
    index: int
    prev_hash: str
    payload: Dict[str, Any]          # JSON-serializable record
    nonce: int = 0
    timestamp: float = 0.0
    miner: int = -1

    def header_bytes(self) -> bytes:
        return json.dumps(
            {"index": self.index, "prev": self.prev_hash,
             "payload": self.payload, "nonce": self.nonce,
             "miner": self.miner},
            sort_keys=True).encode()

    @property
    def hash(self) -> str:
        return digest_bytes(self.header_bytes())


class Ledger:
    """Append-only chain with integrity verification."""

    def __init__(self):
        genesis = Block(0, "0" * 64, {"genesis": True})
        self.blocks: List[Block] = [genesis]

    @property
    def head(self) -> Block:
        return self.blocks[-1]

    def append(self, block: Block) -> None:
        if block.prev_hash != self.head.hash:
            raise ValueError("block does not extend the chain head")
        if block.index != len(self.blocks):
            raise ValueError("bad block index")
        self.blocks.append(block)

    def verify_chain(self) -> bool:
        for i in range(1, len(self.blocks)):
            if self.blocks[i].prev_hash != self.blocks[i - 1].hash:
                return False
            if self.blocks[i].index != i:
                return False
        return True

    def find(self, **kv) -> Optional[Block]:
        for b in reversed(self.blocks):
            if all(b.payload.get(k) == v for k, v in kv.items()):
                return b
        return None

    def find_all(self, **kv) -> List[Block]:
        """All blocks whose payload matches, chain order."""
        return [b for b in self.blocks
                if all(b.payload.get(k) == v for k, v in kv.items())]

    def rollbacks(self) -> List[Block]:
        """The chain's rollback record: one block per confirmed fraud
        (kind="rollback"), each naming the convicted round, the slashed
        executor, and the voided chain of optimistic descendants."""
        return self.find_all(kind="rollback")

    def aggregations(self) -> List[Block]:
        """Federated-aggregation record: one block per training round
        (kind="fed_round"), binding the aggregation commitment root, the
        participant set and the received/straggled/dropped split."""
        return self.find_all(kind="fed_round")

    def slashes(self) -> List[Block]:
        """Every slash-bearing block, chain order: DA slashes plus any
        rollback block that burned an executor's stake."""
        return [b for b in self.blocks
                if b.payload.get("kind") == "da_slash"
                or b.payload.get("slashed")]
