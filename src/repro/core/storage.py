"""Compatibility shim: the storage layer grew into ``repro.storage``.

The toy single-blob module that lived here became a real subsystem —
chunked content-addressed objects under Merkle chunk manifests, a
versioned ``ExpertStore`` with chunk-level dedup, an edge-side
``ExpertCache`` with gate-driven prefetch, and a replicated
``StorageNetwork`` with a deterministic transfer cost model.  Existing
imports (``repro.core.storage.StorageNetwork`` etc.) keep working.
"""
from repro.storage import (ChunkManifest, ChunkUnavailableError,  # noqa: F401
                           ExpertCache, ExpertStore, GateEMA,
                           NetworkCostModel, StorageNetwork, StorageNode,
                           deserialize_tree, serialize_tree)

__all__ = [
    "ChunkManifest", "ChunkUnavailableError", "ExpertCache", "ExpertStore",
    "GateEMA", "NetworkCostModel", "StorageNetwork", "StorageNode",
    "deserialize_tree", "serialize_tree",
]
