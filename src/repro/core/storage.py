"""Decentralized storage layer (IPFS-like, paper §IV-A(4)).

Content-addressed: the CID of an object is the SHA-256 of its serialized
bytes, so any expert downloaded by CID can be verified against the CID
recorded on-chain (tamper-evidence).  ``StorageNetwork`` replicates each
object across ``replication`` storage nodes and can survive node loss.
"""
from __future__ import annotations

import io
import random
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.core.ledger import digest_bytes


def serialize_tree(tree) -> bytes:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    buf = io.BytesIO()
    np.savez(buf, treedef=str(treedef),
             **{f"leaf{i}": np.asarray(l) for i, l in enumerate(leaves)})
    return buf.getvalue()


def deserialize_tree(data: bytes, like) -> Any:
    buf = io.BytesIO(data)
    z = np.load(buf, allow_pickle=False)
    leaves = [z[f"leaf{i}"] for i in range(len(z.files) - 1)]
    _, treedef = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class StorageNode:
    def __init__(self, node_id: int):
        self.node_id = node_id
        self.objects: Dict[str, bytes] = {}

    def put(self, cid: str, data: bytes) -> None:
        self.objects[cid] = data

    def get(self, cid: str) -> Optional[bytes]:
        return self.objects.get(cid)


class StorageNetwork:
    """A set of storage nodes with replication. ``put`` returns the CID."""

    def __init__(self, num_nodes: int = 4, replication: int = 2, seed: int = 0):
        self.nodes: List[StorageNode] = [StorageNode(i) for i in range(num_nodes)]
        self.replication = min(replication, num_nodes)
        self._rng = random.Random(seed)

    def put(self, data: bytes) -> str:
        cid = digest_bytes(data)
        for node in self._rng.sample(self.nodes, self.replication):
            node.put(cid, data)
        return cid

    def put_tree(self, tree) -> str:
        return self.put(serialize_tree(tree))

    def get(self, cid: str, verify: bool = True) -> bytes:
        for node in self.nodes:
            data = node.get(cid)
            if data is not None:
                if verify and digest_bytes(data) != cid:
                    continue  # corrupted replica; try another node
                return data
        raise KeyError(f"CID {cid[:12]}... not found on any storage node")

    def get_tree(self, cid: str, like) -> Any:
        return deserialize_tree(self.get(cid), like)

    def discard(self, cid: str) -> None:
        """Drop an object from every node — e.g. audit evidence whose
        data-availability window (the challenge window) has closed."""
        for node in self.nodes:
            node.objects.pop(cid, None)

    def drop_node(self, node_id: int) -> None:
        self.nodes = [n for n in self.nodes if n.node_id != node_id]
