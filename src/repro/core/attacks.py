"""Data-manipulation attacks (paper §III, §V-A(5)).

The paper's adversary: malicious edges "inject random Gaussian noise into
the employed experts in each round", attacking with probability 0.2 per
round; in B-MoE the malicious edges *collude* — they publish identical
manipulated results to maximize their coalition's vote weight (§V-B).

Two manipulation surfaces:
- output manipulation: corrupt the expert's computational result;
- parameter poisoning: corrupt the updated expert parameters uploaded to
  the storage layer (detected on-chain via hash vote, paper Step 5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AttackConfig:
    malicious_edges: tuple = ()       # edge indices controlled by adversary
    attack_prob: float = 0.2          # per-round attack probability (paper)
    noise_std: float = 5.0            # Gaussian manipulation magnitude
    colluding: bool = True            # identical manipulated results (paper)
    poison_params: bool = False       # also corrupt uploaded expert params

    @property
    def num_malicious(self) -> int:
        return len(self.malicious_edges)


def round_attack_mask(atk: AttackConfig, num_edges: int, round_key) -> jax.Array:
    """(num_edges,) float mask: 1.0 where the edge attacks this round."""
    mal = jnp.zeros(num_edges).at[jnp.array(atk.malicious_edges,
                                            jnp.int32)].set(1.0) \
        if atk.malicious_edges else jnp.zeros(num_edges)
    if atk.colluding:
        # coalition attacks together (one coin flip per round)
        flip = (jax.random.uniform(round_key, ()) < atk.attack_prob)
        return mal * flip.astype(jnp.float32)
    flips = (jax.random.uniform(round_key, (num_edges,)) < atk.attack_prob)
    return mal * flips.astype(jnp.float32)


def manipulate_outputs(outputs: jax.Array, mask: jax.Array,
                       noise_std: float, key, colluding: bool = True):
    """Corrupt per-edge copies of expert outputs.

    outputs: (E, M, ...) — expert e's result as published by edge m.
    mask: (M,) 1.0 for attacking edges.  Colluding attackers share one
    noise draw (identical manipulated results); independent attackers
    draw per-edge noise.
    """
    E, M = outputs.shape[:2]
    tail = outputs.shape[2:]
    if colluding:
        noise = jax.random.normal(key, (E, 1) + tail, outputs.dtype)
        noise = jnp.broadcast_to(noise, outputs.shape)
    else:
        noise = jax.random.normal(key, outputs.shape, outputs.dtype)
    mshape = (1, M) + (1,) * len(tail)
    return outputs + noise_std * noise * mask.reshape(mshape)


def manipulate_single(outputs: jax.Array, mask: jax.Array, noise_std: float,
                      key):
    """Traditional distributed MoE: expert e lives only on edge e.
    outputs: (E, ...); mask: (E,)."""
    noise = jax.random.normal(key, outputs.shape, outputs.dtype)
    mshape = (outputs.shape[0],) + (1,) * (outputs.ndim - 1)
    return outputs + noise_std * noise * mask.reshape(mshape)


def poison_tree(tree, key, noise_std: float):
    """Parameter poisoning: add Gaussian noise to every leaf."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [l + noise_std * jax.random.normal(k, jnp.shape(l), jnp.result_type(l))
           for l, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, out)
