"""Checkpointing: local npz save/restore plus content-addressed storage
through the B-MoE storage layer (CIDs recorded on a ledger when given),
mirroring the paper's Step 5 expert-storage flow for whole checkpoints.
"""
from __future__ import annotations

import os
from typing import Any, Optional

from repro.core.ledger import Ledger, digest_bytes
from repro.core.storage import StorageNetwork, deserialize_tree, serialize_tree


def save(path: str, tree: Any) -> str:
    """Save a pytree to ``path`` (npz).  Returns the content digest."""
    data = serialize_tree(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(data)
    return digest_bytes(data)


def restore(path: str, like: Any) -> Any:
    with open(path, "rb") as f:
        data = f.read()
    return deserialize_tree(data, like)


def save_to_storage(storage: StorageNetwork, tree: Any,
                    ledger: Optional[Ledger] = None,
                    meta: Optional[dict] = None) -> str:
    """Store a checkpoint in the decentralized storage layer; optionally
    record its CID on-chain."""
    cid = storage.put(serialize_tree(tree))
    if ledger is not None:
        from repro.core.ledger import Block
        payload = dict(meta or {})
        payload.update({"kind": "checkpoint", "cid": cid})
        ledger.append(Block(index=len(ledger.blocks),
                            prev_hash=ledger.head.hash, payload=payload))
    return cid


def restore_from_storage(storage: StorageNetwork, cid: str, like: Any) -> Any:
    return deserialize_tree(storage.get(cid), like)
