"""Logical-axis sharding rules.

Model code annotates params and activations with *logical* axis names
("batch", "vocab", "ff", "experts", ...).  This module maps them to mesh
axes for whatever mesh is in play:

  single-pod        (data=16, model=16)
  multi-pod         (pod=2, data=16, model=16)     # pod folds into batch
  trusted (B-MoE)   (data/r, replica=r, model)     # widths device-derived
  edge (B-MoE sys)  (data, model=edge shards)      # expert bank over model
  CPU tests         mesh=None -> every annotation is a no-op

The edge mesh (launch.mesh.make_edge_mesh) backs BMoESystem's
``mesh="on"`` rounds: ``Sharder(mesh, rules={"experts": "model"})``
places the expert bank, and the round step exchanges sparse dispatch
buckets over "model" via all_to_all (core.bmoe._mesh_sparse_forward).

The "replica" axis is *never* assigned to a logical axis: replicas hold
identical copies of the batch shard (the paper's redundancy mechanism) and
only the consensus-vote shard_map communicates across it.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def logical_rules(mesh: Optional[Mesh], cfg=None, params: bool = False) -> dict:
    """Activation rules (default) or parameter rules (``params=True``).

    Parameter rules additionally shard the ``embed`` dim over the batch
    axes — FSDP/ZeRO-3: every weight (and its AdamW state) splits over
    data x model, and XLA all-gathers shards per layer.  Without this a
    400B-param MoE cannot fit 16 GB/chip at 16-way model parallelism.
    Activations keep ``embed`` unsharded (their batch dim already carries
    the data axes)."""
    if mesh is None:
        return {}
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch = tuple(a for a in ("pod", "data") if a in axes) or None
    if cfg is not None and not getattr(cfg, "batch_shardable", True):
        batch = None
    model = "model" if "model" in axes else None
    msize = axes.get("model", 1)

    def _iff_divides(n):  # shard an axis only when it divides the mesh axis
        return model if (model and n and n % msize == 0) else None

    rules = {
        "batch": batch,
        "seq": None,
        "embed": None,
        "layers": None,
        "vocab": model,
        "q_dim": model,
        "kv_dim": model,
        "heads": _iff_divides(getattr(cfg, "num_heads", 0)),
        "kv_heads": _iff_divides(getattr(cfg, "num_kv_heads", 0)),
        "head_dim": None,
        "ff": model,
        "ssm_inner": model,
        "ssm_heads": _iff_divides(getattr(cfg, "ssm_heads", 0) if cfg else 0),
        "rglru_inner": model,
        "state": None,
        "conv": None,
        "kv_seq": _iff_divides(getattr(cfg, "sliding_window", 0)),
        "cache_seq": None,
    }
    # Expert parallelism: shard the expert axis when it divides the model
    # axis; otherwise fall back to tensor parallelism inside each expert.
    if cfg is not None and getattr(cfg, "num_experts", 0):
        n_exp = getattr(cfg, "resolved_padded_experts", cfg.num_experts)
        if n_exp % msize == 0:
            rules["experts"] = model
            rules["moe_ff"] = None
        else:
            rules["experts"] = None
            rules["moe_ff"] = model
    else:
        rules["experts"] = None
        rules["moe_ff"] = model
    # Decode caches: the sequence dim shards over the axes named by the
    # config (launch/shapes sets ("model",) for batched decode and
    # ("data", "model") for batch=1 long-context decode).
    cache_axes = tuple(a for a in getattr(cfg, "cache_seq_axes", ("model",))
                       if a in axes) if cfg is not None else ()
    rules["cache_seq"] = cache_axes or None
    if "model" in cache_axes:
        # one spec may use each mesh axis once: the cache shards its seq
        # dim over model, so its kv_heads dim must stay unsharded
        rules["kv_heads"] = None
    if params:
        fsdp = tuple(a for a in ("pod", "data") if a in axes) or None
        d_model = getattr(cfg, "d_model", 0) if cfg is not None else 0
        n_fsdp = 1
        for a in (fsdp or ()):
            n_fsdp *= axes[a]
        if fsdp and d_model and d_model % n_fsdp == 0:
            rules["embed"] = fsdp
    return rules


def use_fsdp(cfg, kind: str, model_shards: int = 16,
             hbm_budget: float = 9e9) -> bool:
    """FSDP (param embed-dim over data) policy per step kind.

    Training always FSDPs (optimizer state forces it).  Decode/prefill
    re-gather params every step, which dominated decode collectives
    (§Perf iteration 1: qwen3-32b decode_32k collective bytes dropped
    102x by replicating params over data) — so inference uses FSDP only
    when the replicated per-device params would not fit."""
    if kind == "train":
        return True
    try:
        from repro.launch.costmodel import param_counts
        per_dev = param_counts(cfg)["total"] * 2 / model_shards  # bf16
    except Exception:
        return True
    return per_dev > hbm_budget


class Sharder:
    """Applies with_sharding_constraint for logical axis names; no-op when
    mesh is None (CPU-scale tests)."""

    def __init__(self, mesh: Optional[Mesh] = None, rules: Optional[dict] = None,
                 fsdp: bool = True, attack=None):
        self.mesh = mesh
        self.rules = rules if rules is not None else logical_rules(mesh)
        self.fsdp = fsdp        # whether params carry FSDP (embed-over-data)
        self.attack = attack    # LMAttack for trusted-MoE robustness tests

    def spec(self, *axes) -> P:
        return P(*[self.rules.get(a) if a is not None else None for a in axes])

    def __call__(self, x, *axes):
        if self.mesh is None:
            return x
        if len(axes) != x.ndim:
            raise ValueError(f"{len(axes)} axes for rank-{x.ndim} value")
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*axes)))

    def named(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


NO_SHARD = Sharder(None)
