"""Analytic cost model: MODEL_FLOPS and an HBM-traffic model per
(architecture x input shape), used for the §Roofline "useful compute"
ratio and the memory term.

MODEL_FLOPS convention (documented in EXPERIMENTS.md):
- train:   6 * N_active * tokens  (+ attention term 3.5 * 4*B*S*W*q_dim
           per attention layer; W = min(window, S), /2 if causal)
- prefill: 2 * N_active * tokens  (+ attention term 1x)
- decode:  2 * N_active * batch   (+ cache-attention term)

N_active counts routed experts at k/E of their parameters (MoE).
"""
from __future__ import annotations


from repro.models.builder import count_params
from repro.models.config import ModelConfig

HW = {
    "peak_flops": 197e12,       # bf16 / chip (TPU v5e)
    "hbm_bw": 819e9,            # B/s / chip
    "ici_bw": 50e9,             # B/s / link (aggregate per chip, given)
    "hbm_per_chip": 16e9,
}


def param_counts(cfg: ModelConfig) -> dict:
    from repro.launch.shapes import param_decl
    total = count_params(param_decl(cfg))
    # routed-expert params (E experts, only k active per token)
    expert = 0
    specs = cfg.block_pattern + cfg.remainder
    n_moe = sum(1 for s in specs if s.mlp == "moe")
    if n_moe and cfg.num_experts:
        per_layer = 3 * cfg.d_model * cfg.moe_d_ff * cfg.num_experts
        n_moe_layers = (cfg.resolved_num_blocks *
                        sum(1 for s in cfg.block_pattern if s.mlp == "moe")
                        + sum(1 for s in cfg.remainder if s.mlp == "moe"))
        expert = per_layer * n_moe_layers
    active = total - expert
    if expert:
        active += expert * cfg.num_experts_per_tok / cfg.num_experts
    return {"total": total, "routed_expert": expert, "active": int(active)}


def _attn_layers(cfg: ModelConfig):
    out = []
    specs = (list(cfg.block_pattern) * cfg.resolved_num_blocks
             + list(cfg.remainder))
    for s in specs:
        if s.kind == "attn":
            out.append(0)                      # full attention
        elif s.kind == "local_attn":
            out.append(cfg.sliding_window)
    if cfg.is_encoder_decoder:
        out += [0] * cfg.num_encoder_layers    # encoder self-attn
        out += [-1] * cfg.num_layers           # cross-attn markers
    return out


def model_flops(cfg: ModelConfig, shape: dict) -> dict:
    B, S, kind = shape["batch"], shape["seq"], shape["kind"]
    pc = param_counts(cfg)
    if kind == "train":
        tokens, mult_mm, mult_attn = B * S, 6.0, 3.5
    elif kind == "prefill":
        tokens, mult_mm, mult_attn = B * S, 2.0, 1.0
    else:  # decode: one token per sequence
        tokens, mult_mm, mult_attn = B, 2.0, 1.0
    mm = mult_mm * pc["active"] * tokens
    attn = 0.0
    q_dim = cfg.q_dim
    for w in _attn_layers(cfg):
        if kind == "decode":
            span = S if w <= 0 else min(w, S)
        else:
            span = (S / 2 if w == 0 else min(w, S)) if w >= 0 else S
        attn += mult_attn * 4.0 * tokens * span * q_dim
    return {"matmul": mm, "attention": attn, "total": mm + attn,
            "params": pc}


def hbm_bytes(cfg: ModelConfig, shape: dict, num_devices: int,
              model_shards: int = 16) -> dict:
    """Per-device HBM traffic model (bytes / step).  bf16 params/acts,
    f32 optimizer state."""
    B, S, kind = shape["batch"], shape["seq"], shape["kind"]
    pc = param_counts(cfg)
    p_local = pc["total"] / model_shards * 2          # bf16 param bytes
    data_shards = max(num_devices // model_shards, 1)
    b_local = max(B // data_shards, 1)
    d = cfg.d_model
    L = cfg.num_layers + cfg.num_encoder_layers

    if kind == "train":
        # weights fwd+bwd reads, grad write, AdamW m/v read+write (f32),
        # param read+write
        wbytes = p_local * (2 + 1) + (pc["total"] / model_shards) * (
            4 * 4 + 2 * 2)
        # remat: store+reload one residual per layer, recompute acts
        abytes = L * b_local * S * d * 2 * 3
        return {"total": wbytes + abytes, "weights": wbytes,
                "activations": abytes}
    if kind == "prefill":
        abytes = L * b_local * S * d * 2 * 2
        return {"total": p_local + abytes, "weights": p_local,
                "activations": abytes}
    # decode: weights + full KV-cache (or state) read per token
    cache = 0.0
    kv_bytes = 1 if getattr(cfg, "kv_cache_dtype", "") == "int8" else 2
    hd = cfg.resolved_head_dim
    specs = (list(cfg.block_pattern) * cfg.resolved_num_blocks
             + list(cfg.remainder))
    for s in specs:
        if s.kind == "attn":
            cache += kv_bytes * S * cfg.num_kv_heads * hd
        elif s.kind == "local_attn":
            cache += kv_bytes * min(cfg.sliding_window, S) * cfg.num_kv_heads * hd
        elif s.kind == "ssm":
            cache += cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 2 * 2
        elif s.kind == "rglru":
            cache += cfg.rglru_expand * d * 2 * 2
    if cfg.is_encoder_decoder:
        cache += cfg.num_layers * kv_bytes * (S + 4096) * cfg.num_kv_heads * hd
    # k+v pair; caches shard over model (and data when B==1)
    cache_local = cache * b_local * 2 / model_shards
    return {"total": p_local + cache_local, "weights": p_local,
            "cache": cache_local}
