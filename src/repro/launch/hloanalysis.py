"""Loop-aware accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so with
scan-over-layers every per-layer cost is understated by the trip count.
This module parses the HLO module text into its computations, extracts
while-loop trip counts from the loop conditions (scan lowers to a
``compare(iter, constant)`` condition), and accumulates per-computation:

- collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all
  / collective-permute, output-shape bytes), and
- dot FLOPs (2 * prod(output shape) * prod(contracted dims)),

multiplying costs inside while bodies by their trip counts, recursively
(nested scans multiply up).  Validated against fully-unrolled compiles in
tests/test_hloanalysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALL_ATTR = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _replica_group_size(rhs: str) -> Optional[int]:
    """Largest replica group of a collective, or None if unspecified.

    A collective whose groups are all singletons (``{{0},{1},...}`` or
    iota ``[N,1]<=[N]``) exchanges nothing — XLA leaves it in place when
    every partition reduces only with itself (e.g. an explicitly
    shard-constrained gradient), and it must not count as wire bytes.
    """
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:  # [n_groups, group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(rhs)
    if m:
        return max(len([d for d in g.split(",") if d.strip()])
                   for g in m.group(1)[1:-1].split("},{"))
    return None


def _dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",") if d]


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class Instr:
    op: str
    out_bytes: int
    flops: float
    calls: List[str]
    is_while: bool
    cond: Optional[str]
    trip: Optional[int] = None  # from backend_config known_trip_count


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    max_const: int = 1  # largest integer constant (trip-count heuristic)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    shapes_by_name: Dict[str, List[int]] = {}
    pending_dots: List[Tuple[Instr, str, List[int], List[int]]] = []

    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_HEADER.match(line)
                if m:
                    cur = Computation(m.group(1), [])
            continue
        if line == "}" or line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = re.match(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$", line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # record this instruction's (first) output shape for operand lookup
        sm = _SHAPE_RE.search(rhs)
        if sm:
            shapes_by_name[name] = _dims(sm.group(2))
        opm = re.search(r"\]\S*\s+([a-z][\w\-]*)\(", rhs)
        op = opm.group(1) if opm else ""
        base = op[:-6] if op.endswith("-start") else op
        calls = _CALL_ATTR.findall(rhs)
        is_while = base == "while"
        cond = None
        trip = None
        if is_while:
            cm = re.search(r"condition=%?([\w.\-]+)", rhs)
            cond = cm.group(1) if cm else None
            bm = re.search(r"body=%?([\w.\-]+)", rhs)
            calls = [bm.group(1)] if bm else []
            tm = _TRIP_RE.search(rhs)
            trip = int(tm.group(1)) if tm else None
        out_bytes = 0
        flops = 0.0
        instr = Instr(base, out_bytes, flops, calls, is_while, cond, trip)
        if base in COLLECTIVE_OPS and opm:
            gsize = _replica_group_size(rhs)
            if gsize is not None and gsize <= 1:
                instr.op = ""          # singleton groups: no wire traffic
            else:
                # shapes between '=' and the op name (opm.start(1)) = outputs
                shapes = _SHAPE_RE.findall(rhs[:opm.start(1)])
                instr.out_bytes = sum(_shape_bytes(d, s) for d, s in shapes)
        elif base == "dot":
            out_dims = _dims(sm.group(2)) if sm else []
            am = re.search(r"dot\(\s*%?([\w.\-]+)", rhs)
            km = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
            cdims = _dims(km.group(1)) if km else []
            pending_dots.append((instr, am.group(1) if am else "",
                                 out_dims, cdims))
        elif base == "convolution" and sm:
            out = 1
            for d in _dims(sm.group(2)):
                out *= d
            all_shapes = _SHAPE_RE.findall(rhs)
            ker = _dims(all_shapes[-1][1]) if len(all_shapes) >= 2 else []
            k = 1
            for d in ker[:-1]:
                k *= d
            instr.flops = 2.0 * out * k
        for c in _CONST_RE.finditer(rhs):
            cur.max_const = max(cur.max_const, int(c.group(1)))
        cur.instrs.append(instr)

    # second pass: dot flops = 2 * prod(out) * prod(lhs contracting dims)
    for instr, lhs_name, out_dims, cdims in pending_dots:
        lhs = shapes_by_name.get(lhs_name, [])
        contract = 1
        for i in cdims:
            if i < len(lhs):
                contract *= lhs[i]
        n = 1
        for d in out_dims:
            n *= d
        instr.flops = 2.0 * n * contract
    return comps


def _trip_count(comps: Dict[str, Computation], cond: Optional[str]) -> int:
    if cond and cond in comps:
        return max(comps[cond].max_const, 1)
    return 1


def analyze(text: str, entry: Optional[str] = None) -> dict:
    """Loop-corrected totals: {'collective_bytes': {op: bytes},
    'collective_counts': {op: n}, 'dot_flops': float}."""
    comps = parse_hlo(text)
    memo: Dict[str, Tuple[Dict[str, float], Dict[str, float], float]] = {}

    def visit(name: str, stack=()) -> Tuple[Dict[str, float],
                                            Dict[str, float], float]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {}, {}, 0.0
        cb: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
        cc: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
        fl = 0.0
        for ins in comps[name].instrs:
            if ins.op in COLLECTIVE_OPS:
                cb[ins.op] += ins.out_bytes
                cc[ins.op] += 1
            fl += ins.flops
            mult = 1
            if ins.is_while:
                mult = ins.trip if ins.trip else _trip_count(comps, ins.cond)
            for callee in ins.calls:
                scb, scc, sfl = visit(callee, stack + (name,))
                for op in COLLECTIVE_OPS:
                    cb[op] += mult * scb.get(op, 0.0)
                    cc[op] += mult * scc.get(op, 0.0)
                fl += mult * sfl
        memo[name] = (cb, cc, fl)
        return memo[name]

    # entry computation: the one named like ENTRY (first parsed with
    # 'main' in it) or the explicitly requested one
    entry_name = entry
    if entry_name is None:
        for n in comps:
            if "main" in n:
                entry_name = n
                break
        else:
            entry_name = next(iter(comps))
    cb, cc, fl = visit(entry_name)
    return {"collective_bytes": cb, "collective_counts": cc,
            "dot_flops": fl,
            "total_collective_bytes": sum(cb.values()),
            "entry": entry_name}
