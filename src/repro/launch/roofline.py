"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artifacts.

  compute term    = HLO dot FLOPs (loop-corrected, per device) / 197 TF/s
  memory term     = HBM traffic model bytes (per device)       / 819 GB/s
  collective term = wire bytes (loop-corrected; AG + 2*AR + RS + A2A + CP,
                    output-shape sizes) / 50 GB/s

Also reports MODEL_FLOPS (analytic useful compute) and the ratio
MODEL_FLOPS / HLO_FLOPs, which catches remat/redundancy waste, plus the
dominant term and a one-line lever suggestion.

Usage:
  python -m repro.launch.roofline artifacts/dryrun_single.json [-o out.md]
"""
from __future__ import annotations

import argparse
import json
from typing import Optional

from repro.launch.costmodel import HW, hbm_bytes, model_flops

LEVERS = {
    "compute": ("shrink redundant compute: lower remat recompute, skip "
                "fully-masked attention chunks, larger MoE capacity tiles"),
    "memory": ("cut HBM traffic: shard/quantize the KV cache, fuse "
               "elementwise chains, avoid f32 staging of bf16 tensors"),
    "collective": ("cut wire bytes: reduce-scatter instead of all-reduce "
                   "+ all-gather, overlap collectives with compute, "
                   "digest-vote instead of full-tensor redundancy gather"),
}


def roofline_row(rec: dict) -> Optional[dict]:
    """rec: one dryrun JSON record -> roofline terms."""
    if "error" in rec or "skipped" in rec:
        return None
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, shape_config
    cfg = shape_config(get_config(rec["arch"]), rec["shape"])
    shape = SHAPES[rec["shape"]]
    n_dev = rec["num_devices"]

    flops_dev = rec["dot_flops"]                      # per device (SPMD)
    cb = rec["collective_bytes"]
    wire = (cb.get("all-gather", 0) + 2 * cb.get("all-reduce", 0)
            + cb.get("reduce-scatter", 0) + cb.get("all-to-all", 0)
            + cb.get("collective-permute", 0))
    mem = hbm_bytes(cfg, shape, n_dev)

    t_compute = flops_dev / HW["peak_flops"]
    t_memory = mem["total"] / HW["hbm_bw"]
    t_coll = wire / HW["ici_bw"]
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful_ratio = (mf["total"] / n_dev) / max(flops_dev, 1.0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "trusted": rec.get("trusted", "off"),
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops_total": mf["total"],
        "hlo_flops_per_dev": flops_dev,
        "useful_ratio": useful_ratio,
        "wire_bytes_per_dev": wire,
        "hbm_bytes_per_dev": mem["total"],
        "lever": LEVERS[dominant],
        "compile_s": rec.get("compile_s"),
    }


def to_markdown(rows, title="Roofline") -> str:
    out = [f"### {title}", "",
           "| arch | shape | trusted | compute (s) | memory (s) | "
           "collective (s) | dominant | MODEL_FLOPS | useful ratio |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r is None:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['trusted']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['model_flops_total']:.2e} | {r['useful_ratio']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("records", nargs="+")
    ap.add_argument("-o", "--out", default=None)
    args = ap.parse_args()
    rows = []
    for path in args.records:
        with open(path) as f:
            for rec in json.load(f):
                row = roofline_row(rec)
                if row:
                    rows.append(row)
                elif "skipped" in rec:
                    rows.append(None)
    md = to_markdown([r for r in rows if r])
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
