"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

CPU-scale driver over the production step functions: smoke-sized variants
train locally; full configs are for the dry-run (this driver will also
run them under a mesh if you have the hardware).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import lm_batches
from repro.optim.adamw import AdamWConfig
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bmoe-paper", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not smoke) config — needs a mesh")
    ap.add_argument("--mesh", default=None,
                    help="'data,model' sizes, e.g. '2,4' (needs devices)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    print(f"[train] arch={cfg.name} smoke={not args.full} "
          f"steps={args.steps} devices={len(jax.devices())}")
    batches = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    _, history = train(
        cfg, batches, steps=args.steps, mesh=mesh,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=10,
                            total_steps=args.steps),
        log_every=max(args.steps // 10, 1),
        callback=lambda m: print(
            f"  step {m['step']:5d} loss={m['loss']:.4f} "
            f"grad_norm={m['grad_norm']:.3f} ({m['wall_s']:.0f}s)"))
    print(f"[train] done: loss {history[0]['loss']:.3f} -> "
          f"{history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
