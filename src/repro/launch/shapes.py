"""Assigned input shapes + ShapeDtypeStruct input_specs per architecture.

INPUT SHAPES (assigned):
  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)

``input_specs`` returns weak-type-correct, shardable stand-ins (no device
allocation) for every model input: tokens/labels for training; frame or
patch embeddings for the stubbed audio/vision frontends; KV caches +
single token for decode.  ``applicable`` encodes the documented skips
(DESIGN.md §6): long_500k only for sub-quadratic archs.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import encdec as encdec_lib
from repro.models import transformer as tfm
from repro.models.builder import abstract, partition_specs
from repro.models.config import ModelConfig
from repro.sharding import logical_rules

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# encoder memory length used for enc-dec decode shapes (frames already
# encoded at prefill time; cross-KV precomputed in the cache)
ENCDEC_DECODE_MEMORY = 4096


def applicable(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention KV cache at 500k has no native "
                       "sub-quadratic variant (DESIGN.md §6 skip)")
    return True, ""


def shape_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Per-shape config adjustments (decode-cache sharding axes)."""
    info = SHAPES[shape_name]
    if info["kind"] == "decode":
        axes = ("data", "model") if info["batch"] == 1 else ("model",)
        return dataclasses.replace(cfg, cache_seq_axes=axes,
                                   batch_shardable=info["batch"] > 1)
    return cfg


def _tok(batch, seq):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str, *, mesh=None,
                dtype=jnp.bfloat16):
    """Returns (args: tuple of abstract step inputs (after params),
    shardings: matching tree of NamedShardings or None)."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    rules = logical_rules(mesh, cfg)
    bspec = rules.get("batch")

    def ns(spec):
        return NamedSharding(mesh, spec) if mesh is not None else None

    if info["kind"] in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            batch = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype),
                     "tokens": _tok(B, S)}
            shard = {"frames": ns(P(bspec, None, None)),
                     "tokens": ns(P(bspec, None))}
        elif cfg.frontend == "vision":
            ptoks = min(cfg.frontend_tokens, S // 2)
            batch = {"patches": jax.ShapeDtypeStruct((B, ptoks, cfg.d_model),
                                                     dtype),
                     "tokens": _tok(B, S - ptoks)}
            shard = {"patches": ns(P(bspec, None, None)),
                     "tokens": ns(P(bspec, None))}
        else:
            batch = {"tokens": _tok(B, S)}
            shard = {"tokens": ns(P(bspec, None))}
        if info["kind"] == "train":
            batch["labels"] = _tok(B, batch["tokens"].shape[1])
            shard["labels"] = ns(P(bspec, None))
        return (batch,), (shard,)

    # ---- decode: caches + one token
    if cfg.is_encoder_decoder:
        cdecl = encdec_lib.encdec_cache_decl(cfg, B, S, ENCDEC_DECODE_MEMORY)
    else:
        cdecl = tfm.cache_decl(cfg, B, S)
    caches = abstract(cdecl, dtype)
    cache_specs = partition_specs(cdecl, rules)
    cache_shard = jax.tree_util.tree_map(
        ns, cache_specs, is_leaf=lambda x: isinstance(x, P)) \
        if mesh is not None else None
    batch = {"tokens": _tok(B, 1), "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    bshard = {"tokens": ns(P(bspec, None)), "pos": ns(P())}
    return (caches, batch), (cache_shard, bshard)


def param_decl(cfg: ModelConfig):
    return (encdec_lib.encdec_decl(cfg) if cfg.is_encoder_decoder
            else tfm.model_decl(cfg))


def abstract_params(cfg: ModelConfig, *, mesh=None, dtype=jnp.bfloat16,
                    kind: str = "train"):
    """(abstract params, NamedSharding tree or None)."""
    from repro.sharding import use_fsdp
    decl = param_decl(cfg)
    params = abstract(decl, dtype)
    if mesh is None:
        return params, None
    rules = logical_rules(mesh, cfg, params=True)   # FSDP param rules
    if not use_fsdp(cfg, kind, mesh.devices.shape[-1]):
        rules["embed"] = None                       # replicate over data
    specs = partition_specs(decl, rules)
    shard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
    return params, shard


def abstract_opt_state(params_abs, params_shard, mesh=None):
    """AdamW state stand-ins: m, v shaped/sharded like params (f32)."""
    from repro.optim.adamw import AdamWState
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    state = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       m=f32(params_abs), v=f32(params_abs))
    if mesh is None:
        return state, None
    shard = AdamWState(step=NamedSharding(mesh, P()),
                       m=params_shard, v=params_shard)
    return state, shard
