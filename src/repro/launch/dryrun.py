import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, and fits — without real hardware.

For each combination:
  with mesh:
      lowered  = jax.jit(step, in_shardings=..., out_shardings=None).lower(*abstract_inputs)
      compiled = lowered.compile()
      memory_analysis / cost_analysis / collective-bytes extraction

Results (memory, FLOPs, bytes, per-collective byte counts) are written to
JSON artifacts consumed by launch/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import re
import time

import jax

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8}
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-operand bytes of every collective op in optimized HLO.

    Uses the op's *output* shape (for all-gather this is the gathered
    size = bytes received per device; for all-reduce the full operand —
    a ring all-reduce moves ~2x this, accounted in roofline.py)."""
    per_op = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # def lines look like: %name = TYPE[dims]{...} op-name(...)
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for op in COLLECTIVE_OPS:
            # match 'all-gather(' or 'all-gather-start(' etc.
            opm = re.search(rf"\b{op}(?:-start)?\(", rhs)
            if opm:
                shapes = _SHAPE_RE.findall(rhs[:opm.start()])
                per_op[op] += sum(_shape_bytes(d, s) for d, s in shapes)
                counts[op] += 1
                break
    total = sum(per_op.values())
    return {"total_bytes": total, "per_op_bytes": per_op, "counts": counts}


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            trusted: str = "off", redundancy_r: int = 4,
            unroll: bool = True, kv_int8: bool = False,
            verbose: bool = True) -> dict:
    """Lower + compile one (arch, shape, mesh) combination; returns the
    roofline-input record."""
    from repro.configs import get_config
    from repro.launch import shapes as shp
    from repro.launch.mesh import make_production_mesh, make_trusted_mesh
    from repro.models.config import RedundancyConfig
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import make_step

    cfg = get_config(arch)
    ok, reason = shp.applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": reason}
    if trusted != "off":
        cfg = dataclasses.replace(
            cfg, redundancy=RedundancyConfig(r=redundancy_r, mode=trusted))
        mesh = make_trusted_mesh(redundancy_r, multi_pod=multi_pod)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = shp.shape_config(cfg, shape_name)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    kind = shp.SHAPES[shape_name]["kind"]

    params, pshard = shp.abstract_params(cfg, mesh=mesh, kind=kind)
    args, shards = shp.input_specs(cfg, shape_name, mesh=mesh)
    if kind == "train":
        opt, oshard = shp.abstract_opt_state(params, pshard, mesh)
        step_args = (params, opt) + args
        in_shardings = (pshard, oshard) + shards
        step = make_step(cfg, "train", mesh,
                         opt_cfg=AdamWConfig(total_steps=1000),
                         unroll=unroll)
    else:
        step_args = (params,) + args
        in_shardings = (pshard,) + shards
        step = make_step(cfg, kind, mesh, unroll=unroll)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_shardings).lower(*step_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)          # raw (loop bodies counted once)
    from repro.launch import hloanalysis
    loop_aware = hloanalysis.analyze(hlo)  # trip-count corrected

    record = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "trusted": trusted, "unroll": unroll,
        "kv_int8": kv_int8,
        "num_devices": mesh.devices.size,
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collectives_raw": coll,
        "collective_bytes": loop_aware["collective_bytes"],
        "collective_counts": loop_aware["collective_counts"],
        "total_collective_bytes": loop_aware["total_collective_bytes"],
        "dot_flops": loop_aware["dot_flops"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        record[attr] = getattr(mem, attr, None)
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {record['mesh']} "
              f"(trusted={trusted}): COMPILED OK in {t_compile:.0f}s")
        print(f"  memory_analysis: args={record['argument_size_in_bytes']}"
              f" temp={record['temp_size_in_bytes']}"
              f" out={record['output_size_in_bytes']}")
        print(f"  cost_analysis: flops={record['flops']:.3e}"
              f" bytes={record['bytes_accessed']:.3e}")
        print(f"  collectives (loop-corrected): "
              f"{loop_aware['total_collective_bytes']:.3e} B "
              f"{ {k: int(v) for k, v in loop_aware['collective_counts'].items() if v} }")
        print(f"  dot_flops (loop-corrected): {loop_aware['dot_flops']:.3e}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(__import__("repro.launch.shapes",
                                            fromlist=["SHAPES"]).SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--trusted", default="off",
                    choices=["off", "faithful", "digest"])
    ap.add_argument("--redundancy-r", type=int, default=4)
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized decode KV cache (Perf iter 4)")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep scan-over-layers (faster compile, "
                         "loop-body-once cost accounting)")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES

    if args.all:
        combos = [(a, s) for a in ARCH_IDS if a != "bmoe-paper"
                  for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    records = []
    for arch, shape_name in combos:
        try:
            rec = run_one(arch, shape_name, multi_pod=args.multi_pod,
                          trusted=args.trusted,
                          redundancy_r=args.redundancy_r,
                          unroll=not args.no_unroll,
                          kv_int8=args.kv_int8)
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape_name, "error": repr(e)[:500]}
            print(f"[dryrun] {arch} x {shape_name}: FAILED {e!r}")
        records.append(rec)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
        print(f"[dryrun] wrote {len(records)} records to {args.out}")
    failed = [r for r in records if "error" in r]
    if failed:
        raise SystemExit(f"{len(failed)} combinations FAILED")


if __name__ == "__main__":
    main()
