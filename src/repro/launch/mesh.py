"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state.  The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis folds
    into the batch sharding (dp = pod x data)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_trusted_mesh(r: int, *, multi_pod: bool = False):
    """B-MoE redundancy mesh: the data axis splits into (data/r groups,
    r replicas); same chip count as the production mesh."""
    if 16 % r:
        raise ValueError(f"redundancy r={r} must divide 16")
    if multi_pod:
        return jax.make_mesh((2, 16 // r, r, 16),
                             ("pod", "data", "replica", "model"))
    return jax.make_mesh((16 // r, r, 16), ("data", "replica", "model"))


def make_host_mesh():
    """Whatever fits the current host (CPU tests): 1 device -> (1, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
