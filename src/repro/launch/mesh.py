"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state.  The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; everything else sees the real device count.

Every factory except ``make_production_mesh`` (a fixed physical pod
geometry) derives its axis widths from the *actual* device count:
excess devices fold into the data axis, and impossible splits raise
with the arithmetic spelled out instead of handing GSPMD a mesh the
model cannot shard over.
"""
from __future__ import annotations

from typing import Optional

import jax


def _model_width(n: int, divides: Optional[int] = None,
                 cap: Optional[int] = None) -> int:
    """Largest divisor of ``n`` that also divides ``divides`` (when
    given) and is <= ``cap`` (when given).  Always >= 1 — leftover
    devices fold into the data axis instead of failing."""
    for m in range(min(n, cap or n), 0, -1):
        if n % m == 0 and (divides is None or divides % m == 0):
            return m
    return 1


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis folds
    into the batch sharding (dp = pod x data)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_trusted_mesh(r: int, *, multi_pod: bool = False):
    """B-MoE redundancy mesh: the data axis splits into (data/r groups,
    r replicas).  Axis widths derive from the actual device count —
    the replica axis is reserved first, the model axis takes the widest
    power up to 16 that fits, and every leftover device folds into the
    data axis (a 512-chip single-pod run uses all 512 chips as
    (16, r, 16)-ish instead of silently assuming a 16-wide data axis)."""
    n = len(jax.devices())
    pods = 2 if multi_pod else 1
    if n % pods:
        raise ValueError(f"multi_pod needs an even device count, got {n}")
    per_pod = n // pods
    if r < 1 or per_pod % r:
        raise ValueError(
            f"redundancy r={r} must divide the per-pod device count "
            f"{per_pod} ({n} devices / {pods} pod(s))")
    rest = per_pod // r
    model = _model_width(rest, cap=16)
    data = rest // model
    if multi_pod:
        return jax.make_mesh((2, data, r, model),
                             ("pod", "data", "replica", "model"))
    return jax.make_mesh((data, r, model), ("data", "replica", "model"))


def make_host_mesh(num_experts: Optional[int] = None):
    """Whatever fits the current host (CPU tests): 1 device -> (1, 1).

    With ``num_experts`` the model axis is the largest device-count
    divisor that also divides the expert count — what ``moe_mlp_ep``
    needs (``E % msize == 0``) — and excess devices fold into the data
    axis, instead of the old unconditional ``(1, n)`` that made expert
    parallelism raise whenever ``num_experts % n != 0``."""
    n = len(jax.devices())
    model = _model_width(n, divides=num_experts)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_edge_mesh(num_experts: int, *, shards: Optional[int] = None):
    """B-MoE edge mesh: ``model`` is the edge-shard axis — each
    simulated edge owns a contiguous ``num_experts/shards`` expert
    slice, dispatch crosses shards via all_to_all, and commitments/
    audits are shard-local (see ``repro.core.bmoe``).  Leftover devices
    fold into a replicated ``data`` axis.  ``shards=None`` picks the
    widest edge axis the device and expert counts allow."""
    n = len(jax.devices())
    if shards is None:
        shards = _model_width(n, divides=num_experts)
    if shards < 1 or n % shards:
        raise ValueError(
            f"mesh_shards={shards} must divide the device count ({n})")
    if num_experts % shards:
        raise ValueError(
            f"num_experts ({num_experts}) % mesh_shards ({shards}) != 0 — "
            f"each edge shard must own a whole expert slice; pick shards "
            f"from the divisors of {num_experts}")
    return jax.make_mesh((n // shards, shards), ("data", "model"))
