"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the continuous-batching serving engine (per-tick admit/evict,
fused chunked prefill, greedy decode; ``--scheduling fixed`` for the
legacy batch-synchronous baseline) over synthetic requests and reports
throughput.
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import serving_requests
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import POLICIES
from repro.train.loop import init_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--scheduling", choices=list(POLICIES),
                    default="continuous")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="max prompt tokens fused per compiled step")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.is_encoder_decoder:
        raise SystemExit("serve driver targets decoder-only archs")
    params = init_model(cfg, seed=0)
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           cache_len=args.cache_len,
                           scheduling=args.scheduling,
                           prefill_chunk=args.prefill_chunk)
    reqs = list(serving_requests(cfg.vocab_size, args.requests,
                                 max_prompt=args.max_prompt,
                                 max_new=args.max_new, seed=0))
    engine.submit(reqs)
    done = engine.run()
    dt = engine.report()["tick_s"]     # wall seconds from the registry
    total_tokens = sum(len(v) for v in done.values())
    print(f"[serve] arch={cfg.name} completed {len(done)}/{len(reqs)} "
          f"requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / max(dt, 1e-9):.1f} tok/s)")
    for rid in sorted(done)[:5]:
        print(f"  req {rid}: {done[rid]}")


if __name__ == "__main__":
    main()
