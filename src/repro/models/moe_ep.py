"""Expert-parallel MoE via shard_map + all_to_all (§Perf iteration 2).

GSPMD cannot turn the scatter-based dispatch of repro.models.moe into an
all-to-all — it all-gathers the (B, E, C, d) capacity buffer over the
model axis (measured 7.6e12 B/device on qwen2-moe train_4k) or, under
expert-TP, all-reduces the full buffer per layer (1.06e12 B).  This path
expresses the exchange explicitly:

  per model-shard: route locally -> pack per-expert send buffer
  (E, C_send, d) -> all_to_all over "model" -> run the E/msize local
  experts' SwiGLU (full moe_ff, no psum) -> all_to_all back -> combine.

Wire bytes/device/layer ~ 2 * B_l*S*k*d (send + return), independent of
E and C — ~5x less than expert-TP on qwen2-moe.

Grouping note: the dispatch group is the model-shard (GShard's "group =
device"), vs per-batch-row groups in the GSPMD path; capacity semantics
are per-shard.  The B-MoE consensus vote composes here too: replicas
all-gather the local expert outputs over the "replica" mesh axis and
majority-vote before the return all_to_all.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.trusted_moe import LMAttack, _inject
from repro.kernels import ref as kref
from repro.models.moe import route_masked


def _shard_map(body, mesh, in_specs, out_specs):
    """jax.shard_map across the pinned-jax spelling divide (see ROADMAP
    'jax pinning'): new-style ``jax.shard_map(check_vma=)`` when the
    installed jax has it, else the experimental ``check_rep=`` spelling."""
    try:
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (TypeError, AttributeError):
        from jax.experimental.shard_map import shard_map
        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)


def _ep_body(x, router, wg, wu, wd, *, cfg, msize, batch_axes, fsdp_axes,
             trust_mode, attack):
    """Per-device block. x: (B_l, S, d) local batch shard (replicated over
    model + replica). Expert weights: local (E_l, d or d/fsdp, f) shards."""
    B_l, S, d = x.shape
    E = cfg.resolved_padded_experts
    E_l = E // msize
    k = cfg.num_experts_per_tok

    if fsdp_axes:  # restore the embed dim of the local expert shard
        wg = jax.lax.all_gather(wg, fsdp_axes, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, fsdp_axes, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, fsdp_axes, axis=2, tiled=True)
        router = jax.lax.all_gather(router, fsdp_axes, axis=0, tiled=True)
    router = jax.lax.all_gather(router, "model", axis=1, tiled=True)

    # ---- token-split over the model axis: x arrives replicated across
    # model shards; each shard routes/dispatches its own T_l slice
    # (without this every expert would receive msize duplicate copies).
    # A ragged token count (T_full % msize != 0 — ANY odd batch shape,
    # not just tiny decode steps) pads the token axis up to a multiple
    # of msize; pad rows route to the out-of-range sentinel expert (no
    # capacity slot, no wire bytes, zero combine weight), so wire bytes
    # stay ~T_full*k*d instead of the old fallback's msize-duplicate
    # dispatch that multiplied wire bytes and expert FLOPs by msize.
    T_full = B_l * S
    T_l = -(-T_full // msize)
    T_pad = T_l * msize
    mid = jax.lax.axis_index("model")
    xt_full = x.reshape(T_full, d)
    if T_pad != T_full:
        xt_full = jnp.concatenate(
            [xt_full, jnp.zeros((T_pad - T_full, d), x.dtype)], axis=0)
    xt = jax.lax.dynamic_slice_in_dim(xt_full, mid * T_l, T_l)
    valid = (jnp.arange(T_l) + mid * T_l < T_full) if T_pad != T_full \
        else None

    # ---- local routing (group = this shard's token slice)
    logits = (xt @ router)[None]                         # (1, T_l, E)
    cap = max(int(cfg.capacity_factor * T_l * k / E), 1)
    cap = -(-cap // 8) * 8
    weights, expert_id, position, keep, stats = route_masked(
        logits, k, cap, cfg.num_experts,
        valid=None if valid is None else valid[None])
    weights = weights.reshape(T_l, k)
    eid = expert_id.reshape(T_l * k)
    pos = jnp.where(keep, position, cap - 1).reshape(T_l * k)
    keep = keep.reshape(T_l * k)

    # ---- pack send buffer (E, cap, d); pad rows carry the sentinel
    # expert id E — out of bounds for the scatter, hence dropped
    tok = jnp.repeat(jnp.arange(T_l), k)
    gath = xt[tok] * keep[:, None].astype(x.dtype)
    send = jnp.zeros((E, cap, d), x.dtype).at[eid, pos].add(gath,
                                                            mode="drop")

    # ---- all_to_all: experts to their owners
    send = send.reshape(msize, E_l, cap, d)
    recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=False)               # (msize, E_l, cap, d)
    buf = jnp.moveaxis(recv, 0, 1).reshape(E_l, msize * cap, d)

    # ---- local expert FFN (full moe_ff: no tensor-parallel psum)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd)              # (E_l, msize*cap, d)

    if trust_mode != "off":
        out = _ep_vote(out, trust_mode, attack)

    # ---- return all_to_all and combine
    back = jnp.moveaxis(out.reshape(E_l, msize, cap, d), 1, 0)
    ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                             tiled=False)
    ret = ret.reshape(E, cap, d)                         # home-shard layout
    yk = ret.at[eid, pos].get(mode="fill", fill_value=0) * \
        (weights.reshape(T_l * k) * keep).astype(x.dtype)[:, None]
    y_loc = jnp.zeros((T_l, d), x.dtype).at[tok].add(yk)
    # restore the full token axis (residual stream is model-replicated)
    y = jax.lax.all_gather(y_loc, "model", axis=0, tiled=True)
    if T_pad != T_full:
        y = y[:T_full]
    # ---- aux loss over the EXACT global batch from psum'd routing
    # statistics — identical whether or not the token axis is ragged
    # (the old msplit==1 / msplit>1 branches averaged per-shard aux,
    # which disagreed between the two regimes)
    axes = batch_axes + ("model",)
    cnt = jax.lax.psum(stats[0], axes)
    psum_p = jax.lax.psum(stats[1], axes)
    T = jnp.maximum(jax.lax.psum(stats[2], axes), 1.0)
    aux = E * jnp.sum((cnt / (T * k)) * (psum_p / T))
    return y.reshape(B_l, S, d), aux


def _ep_vote(out, mode, attack: Optional[LMAttack]):
    """B-MoE consensus over the 'replica' axis on the local expert
    outputs (E_l, C, d)."""
    E_l, C, d = out.shape
    out = _inject(out, attack)
    if mode == "faithful":
        ys = jax.lax.all_gather(out, "replica")          # (r, E_l, C, d)
        pub = jnp.moveaxis(ys, 0, 1)
        trusted, _ = kref.redundancy_vote_ref(pub)
        return trusted
    # digest mode
    v = jax.random.normal(jax.random.PRNGKey(0xB30E), (C, d), jnp.float32)
    dig = jnp.tensordot(out.astype(jnp.float32), v, axes=2)  # (E_l,)
    digs = jax.lax.all_gather(dig, "replica")
    agree = (jnp.abs(digs[:, None, :] - digs[None, :, :]) <= 0.0)
    support = agree.sum(axis=1)
    rid = jax.lax.axis_index("replica")
    majority = support.max(axis=0)
    winner = jnp.argmax(support == majority[None, :], axis=0)
    ok = (jnp.abs(digs[rid] -
                  jnp.take_along_axis(digs, winner[None, :], axis=0)[0])
          <= 0.0).astype(out.dtype)
    n_ok = jax.lax.psum(ok, "replica")
    total = jax.lax.psum(out * ok[:, None, None], "replica")
    return (total / jnp.maximum(n_ok, 1.0)[:, None, None]).astype(out.dtype)


def moe_mlp_ep(params, x, cfg, mesh: Mesh, act_rules: dict, *,
               fsdp: bool = True, attack: Optional[LMAttack] = None):
    """Drop-in for moe_mlp under a mesh: (B, S, d) -> ((B, S, d), aux)."""
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    E = cfg.resolved_padded_experts
    if E % msize:
        raise ValueError(f"EP needs experts ({E}) % model axis ({msize}) == 0")
    batch_axes = act_rules.get("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    fsdp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names) \
        if fsdp else ()
    bspec = batch_axes or None

    in_specs = (
        P(bspec, None, None),                              # x
        P(fsdp_axes or None, "model"),                     # router
        P("model", fsdp_axes or None, None),               # w_gate
        P("model", fsdp_axes or None, None),               # w_up
        P("model", None, fsdp_axes or None),               # w_down
    )
    out_specs = (P(bspec, None, None), P())
    body = functools.partial(
        _ep_body, cfg=cfg, msize=msize, batch_axes=batch_axes,
        fsdp_axes=fsdp_axes, trust_mode=cfg.redundancy.mode, attack=attack)
    mapped = _shard_map(body, mesh, in_specs, out_specs)
    y, aux = mapped(x, params["router"], params["w_gate"], params["w_up"],
                    params["w_down"])
    if cfg.num_shared_experts:
        sp = params["shared"]
        y_sh = (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
        if cfg.redundancy.mode != "off" and "replica" in mesh.axis_names:
            # shared experts used to run outside the shard_map and skip
            # _ep_vote entirely — a tampered shared expert was invisible
            # to redundancy voting.  Vote their dense rows over the same
            # replica axis as the routed buckets (one pseudo-expert row
            # per shard).
            def shared_body(yl):
                bl, s, dd = yl.shape
                out = _ep_vote(yl.reshape(1, bl * s, dd),
                               cfg.redundancy.mode, attack)
                return out.reshape(bl, s, dd)
            y_sh = _shard_map(shared_body, mesh,
                              (P(bspec, None, None),),
                              P(bspec, None, None))(y_sh)
        y = y + y_sh
    return y, aux * cfg.router_aux_weight
