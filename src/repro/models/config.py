"""Unified model configuration covering every assigned architecture family:
dense / MoE / SSM / hybrid (RG-LRU) / VLM / audio enc-dec.

A model is a repeating ``block_pattern`` of :class:`LayerSpec` scanned
``num_blocks`` times (scan-over-layers keeps HLO size O(1) in depth, which
is what keeps the 512-device dry-run compile tractable), plus an unrolled
``remainder`` for depths that don't divide the pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"          # attn | local_attn | rglru | ssm
    mlp: str = "dense"          # dense | moe | none


@dataclasses.dataclass(frozen=True)
class RedundancyConfig:
    """B-MoE trust settings (the paper's technique at LM scale).

    r: redundancy degree — the ``data`` mesh axis is split into
       ``data/r`` groups of ``r`` replicas; replicas within a group
       process identical tokens and majority-vote layer outputs.
    mode:
      off      — traditional distributed MoE (paper's baseline)
      faithful — all-gather full replica outputs, elementwise majority
                 vote (paper's Step 2-3, redundancy + consensus)
      digest   — beyond-paper: vote on per-token digests, recover the
                 majority value with one masked all-reduce (same
                 detection power vs the paper's adversary, ~r/2 x less
                 collective traffic)
    """

    r: int = 1
    mode: str = "off"           # off | faithful | digest

    def __post_init__(self):
        if self.mode not in ("off", "faithful", "digest"):
            raise ValueError(self.mode)
        if self.mode != "off" and self.r < 2:
            raise ValueError("redundancy requires r >= 2")


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                  # 0 -> d_model // num_heads
    # --- attention options ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 1024         # window for local_attn layers
    attn_logit_softcap: float = 0.0
    # --- layer pattern ---
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    num_blocks: int = 0                # 0 -> num_layers // len(block_pattern)
    remainder: Tuple[LayerSpec, ...] = ()
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    # pad the expert axis so it shards over the model axis (0 = off);
    # padded experts are masked out of routing (§Perf iteration 2)
    padded_num_experts: int = 0
    # KV-cache storage dtype for decode shapes: "default" (= activation
    # dtype) or "int8" (per-(batch,slot,head) absmax quantization —
    # §Perf iteration 4: halves the decode memory term)
    kv_cache_dtype: str = "default"
    # MoE distribution: "gspmd" (scatter dispatch, compiler-chosen
    # collectives) or "ep" (shard_map + explicit all_to_all expert
    # parallelism; §Perf iteration 2)
    moe_impl: str = "gspmd"

    num_shared_experts: int = 0
    moe_d_ff: int = 0                  # routed-expert hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # --- hybrid (RG-LRU) ---
    rglru_expand: int = 1
    # --- enc-dec ---
    num_encoder_layers: int = 0
    # --- multimodal stub frontend ---
    frontend: str = "none"             # none | vision | audio
    frontend_tokens: int = 0           # prefix embeddings per sample (train)
    # --- trust (the paper's technique) ---
    redundancy: RedundancyConfig = RedundancyConfig()
    # --- decode-cache sharding (set per input shape by launch/shapes) ---
    # mesh axes carrying the full-attention cache's sequence dim; sharding
    # the 32k/500k KV cache over "model" (and "data" when batch=1) is what
    # makes long-context decode fit HBM (flash-decoding-style parallelism)
    cache_seq_axes: Tuple[str, ...] = ("model",)
    # batch=1 shapes (long_500k) cannot shard the batch axis
    batch_shardable: bool = True
    # gradient-accumulation microbatches for train_4k (activation memory)
    train_microbatches: int = 1
    # --- numerics ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def padded_vocab(self) -> int:
        # pad so the vocab axis shards evenly over a 16-wide model axis
        return _round_up(self.vocab_size, 256)

    @property
    def resolved_padded_experts(self) -> int:
        return max(self.padded_num_experts, self.num_experts)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def pattern_layers(self) -> Tuple[LayerSpec, ...]:
        return self.block_pattern

    @property
    def resolved_num_blocks(self) -> int:
        if self.num_blocks:
            return self.num_blocks
        return (self.num_layers - len(self.remainder)) // len(self.block_pattern)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.num_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        specs = self.block_pattern + self.remainder
        return all(s.kind in ("ssm", "rglru") for s in specs)

    @property
    def subquadratic(self) -> bool:
        """True if no layer keeps an unbounded full-attention KV cache.

        ``attn`` layers are quadratic/full-cache; ``local_attn`` caches only
        the window; ``ssm``/``rglru`` carry O(1) state.  Models with *sparse*
        global layers (gemma3 5:1) are treated as subquadratic-capable for
        decode because the dominant cache is windowed and the rare global
        caches shard over the mesh.
        """
        specs = self.block_pattern + self.remainder
        n_global = sum(1 for s in specs if s.kind == "attn")
        return n_global == 0 or (n_global / len(specs)) <= 0.2

    def validate(self):
        n = self.resolved_num_blocks * len(self.block_pattern) + len(self.remainder)
        if n != self.num_layers:
            raise ValueError(
                f"{self.name}: pattern x blocks + remainder = {n} != num_layers {self.num_layers}")
        if any(s.mlp == "moe" for s in self.block_pattern + self.remainder):
            if not (self.num_experts and self.num_experts_per_tok and self.moe_d_ff):
                raise ValueError(f"{self.name}: MoE layers need expert config")
        return self


def dense_pattern(n_layers: int, mlp: str = "dense") -> dict:
    return dict(block_pattern=(LayerSpec("attn", mlp),), num_blocks=n_layers,
                remainder=())
