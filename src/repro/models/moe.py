"""Token-choice top-k sparsely-gated MoE layer (GShard-style) with
*group-wise* capacity dispatch, shared experts, and a load-balance
auxiliary loss.

Grouping: each batch row is a dispatch group (batch is the data-sharded
axis), so the capacity cumsum runs over S*k positions *within* a row —
independent across data shards, no cross-device serialization.  Tokens
are scattered into a per-group per-expert capacity buffer
(B, E, C, d), run through the grouped expert GEMM (the Pallas
``moe_gemm`` kernel on TPU; jnp einsum oracle elsewhere), and combined
back with their gate weights.

The B-MoE trust mechanism (redundant execution + consensus vote) wraps
the routed-expert output buffer — see ``repro.core.trusted_moe``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.builder import Leaf


def moe_decl(cfg) -> dict:
    E, d, f = cfg.resolved_padded_experts, cfg.d_model, cfg.moe_d_ff
    decl = {
        "router": Leaf((d, E), ("embed", "experts"), scale=0.02),
        "w_gate": Leaf((E, d, f), ("experts", "embed", "moe_ff")),
        "w_up": Leaf((E, d, f), ("experts", "embed", "moe_ff")),
        "w_down": Leaf((E, f, d), ("experts", "moe_ff", "embed")),
    }
    if cfg.num_shared_experts:
        sf = cfg.num_shared_experts * f
        decl["shared"] = {
            "w_gate": Leaf((d, sf), ("embed", "ff")),
            "w_up": Leaf((d, sf), ("embed", "ff")),
            "w_down": Leaf((sf, d), ("ff", "embed")),
        }
    return decl


def capacity_for(cfg, tokens_per_group: int) -> int:
    cap = max(int(cfg.capacity_factor * tokens_per_group *
                  cfg.num_experts_per_tok / cfg.num_experts), 1)
    cap = min(-(-cap // 8) * 8, tokens_per_group * cfg.num_experts_per_tok)
    return max(cap, 1)


def capacity_positions(expert_id, num_experts: int, capacity: int):
    """Capacity-bucket slot assignment — the shared dispatch machinery.

    ``expert_id``: (G, P) int — expert chosen at each of P dispatch
    positions, independently per group G (a batch row here; the single
    all-batch group in the B-MoE system's sparse dispatch).  Returns
    ``(position, keep, onehot)``: ``position[g, p]`` counts earlier
    same-expert assignments within the group (the slot in that expert's
    capacity bucket), ``keep = position < capacity`` marks assignments
    that fit, and ``onehot`` is the (G, P, E) int32 assignment tensor the
    positions were computed from (returned so callers needing per-expert
    statistics — the router aux loss — don't rebuild it).  Overflowing
    assignments are *dropped*, never mis-routed.
    """
    onehot = jax.nn.one_hot(expert_id, num_experts, dtype=jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot       # (G, P, E)
    position = (pos_all * onehot).sum(-1)
    return position, position < capacity, onehot


def route(logits, k: int, capacity: int, num_real: int = 0):
    """logits: (B, S, E).  Per-row top-k routing with capacity buckets.

    ``num_real`` < E masks the padded experts (expert-axis padding for
    even model-axis sharding) out of the softmax/top-k.

    Returns weights (B,S,k), expert_id (B,S,k), position (B,S,k),
    keep (B,S,k) and the GShard load-balance aux loss."""
    B, S, E = logits.shape
    if num_real and num_real < E:
        pad_mask = jnp.arange(E) >= num_real
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, expert_id = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    position, keep, onehot = capacity_positions(
        expert_id.reshape(B, S * k), E, capacity)
    position = position.reshape(B, S, k)
    keep = keep.reshape(B, S, k)

    frac_tokens = onehot.sum(axis=(0, 1)).astype(jnp.float32) / (B * S * k)
    frac_probs = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return weights, expert_id, position, keep, aux


def route_masked(logits, k: int, capacity: int, num_real: int = 0,
                 valid=None):
    """``route`` for a token axis that may carry pad rows, returning
    psum-able load-balance statistics instead of a local scalar aux.

    ``valid``: (B, S) bool (None = every row real).  Pad rows route to
    the out-of-range sentinel expert E whose one-hot row is all-zero —
    they occupy no capacity slot, carry zero gate weight, and a scatter
    at expert index E is out-of-bounds (dropped), so padding adds no
    wire bytes and no expert FLOPs.

    Returns weights (B,S,k), expert_id (B,S,k), position (B,S,k),
    keep (B,S,k) and ``(tok_counts (E,), prob_sums (E,), n_valid ())``.
    A sharded caller psums the statistics over its token shards and
    forms the aux loss over the EXACT global batch::

        aux = E * sum(counts / (T * k) * probs / T),  T = n_valid

    which with ``valid=None`` on one shard reduces bitwise to
    ``route``'s aux (same sums, same order)."""
    B, S, E = logits.shape
    if num_real and num_real < E:
        pad_mask = jnp.arange(E) >= num_real
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, expert_id = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    if valid is not None:
        expert_id = jnp.where(valid[:, :, None], expert_id, E)
        weights = weights * valid[:, :, None].astype(weights.dtype)

    position, keep, onehot = capacity_positions(
        expert_id.reshape(B, S * k), E, capacity)
    position = position.reshape(B, S, k)
    keep = keep.reshape(B, S, k)
    if valid is not None:
        # a pad row's zero one-hot lands at position 0 (< capacity)
        keep = keep & valid[:, :, None]

    tok_counts = onehot.sum(axis=(0, 1)).astype(jnp.float32)
    if valid is None:
        prob_sums = probs.sum(axis=(0, 1))
        n_valid = jnp.float32(B * S)
    else:
        prob_sums = (probs * valid[:, :, None].astype(probs.dtype)
                     ).sum(axis=(0, 1))
        n_valid = valid.sum().astype(jnp.float32)
    return weights, expert_id, position, keep, (tok_counts, prob_sums,
                                                n_valid)


def grouped_mlp(buf, w_gate, w_up, w_down, shard=None):
    """buf: (B, E, C, d) -> (B, E, C, d) through each expert's SwiGLU.

    On TPU this is the ``moe_gemm`` Pallas kernel (B folded into the
    grid); the einsums below are its exact oracle and the GSPMD path
    used for dry-run lowering."""
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, w_gate)) * \
        jnp.einsum("becd,edf->becf", buf, w_up)
    if shard is not None:
        h = shard(h, "batch", "experts", None, "moe_ff")
    return jnp.einsum("becf,efd->becd", h, w_down)


def moe_mlp(params, x, cfg, shard=None, trust=None, return_stats=False):
    """x: (B, S, d) -> (B, S, d), plus aux loss.

    ``trust``: optional hook applied to the routed-expert output buffer —
    the B-MoE redundancy + consensus vote.

    ``return_stats``: also return the per-expert routed-token counts
    ``(E,)`` (drops included — a dropped assignment still computed its
    bucket, so its expert's parameters were needed).  This is the gate
    statistic the serving engine's edge cache feeds its EMA prefetcher
    with; default off so existing (y, aux) call sites are untouched."""
    B, S, d = x.shape
    k = cfg.num_experts_per_tok
    E = cfg.resolved_padded_experts
    C = capacity_for(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    weights, expert_id, position, keep, aux = route(logits, k, C,
                                                    cfg.num_experts)

    # ---- dispatch: per-row scatter into (B, E, C, d) capacity buffers
    row = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * k))
    eid = expert_id.reshape(B, S * k)
    pos = jnp.where(keep, position, C - 1).reshape(B, S * k)  # clamp
    gath = jnp.repeat(x, k, axis=1) * keep.reshape(B, S * k, 1).astype(x.dtype)
    buf = jnp.zeros((B, E, C, d), x.dtype).at[row, eid, pos].add(gath)
    if shard is not None:
        buf = shard(buf, "batch", "experts", None, "embed")

    out_buf = grouped_mlp(buf, params["w_gate"], params["w_up"],
                          params["w_down"], shard=shard)
    if trust is not None:  # B-MoE consensus on per-expert outputs
        # the vote needs concrete (fully-reduced) buffer values
        if shard is not None:
            out_buf = shard(out_buf, "batch", "experts", None, "embed")
        out_buf = trust(out_buf)
    # NOTE: no sharding constraint on out_buf otherwise — under expert-TP
    # (moe_ff sharded) the buffer is a partial sum, and the combine below
    # is linear in it, so XLA can defer the psum to the (B, S, d) output
    # (~E*C/S x fewer reduced bytes; §Perf iteration 2)

    # ---- combine: gather back and weight
    yk = out_buf[row, eid, pos]                          # (B, S*k, d)
    wk = (weights * keep).reshape(B, S * k, 1).astype(x.dtype)
    y = (yk * wk).reshape(B, S, k, d).sum(axis=2)

    if cfg.num_shared_experts:
        sp = params["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    if return_stats:
        counts = jnp.zeros(E, jnp.int32).at[eid.reshape(-1)].add(1)
        return y, aux * cfg.router_aux_weight, counts
    return y, aux * cfg.router_aux_weight
