"""Encoder-decoder backbone (Seamless-M4T-style, arXiv:2308.11596).

The modality frontend (mel-spectrogram + conv feature extractor) is a
STUB per the assignment: the encoder consumes precomputed frame
embeddings (B, S_enc, d) supplied by ``input_specs``.  This module
implements the transformer backbone: bidirectional encoder + causal
decoder with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.builder import Leaf, stack
from repro.models.config import ModelConfig
from repro.models.layers import (attn_decl, attn_decode, attn_train,
                                 blockwise_attention, mlp_decl, rmsnorm,
                                 swiglu)


def _enc_layer_decl(cfg):
    return {
        "norm1": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn_decl(cfg),
        "norm2": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "mlp": mlp_decl(cfg),
    }


def _dec_layer_decl(cfg):
    return {
        "norm1": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn_decl(cfg),
        "norm_x": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "xattn": attn_decl(cfg),
        "norm2": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "mlp": mlp_decl(cfg),
    }


def encdec_decl(cfg: ModelConfig) -> dict:
    return {
        "embed": Leaf((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                      scale=0.02),
        "enc_blocks": stack(_enc_layer_decl(cfg), cfg.num_encoder_layers),
        "dec_blocks": stack(_dec_layer_decl(cfg), cfg.num_layers),
        "enc_norm": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "final_norm": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "lm_head": Leaf((cfg.d_model, cfg.padded_vocab), ("embed", "vocab"),
                        scale=0.02),
    }


def encdec_cache_decl(cfg: ModelConfig, batch: int, cache_len: int,
                      memory_len: int) -> dict:
    """Decoder self-attention KV cache + precomputed cross K/V."""
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    self_kv = Leaf((L, batch, cache_len, cfg.num_kv_heads, hd),
                   ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                   "zeros")
    cross_kv = Leaf((L, batch, memory_len, cfg.num_kv_heads, hd),
                    ("layers", "batch", None, "kv_heads", "head_dim"),
                    "zeros")
    return {"self_k": self_kv, "self_v": self_kv,
            "cross_k": cross_kv, "cross_v": cross_kv}


def _cross_attn_train(p, x, memory, cfg, shard):
    """x: (B, Sq, d) queries; memory: (B, Sk, d)."""
    B, Sq, _ = x.shape
    Sk = memory.shape[1]
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, Sq, cfg.num_heads, hd)
    k = (memory @ p["wk"]).reshape(B, Sk, cfg.num_kv_heads, hd)
    v = (memory @ p["wv"]).reshape(B, Sk, cfg.num_kv_heads, hd)
    out = blockwise_attention(q, k, v, causal=False)
    return out.reshape(B, Sq, cfg.q_dim) @ p["wo"]


def encode(params, frames, cfg: ModelConfig, *, shard=None, remat=True,
           unroll=False):
    """frames: (B, S_enc, d) stub embeddings -> encoder memory."""
    x = frames
    if shard is not None:
        x = shard(x, "batch", "seq", "embed")

    def body(x, p):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        x = x + attn_train(p["attn"], h, cfg, causal=False, shard=shard)
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], shard=shard)
        if shard is not None:
            x = shard(x, "batch", "seq", "embed")
        return x, None

    if remat:
        body = jax.checkpoint(body)
    from repro.models.transformer import scan_or_unroll
    x, _ = scan_or_unroll(body, x, params["enc_blocks"], unroll)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def forward_train(params, frames, tokens, cfg: ModelConfig, *, shard=None,
                  remat=True, unroll=False):
    """Full enc-dec training forward.  frames: (B, S_enc, d) stub
    embeddings; tokens: (B, S_dec).  Returns (logits, aux=0)."""
    memory = encode(params, frames, cfg, shard=shard, remat=remat,
                    unroll=unroll)
    x = jnp.take(params["embed"], tokens, axis=0)
    if shard is not None:
        x = shard(x, "batch", "seq", "embed")

    def body(x, p):
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        x = x + attn_train(p["attn"], h, cfg, causal=True, shard=shard)
        h = rmsnorm(x, p["norm_x"], cfg.norm_eps)
        x = x + _cross_attn_train(p["xattn"], h, memory, cfg, shard)
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], shard=shard)
        if shard is not None:
            x = shard(x, "batch", "seq", "embed")
        return x, None

    if remat:
        body = jax.checkpoint(body)
    from repro.models.transformer import scan_or_unroll
    x, _ = scan_or_unroll(body, x, params["dec_blocks"], unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    if shard is not None:
        logits = shard(logits, "batch", "seq", "vocab")
    return logits, jnp.zeros((), jnp.float32)


def forward_decode(params, caches, tokens, pos, cfg: ModelConfig, *,
                   shard=None, unroll=False):
    """One decoder step against cached self-KV and precomputed cross-KV.
    tokens: (B, 1).  Returns (logits, new_caches)."""
    from repro.models.layers import decode_attention
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    hd = cfg.resolved_head_dim

    def body(x, inp):
        p, sk, sv, ck, cv = inp
        h = rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, new_cache = attn_decode(p["attn"], h, {"k": sk, "v": sv}, pos,
                                   cfg, shard=shard)
        x = x + y
        # cross-attention against precomputed memory K/V
        h = rmsnorm(x, p["norm_x"], cfg.norm_eps)
        q = (h @ p["xattn"]["wq"]).reshape(B, 1, cfg.num_heads, hd)
        mem_len = ck.shape[1]
        y = decode_attention(q, ck, cv, jnp.int32(mem_len - 1))
        x = x + y.reshape(B, 1, cfg.q_dim) @ p["xattn"]["wo"]
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], shard=shard)
        return x, (new_cache["k"], new_cache["v"])

    from repro.models.transformer import scan_or_unroll
    x, (new_k, new_v) = scan_or_unroll(
        body, x, (params["dec_blocks"], caches["self_k"], caches["self_v"],
                  caches["cross_k"], caches["cross_v"]), unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    new_caches = dict(caches)
    new_caches["self_k"], new_caches["self_v"] = new_k, new_v
    return logits, new_caches
