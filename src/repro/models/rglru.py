"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Recurrence: a_t = exp(-c * softplus(Lambda) * sigmoid(W_r x_t)),
h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_i x_t) * x_t).
Training uses ``lax.associative_scan`` (log-depth); decode carries the
hidden state — O(1) memory, so recurrentgemma runs ``long_500k``.

Block structure (simplified Griffin recurrent block): two branches from
the residual stream — (conv1d -> RG-LRU) and a GeLU gate — multiplied and
projected back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.builder import Leaf

_C = 8.0


def rglru_decl(cfg) -> dict:
    d = cfg.d_model
    inner = cfg.rglru_expand * d
    w = cfg.ssm_conv_width
    return {
        "w_in": Leaf((d, inner), ("embed", "rglru_inner")),
        "w_gate_branch": Leaf((d, inner), ("embed", "rglru_inner")),
        "conv": Leaf((w, inner), ("conv", "rglru_inner"), scale=0.5),
        "w_r": Leaf((inner, inner), ("rglru_inner", None), scale=0.02),
        "w_i": Leaf((inner, inner), ("rglru_inner", None), scale=0.02),
        "lam": Leaf((inner,), ("rglru_inner",), "constant", scale=0.7),
        "w_out": Leaf((inner, d), ("rglru_inner", "embed")),
    }


def _gates(params, x):
    """x: (..., inner) -> (log_a, gated_input), both (..., inner), f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def rglru_scan(a, b):
    """h_t = a_t * h_{t-1} + b_t over axis 1 via associative scan.
    a, b: (B, S, C) f32.  Returns h: (B, S, C)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_train(params, x, cfg, shard=None):
    """x: (B, S, d) -> (B, S, d)."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u = x @ params["w_in"]
    from repro.models.ssm import _causal_conv
    u = _causal_conv(u, params["conv"])
    if shard is not None:
        u = shard(u, "batch", "seq", "rglru_inner")
        gate = shard(gate, "batch", "seq", "rglru_inner")
    a, b = _gates(params, u)
    h = rglru_scan(a, b).astype(x.dtype)
    return (h * gate) @ params["w_out"]


def rglru_decode(params, x, cache, cfg, shard=None):
    """One token. cache = {"h": (B, inner) f32, "conv": (B, W-1, inner)}."""
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ params["w_gate_branch"])
    pre = xt @ params["w_in"]
    hist = jnp.concatenate([cache["conv"], pre[:, None]], axis=1)
    u = (hist * params["conv"][None]).sum(axis=1)
    a, b = _gates(params, u)
    h = a * cache["h"] + b
    out = ((h.astype(x.dtype) * gate) @ params["w_out"])[:, None]
    return out, {"h": h, "conv": hist[:, 1:]}
