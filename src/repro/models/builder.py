"""Declaration-based parameter trees.

Models declare their parameters once as a nested dict of :class:`Leaf`
(shape + logical axes + init law).  The same declaration is then
*materialized* three ways:

- ``materialize``  -> real ``jnp`` arrays (for CPU-scale training/tests)
- ``abstract``     -> ``jax.ShapeDtypeStruct`` (for the multi-pod dry-run:
  no memory is ever allocated for the full-size models)
- ``partition_specs`` -> ``PartitionSpec`` tree (sharding for pjit)

This guarantees params / shapes / shardings can never drift apart.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Tree = Any


@dataclasses.dataclass(frozen=True)
class Leaf:
    """A single parameter declaration."""

    shape: tuple
    axes: tuple  # logical axis name (or None) per dim, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | scaled | constant
    scale: float | None = None  # stddev for normal/scaled; value for constant
    dtype: str | None = None    # override the materialization dtype
                                # (e.g. "int8" quantized KV caches)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _fold_key(root: jax.Array, path: str) -> jax.Array:
    digest = hashlib.sha256(path.encode()).digest()
    return jax.random.fold_in(root, int.from_bytes(digest[:4], "big"))


def _init_leaf(leaf: Leaf, key: jax.Array, dtype) -> jax.Array:
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    if leaf.init == "constant":
        return jnp.full(leaf.shape, leaf.scale, dtype)
    if leaf.init in ("normal", "scaled"):
        if leaf.scale is not None:
            std = leaf.scale
        else:  # fan-in scaling on the second-to-last dim (or last for 1D)
            fan_in = leaf.shape[-2] if len(leaf.shape) >= 2 else leaf.shape[-1]
            std = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, leaf.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {leaf.init}")


def _walk(tree: Tree, fn: Callable[[str, Leaf], Any], prefix: str = "") -> Tree:
    if isinstance(tree, Leaf):
        return fn(prefix, tree)
    if isinstance(tree, Mapping):
        return {k: _walk(v, fn, f"{prefix}/{k}") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_walk(v, fn, f"{prefix}/{i}") for i, v in enumerate(tree)]
    raise TypeError(f"unexpected node at {prefix}: {type(tree)}")


def _leaf_dtype(leaf: Leaf, default):
    return jnp.dtype(leaf.dtype) if leaf.dtype else default


def materialize(decl: Tree, key: jax.Array, dtype=jnp.float32) -> Tree:
    return _walk(decl, lambda p, l: _init_leaf(l, _fold_key(key, p),
                                               _leaf_dtype(l, dtype)))


def abstract(decl: Tree, dtype=jnp.bfloat16) -> Tree:
    return _walk(decl, lambda p, l: jax.ShapeDtypeStruct(
        l.shape, _leaf_dtype(l, dtype)))


def partition_specs(decl: Tree, rules: Mapping[str, Any]) -> Tree:
    """Map logical axes -> mesh axes.  ``rules[name]`` is a mesh axis name,
    a tuple of mesh axis names, or None."""

    def leaf_spec(_, leaf: Leaf):
        return P(*[rules.get(a) if a is not None else None for a in leaf.axes])

    return _walk(decl, leaf_spec)


def count_params(decl: Tree) -> int:
    total = 0

    def add(_, leaf: Leaf):
        nonlocal total
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        return None

    _walk(decl, add)
    return total


def stack(decl: Tree, n: int, axis_name: str = "layers") -> Tree:
    """Prepend a stacked (scan) dimension of size ``n`` to every leaf."""

    def stk(_, leaf: Leaf):
        return Leaf((n,) + tuple(leaf.shape), (axis_name,) + tuple(leaf.axes),
                    leaf.init, leaf.scale, leaf.dtype)

    return _walk(decl, stk)
