"""Core transformer layers: RMSNorm, RoPE, (blockwise) attention, SwiGLU.

Attention for training/prefill is *blockwise with online softmax* (a pure
jnp twin of the Pallas flash kernel): memory is O(S * chunk), never
O(S^2), which is what lets prefill_32k lower/compile within HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.builder import Leaf

NEG_INF = -1e30


# ----------------------------------------------------------------- norms
def rmsnorm(x, weight, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dtype)


# ------------------------------------------------------------------ rope
def rope(x, positions, theta=10_000.0):
    """x: (..., S, H, D) rotated at absolute ``positions`` (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention
def _softcap(scores, cap):
    if cap and cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


def blockwise_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                        q_offset=0, q_chunk=512, kv_chunk=512):
    """Online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H = KH * G.
    ``window`` > 0 limits attention to the last ``window`` keys (sliding
    window, inclusive of self).  ``q_offset``: absolute position of q[0]
    relative to k[0] (for chunked prefill; 0 for plain self-attention).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = D ** -0.5

    def _pick(S, c):  # largest divisor of S that is <= c
        c = min(c, S)
        while S % c:
            c -= 1
        return c

    q_chunk = _pick(Sq, q_chunk)
    kv_chunk = _pick(Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    qc = q.reshape(B, nq, q_chunk, KH, G, D)
    kc = k.reshape(B, nk, kv_chunk, KH, D)
    vc = v.reshape(B, nk, kv_chunk, KH, D)

    def q_step(_, qi):
        qblk = qc[:, qi]  # (B, qc, KH, G, D)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint  # flash-style: recompute scores/probs in backward
        def kv_step(carry, ki):
            acc, m, l = carry
            kblk, vblk = kc[:, ki], vc[:, ki]
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KH, G, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)  # (B, KH, G, qc, D)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, jnp.arange(nq))
    # outs: (nq, B, KH, G, qc, D) -> (B, Sq, H, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq, H, q_chunk, D)
    out = out.transpose(0, 1, 3, 2, 4).reshape(B, Sq, H, D)
    return out


def decode_attention(q, k_cache, v_cache, pos, *, window=0, softcap=0.0):
    """Single-token attention against a cache.

    q: (B, 1, H, D); caches: (B, cap, KH, D); pos: int32 scalar or (B,)
    vector — number of tokens already in the cache *including* the one
    just written at ``pos % cap`` (ring) or ``pos`` (linear).  A vector
    ``pos`` gives every batch row its own decode position (continuous
    batching: co-batched requests at different depths).  Entries with
    absolute index > pos or <= pos - window are masked.
    """
    B, cap, KH, D = k_cache.shape
    H = q.shape[2]
    G = H // KH
    scale = D ** -0.5
    qh = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    slot = jnp.arange(cap)
    if jnp.ndim(pos):                       # per-row positions: (B, cap)
        p_ = pos[:, None]
        if window:
            absidx = p_ - ((p_ - slot[None, :]) % cap)
            valid = (absidx >= 0) & (absidx <= p_) & (absidx > p_ - window)
        else:
            valid = slot[None, :] <= p_
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    else:
        if window:  # ring buffer: absolute index of slot i
            absidx = pos - ((pos - slot) % cap)
            valid = (absidx >= 0) & (absidx <= pos) & (absidx > pos - window)
        else:
            valid = slot <= pos
        s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, D)


# ----------------------------------------------------------------- MLP
def swiglu(x, w_gate, w_up, w_down, shard=None):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    if shard is not None:
        h = shard(h, "batch", "seq", "ff")
    return h @ w_down


# ------------------------------------------------------- declarations
def attn_decl(cfg) -> dict:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.resolved_head_dim
    decl = {
        "wq": Leaf((d, qd), ("embed", "q_dim")),
        "wk": Leaf((d, kvd), ("embed", "kv_dim")),
        "wv": Leaf((d, kvd), ("embed", "kv_dim")),
        "wo": Leaf((qd, d), ("q_dim", "embed")),
    }
    if cfg.qkv_bias:
        decl["bq"] = Leaf((qd,), ("q_dim",), "zeros")
        decl["bk"] = Leaf((kvd,), ("kv_dim",), "zeros")
        decl["bv"] = Leaf((kvd,), ("kv_dim",), "zeros")
    if cfg.qk_norm:
        decl["q_norm"] = Leaf((hd,), ("head_dim",), "zeros")
        decl["k_norm"] = Leaf((hd,), ("head_dim",), "zeros")
    return decl


def mlp_decl(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": Leaf((d, f), ("embed", "ff")),
        "w_up": Leaf((d, f), ("embed", "ff")),
        "w_down": Leaf((f, d), ("ff", "embed")),
    }


# -------------------------------------------------------------- apply
def attn_qkv(params, x, positions, cfg):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(params, x, cfg, *, window=0, causal=True, shard=None,
               q_chunk=512, kv_chunk=512):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = attn_qkv(params, x, positions, cfg)
    # note: no explicit q/k/v constraints here — GSPMD propagates the head
    # sharding from the (q_dim/kv_dim)-sharded projection weights, which
    # handles GQA counts that don't divide the model axis.
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_logit_softcap,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out.reshape(B, S, cfg.q_dim) @ params["wo"]


def _quantize_kv(t):
    """t: (B, 1, KH, D) -> (int8 values, (B, 1, KH) f32 scales)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attn_decode(params, x, cache, pos, cfg, *, window=0, shard=None):
    """One-token decode. cache: {"k": (B,cap,KH,D), "v": ...} (+ optional
    int8 "k_scale"/"v_scale" when cfg.kv_cache_dtype == "int8").

    ``pos`` is an int32 scalar (every row at the same depth — the
    batch-synchronous path) or a (B,) vector (continuous batching: each
    row writes/reads its own cache slot).  Returns (out, new_cache).
    """
    B = x.shape[0]
    vec = jnp.ndim(pos) > 0
    positions = (jnp.reshape(pos, (B, 1)).astype(jnp.int32) if vec
                 else jnp.full((B, 1), pos, jnp.int32))
    q, k, v = attn_qkv(params, x, positions, cfg)
    cap = cache["k"].shape[1]
    slot = (pos % cap) if window else jnp.minimum(pos, cap - 1)
    kv_seq_ax = "cache_seq" if not window else "kv_seq"
    quantized = "k_scale" in cache

    if vec:
        rows = jnp.arange(B)

        def put(buf, val):           # per-row scatter: row b writes slot[b]
            return buf.at[rows, slot].set(val[:, 0])
    else:
        def put(buf, val):
            return jax.lax.dynamic_update_slice_in_dim(buf, val, slot,
                                                       axis=1)

    if quantized:  # §Perf iteration 4: int8 cache halves HBM cache reads
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k": put(cache["k"], kq),
            "v": put(cache["v"], vq),
            "k_scale": put(cache["k_scale"], ks),
            "v_scale": put(cache["v_scale"], vs),
        }
        if shard is not None:
            new_cache["k"] = shard(new_cache["k"], "batch", kv_seq_ax,
                                   "kv_heads", "head_dim")
            new_cache["v"] = shard(new_cache["v"], "batch", kv_seq_ax,
                                   "kv_heads", "head_dim")
        k_cache = (new_cache["k"].astype(jnp.float32)
                   * new_cache["k_scale"][..., None]).astype(x.dtype)
        v_cache = (new_cache["v"].astype(jnp.float32)
                   * new_cache["v_scale"][..., None]).astype(x.dtype)
    else:
        k_cache = put(cache["k"], k)
        v_cache = put(cache["v"], v)
        if shard is not None:
            k_cache = shard(k_cache, "batch", kv_seq_ax, "kv_heads",
                            "head_dim")
            v_cache = shard(v_cache, "batch", kv_seq_ax, "kv_heads",
                            "head_dim")
        new_cache = {"k": k_cache, "v": v_cache}
    out = decode_attention(q, k_cache, v_cache, pos, window=window,
                           softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, 1, cfg.q_dim) @ params["wo"]
    return out, new_cache
