"""Decoder-only model: scan-over-layers stack handling every layer kind
(attn / local_attn / rglru / ssm) x (dense / moe / none) MLP.

Parameters, KV-caches and inputs are all declared with
``repro.models.builder`` so they materialize identically as real arrays
(tests), ShapeDtypeStructs (dry-run) and PartitionSpecs (pjit).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.builder import Leaf, stack
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (attn_decl, attn_decode, attn_train,
                                 mlp_decl, rmsnorm, swiglu)


# ------------------------------------------------------------- decls
def layer_decl(spec: LayerSpec, cfg: ModelConfig) -> dict:
    decl = {"norm1": Leaf((cfg.d_model,), ("embed",), "zeros")}
    if spec.kind in ("attn", "local_attn"):
        decl["attn"] = attn_decl(cfg)
    elif spec.kind == "rglru":
        decl["rglru"] = rglru_lib.rglru_decl(cfg)
    elif spec.kind == "ssm":
        decl["ssm"] = ssm_lib.ssm_decl(cfg)
    else:
        raise ValueError(spec.kind)
    if spec.mlp != "none":
        decl["norm2"] = Leaf((cfg.d_model,), ("embed",), "zeros")
        decl["moe" if spec.mlp == "moe" else "mlp"] = (
            moe_lib.moe_decl(cfg) if spec.mlp == "moe" else mlp_decl(cfg))
    return decl


def model_decl(cfg: ModelConfig) -> dict:
    nb = cfg.resolved_num_blocks
    decl = {
        "embed": Leaf((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                      scale=0.02),
        "final_norm": Leaf((cfg.d_model,), ("embed",), "zeros"),
        "blocks": {str(i): stack(layer_decl(s, cfg), nb)
                   for i, s in enumerate(cfg.block_pattern)},
    }
    if cfg.remainder:
        decl["remainder"] = [layer_decl(s, cfg) for s in cfg.remainder]
    if not cfg.tie_embeddings:
        decl["lm_head"] = Leaf((cfg.d_model, cfg.padded_vocab),
                               ("embed", "vocab"), scale=0.02)
    return decl


def _attn_cache_decl(cfg: ModelConfig, batch: int, cache_len: int,
                     window: int) -> dict:
    cap = min(window, cache_len) if window else cache_len
    seq_ax = "kv_seq" if window else "cache_seq"
    shape = (batch, cap, cfg.num_kv_heads, cfg.resolved_head_dim)
    axes = ("batch", seq_ax, "kv_heads", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        # §Perf iteration 4: absmax-quantized cache + per-slot-head scales
        sshape = (batch, cap, cfg.num_kv_heads)
        saxes = ("batch", seq_ax, "kv_heads")
        return {"k": Leaf(shape, axes, "zeros", dtype="int8"),
                "v": Leaf(shape, axes, "zeros", dtype="int8"),
                "k_scale": Leaf(sshape, saxes, "zeros", dtype="float32"),
                "v_scale": Leaf(sshape, saxes, "zeros", dtype="float32")}
    return {"k": Leaf(shape, axes, "zeros"), "v": Leaf(shape, axes, "zeros")}


def _layer_cache_decl(spec: LayerSpec, cfg: ModelConfig, batch: int,
                      cache_len: int) -> dict:
    if spec.kind == "attn":
        return _attn_cache_decl(cfg, batch, cache_len, 0)
    if spec.kind == "local_attn":
        return _attn_cache_decl(cfg, batch, cache_len, cfg.sliding_window)
    if spec.kind == "rglru":
        inner = cfg.rglru_expand * cfg.d_model
        return {
            "h": Leaf((batch, inner), ("batch", "rglru_inner"), "zeros"),
            "conv": Leaf((batch, cfg.ssm_conv_width - 1, inner),
                         ("batch", "conv", "rglru_inner"), "zeros"),
        }
    if spec.kind == "ssm":
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        convdim = cfg.ssm_inner + 2 * N
        return {
            "state": Leaf((batch, H, P, N),
                          ("batch", "ssm_heads", None, "state"), "zeros"),
            "conv": Leaf((batch, cfg.ssm_conv_width - 1, convdim),
                         ("batch", "conv", None), "zeros"),
        }
    raise ValueError(spec.kind)


def cache_decl(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    nb = cfg.resolved_num_blocks
    decl = {"blocks": {str(i): stack(_layer_cache_decl(s, cfg, batch, cache_len), nb)
                       for i, s in enumerate(cfg.block_pattern)}}
    if cfg.remainder:
        decl["remainder"] = [_layer_cache_decl(s, cfg, batch, cache_len)
                             for s in cfg.remainder]
    return decl


# ------------------------------------------- block-granular KV paging
def check_kv_pageable(cfg: ModelConfig) -> None:
    """KV paging (``repro.storage.kv``) addresses cache ROWS by absolute
    position, which only the full-attention cache layout guarantees:
    local_attn caches are capped ring windows and rglru/ssm carry
    recurrent state that is not row-addressable.  Raises for those."""
    for spec in list(cfg.block_pattern) + list(cfg.remainder):
        if spec.kind != "attn":
            raise ValueError(
                f"kv_storage needs all-'attn' layers (row-addressable "
                f"caches); config has a {spec.kind!r} layer")


def slice_kv_block(caches, slot: int, start: int, end: int) -> dict:
    """Copy one slot's cache rows [start, end) out of every layer's KV
    leaves, as host numpy arrays — the pytree a sealed KV block stores.
    Stacked block caches carry a leading layer axis (batch is axis 1);
    remainder caches lead with batch."""
    block = {"blocks": jax.tree_util.tree_map(
        lambda a: np.asarray(a[:, slot, start:end]), caches["blocks"])}
    if "remainder" in caches:
        block["remainder"] = jax.tree_util.tree_map(
            lambda a: np.asarray(a[slot, start:end]),
            caches["remainder"])
    return block


def restore_kv_block(caches, slot: int, start: int, block: dict) -> dict:
    """Functional inverse of ``slice_kv_block``: write a fetched block's
    rows back into one slot at ``start``.  Returns the new cache tree."""
    new = {"blocks": jax.tree_util.tree_map(
        lambda a, b: a.at[:, slot, start:start + b.shape[1]].set(
            jnp.asarray(b, a.dtype)),
        caches["blocks"], block["blocks"])}
    if "remainder" in caches:
        new["remainder"] = jax.tree_util.tree_map(
            lambda a, b: a.at[slot, start:start + b.shape[0]].set(
                jnp.asarray(b, a.dtype)),
            caches["remainder"], block["remainder"])
    return new


# ------------------------------------------------------------- apply
def scan_or_unroll(body, carry, xs, unroll: bool):
    """lax.scan, or a Python loop over the leading axis (``unroll=True``).

    The dry-run unrolls the layer stack because XLA's cost_analysis
    counts a while-loop body once — unrolling yields correct per-layer
    FLOPs/bytes/collective accounting (inner chunk scans are corrected
    analytically in launch/roofline.py)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    nb = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(nb):
        sl = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _layer_train(spec: LayerSpec, p, x, cfg, shard, trust, chunks):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.kind in ("attn", "local_attn"):
        window = cfg.sliding_window if spec.kind == "local_attn" else 0
        y = attn_train(p["attn"], h, cfg, window=window, shard=shard,
                       q_chunk=chunks[0], kv_chunk=chunks[1])
    elif spec.kind == "rglru":
        y = rglru_lib.rglru_train(p["rglru"], h, cfg, shard=shard)
    elif spec.kind == "ssm":
        y = ssm_lib.ssm_train(p["ssm"], h, cfg, shard=shard)
    x = x + y
    if shard is not None:
        x = shard(x, "batch", "seq", "embed")
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp != "none":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if spec.mlp == "moe":
            if (cfg.moe_impl == "ep" and shard is not None
                    and shard.mesh is not None):
                from repro.models.moe_ep import moe_mlp_ep
                y, aux = moe_mlp_ep(p["moe"], h, cfg, shard.mesh,
                                    shard.rules, fsdp=shard.fsdp,
                                    attack=shard.attack)
            else:
                y, aux = moe_lib.moe_mlp(p["moe"], h, cfg, shard=shard,
                                         trust=trust)
        else:
            y = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], shard=shard)
        x = x + y
        if shard is not None:
            x = shard(x, "batch", "seq", "embed")
    return x, aux


def forward_train(params, tokens, cfg: ModelConfig, *, shard=None,
                  trust=None, prefix_embeds=None, remat=True,
                  q_chunk=512, kv_chunk=512, unroll=False):
    """tokens: (B, S_text) int32; prefix_embeds: optional (B, P, d) stub
    modality embeddings prepended to the sequence (VLM early fusion).
    Returns (logits (B, S, V), aux_loss)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if shard is not None:
        x = shard(x, "batch", "seq", "embed")
    chunks = (q_chunk, kv_chunk)

    def body(carry, blk):
        x, aux = carry
        for i, spec in enumerate(cfg.block_pattern):
            x, a = _layer_train(spec, blk[str(i)], x, cfg, shard, trust,
                                chunks)
            aux = aux + a
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = scan_or_unroll(body, (x, jnp.zeros((), jnp.float32)),
                                 params["blocks"], unroll)
    for i, spec in enumerate(cfg.remainder):
        x, a = _layer_train(spec, params["remainder"][i], x, cfg, shard,
                            trust, chunks)
        aux = aux + a
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if shard is not None:
        logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux


def _mask_rows(mask, new, old):
    """Row-select a cache leaf: rows where ``mask`` is False keep their
    old value (the slot is not advancing this step)."""
    m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def _layer_decode(spec: LayerSpec, p, cache, x, pos, cfg, shard,
                  expert_stats=False, write_mask=None):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.kind in ("attn", "local_attn"):
        window = cfg.sliding_window if spec.kind == "local_attn" else 0
        y, new_cache = attn_decode(p["attn"], h, cache, pos, cfg,
                                   window=window, shard=shard)
    elif spec.kind == "rglru":
        y, new_cache = rglru_lib.rglru_decode(p["rglru"], h, cache, cfg,
                                              shard=shard)
    elif spec.kind == "ssm":
        y, new_cache = ssm_lib.ssm_decode(p["ssm"], h, cache, cfg,
                                          shard=shard)
    if write_mask is not None:
        # inactive slots (not decoding this step / past their prefill
        # length) must not advance KV rows or recurrent state
        new_cache = jax.tree_util.tree_map(
            lambda n, o: _mask_rows(write_mask, n, o), new_cache, cache)
    x = x + y
    counts = None
    if spec.mlp != "none":
        h = rmsnorm(x, p["norm2"], cfg.norm_eps)
        if spec.mlp == "moe":
            if expert_stats:
                y, _, counts = moe_lib.moe_mlp(p["moe"], h, cfg, shard=shard,
                                               return_stats=True)
            else:
                y, _ = moe_lib.moe_mlp(p["moe"], h, cfg, shard=shard)
        else:
            y = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], shard=shard)
        x = x + y
    return x, new_cache, counts


def forward_decode(params, caches, tokens, pos, cfg: ModelConfig, *,
                   shard=None, unroll=False, expert_stats=False,
                   write_mask=None):
    """One decode step.  tokens: (B, 1); pos: int32 scalar (all rows at
    the same absolute position — the batch-synchronous path) or (B,)
    vector (continuous batching: per-slot positions).  Returns
    (logits (B, 1, V), new_caches) — plus, with ``expert_stats``, the
    per-MoE-layer routed-token counts ``(num_moe_layers, E)`` in layer
    order (scanned blocks first, then the remainder): the gate
    statistics a serving edge feeds its expert cache/prefetcher with.

    ``write_mask`` (B,) bool: rows where it is False run the (padded)
    compute but leave their KV rows and recurrent state untouched — the
    fixed-shape active-slot mask that lets one compiled step serve any
    batch occupancy without recompilation."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if shard is not None:
        x = shard(x, "batch", "seq", "embed")
    n_moe_blk = sum(1 for s in cfg.block_pattern if s.mlp == "moe")

    def body(x, inp):
        blk, cch = inp
        new_cch = {}
        cnts = []
        for i, spec in enumerate(cfg.block_pattern):
            x, new_cch[str(i)], c = _layer_decode(
                spec, blk[str(i)], cch[str(i)], x, pos, cfg, shard,
                expert_stats=expert_stats, write_mask=write_mask)
            if c is not None:
                cnts.append(c)
        if expert_stats and cnts:
            return x, (new_cch, jnp.stack(cnts))
        return x, (new_cch, None) if expert_stats else new_cch

    x, ys = scan_or_unroll(body, x, (params["blocks"], caches["blocks"]),
                           unroll)
    if expert_stats:
        new_block_caches, blk_counts = ys
        counts = ([blk_counts.reshape(-1, blk_counts.shape[-1])]
                  if n_moe_blk else [])
    else:
        new_block_caches = ys
        counts = []
    new_caches = {"blocks": new_block_caches}
    if cfg.remainder:
        new_caches["remainder"] = []
        for i, spec in enumerate(cfg.remainder):
            x, nc, c = _layer_decode(spec, params["remainder"][i],
                                     caches["remainder"][i], x, pos, cfg,
                                     shard, expert_stats=expert_stats,
                                     write_mask=write_mask)
            new_caches["remainder"].append(nc)
            if c is not None:
                counts.append(c[None])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if expert_stats:
        stats = (jnp.concatenate(counts, axis=0) if counts
                 else jnp.zeros((0, max(cfg.resolved_padded_experts, 1)),
                                jnp.int32))
        return logits, new_caches, stats
    return logits, new_caches


def forward_serve_chunk(params, caches, tokens, start, pos, lengths, adv,
                        cfg: ModelConfig, *, shard=None, unroll=False,
                        expert_stats=False):
    """Fused serving macro-step: ``C`` engine ticks in ONE compiled call
    (a ``lax.scan`` of masked greedy decode micro-steps), advancing
    every batch slot one position per micro-step — prefilling slots
    consume prompt tokens while decoding slots keep generating
    autoregressively, so a long prompt is chunked through without ever
    stalling in-flight decode, and the per-call Python/dispatch overhead
    amortizes over the whole chunk.

    tokens: (B, C) int32 — slot b's next prompt tokens, left-aligned and
    zero-padded past ``lengths[b]``; start: (B,) int32 — the last token
    slot b generated (fed at the first micro-step past its prompt; 0 if
    none); pos: (B,) int32 — slot b's absolute position at micro-step 0;
    lengths: (B,) int32 in [0, C] — how many prompt columns slot b
    consumes; adv: (B,) int32 in [0, C] — how many micro-steps slot b
    advances at all (its cache writes are masked from step ``adv[b]``
    on; 0 = idle slot, pure padding).

    Micro-step t feeds ``tokens[:, t]`` where ``t < lengths``, else each
    slot's previous greedy output (carried across the scan, seeded from
    ``start``) — so a slot whose prompt ends inside the chunk hands off
    to generation mid-scan with no host round-trip.

    Returns ``(out_tokens (C, B), new_caches[, stats])``:
    ``out_tokens[t, b]`` is slot b's greedy next token after micro-step
    t — a generated token iff the slot was at or past its prompt
    boundary there (the host emits exactly those).  ``stats`` (with
    ``expert_stats``) sums the per-MoE-layer routed-token counts over
    the chunk's micro-steps."""
    B, C = tokens.shape
    pos = jnp.asarray(pos, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    adv = jnp.asarray(adv, jnp.int32)

    def micro(carry, xt):
        caches, cur = carry
        tok, t = xt                              # (B,), scalar step index
        feed = jnp.where(t < lengths, tok, cur)
        out = forward_decode(params, caches, feed[:, None], pos + t, cfg,
                             shard=shard, unroll=unroll,
                             expert_stats=expert_stats,
                             write_mask=t < adv)
        if expert_stats:
            logits, caches, stats = out
        else:
            (logits, caches), stats = out, None
        nxt = logits[:, -1].argmax(axis=-1).astype(jnp.int32)
        return (caches, nxt), (nxt, stats)

    (caches, _), (outs, stats) = jax.lax.scan(
        micro, (caches, jnp.asarray(start, jnp.int32)),
        (tokens.T, jnp.arange(C)))
    if expert_stats:
        return outs, caches, stats.sum(axis=0)
    return outs, caches


def lm_loss(logits, labels, mask=None):
    """Cross-entropy; labels: (B, S) int32, positions with label < 0 are
    ignored (e.g. the VLM image-prefix region).

    Written vocab-sharding-friendly: logsumexp + one-hot contraction both
    reduce over the (model-sharded) vocab axis via psum — no all-gather of
    the logits, no full-vocab gather."""
    valid = labels >= 0 if mask is None else mask & (labels >= 0)
    labels = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (jnp.arange(logits.shape[-1])[None, None, :] ==
              labels[..., None])
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ll = picked - lse
    denom = jnp.maximum(valid.sum(), 1)
    return -(ll * valid).sum() / denom
