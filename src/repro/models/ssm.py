"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) layer.

Training/prefill uses the chunked dual form: within-chunk quadratic
(attention-like) term + inter-chunk recurrence on the (H, P, N) state,
scanned over chunks with ``lax.scan``.  Decode is a single-token state
update with O(1) memory — this is why mamba2 is a ``long_500k`` arch.

The within-chunk dual form is the Pallas ``ssd_scan`` kernel target; the
jnp code here is its oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.builder import Leaf
from repro.models.layers import rmsnorm


def ssm_decl(cfg) -> dict:
    d, inner, N, H = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_width
    return {
        "wz": Leaf((d, inner), ("embed", "ssm_inner")),
        "wx": Leaf((d, inner), ("embed", "ssm_inner")),
        "wB": Leaf((d, N), ("embed", "state")),
        "wC": Leaf((d, N), ("embed", "state")),
        "wdt": Leaf((d, H), ("embed", "ssm_heads")),
        "conv_x": Leaf((w, inner), ("conv", "ssm_inner"), scale=0.5),
        "conv_B": Leaf((w, N), ("conv", "state"), scale=0.5),
        "conv_C": Leaf((w, N), ("conv", "state"), scale=0.5),
        "A_log": Leaf((H,), ("ssm_heads",), "zeros"),
        "D": Leaf((H,), ("ssm_heads",), "ones"),
        "dt_bias": Leaf((H,), ("ssm_heads",), "zeros"),
        "norm": Leaf((inner,), ("ssm_inner",), "zeros"),
        "out_proj": Leaf((inner, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out


def ssd_chunked(x, dt, A, Bmat, Cmat, state0, chunk):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) (negative);
    Bmat, Cmat: (B, S, N) (single group, shared across heads);
    state0: (B, H, P, N).  Returns (y (B,S,H,P), state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    nchunks = S // chunk
    da = dt * A  # (B, S, H), negative

    xc = x.reshape(Bsz, nchunks, chunk, H, P)
    dtc = dt.reshape(Bsz, nchunks, chunk, H)
    dac = da.reshape(Bsz, nchunks, chunk, H)
    Bc = Bmat.reshape(Bsz, nchunks, chunk, N)
    Cc = Cmat.reshape(Bsz, nchunks, chunk, N)

    @jax.checkpoint  # recompute the within-chunk dual form in backward
    def step(state, ci):
        xq, dtq, daq, Bq, Cq = (xc[:, ci], dtc[:, ci], dac[:, ci],
                                Bc[:, ci], Cc[:, ci])
        cum = jnp.cumsum(daq, axis=1)  # (B, Q, H)
        # intra-chunk (dual / attention-like) term; mask BEFORE exp —
        # above-diagonal seg is positive and overflows, and the vjp of
        # where(mask, exp(inf), 0) is inf * 0 = NaN
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B, Q, Q, H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        L = jnp.where(causal, jnp.exp(jnp.where(causal, seg, 0.0)), 0.0)
        scores = jnp.einsum("bin,bjn->bij", Cq, Bq)[..., None] * L \
            * dtq[:, None, :, :]  # (B, Q, Q, H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, xq)
        # inter-chunk term from carried state
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "bin,bhpn->bihp", Cq, state)
        # state update
        total = cum[:, -1:, :]  # (B, 1, H)
        w = jnp.exp(total - cum) * dtq  # (B, Q, H)
        ds = jnp.einsum("bqh,bqhp,bqn->bhpn", w, xq, Bq)
        state = jnp.exp(total[:, 0])[:, :, None, None] * state + ds
        return state, y_intra + y_inter

    state, ys = jax.lax.scan(step, state0, jnp.arange(nchunks))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y, state


def ssm_train(params, x, cfg, shard=None):
    """x: (B, S, d) -> (B, S, d). Full-sequence (train/prefill) path."""
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ params["wz"]
    xin = _causal_conv(x @ params["wx"], params["conv_x"])
    Bmat = _causal_conv(x @ params["wB"], params["conv_B"])
    Cmat = _causal_conv(x @ params["wC"], params["conv_C"])
    xin = jax.nn.silu(xin)
    Bmat, Cmat = jax.nn.silu(Bmat), jax.nn.silu(Cmat)
    dt = jax.nn.softplus(x @ params["wdt"] + params["dt_bias"])  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    if shard is not None:
        xin = shard(xin, "batch", "seq", "ssm_inner")
        z = shard(z, "batch", "seq", "ssm_inner")
    xh = xin.reshape(B, S, H, P)
    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    y, _ = ssd_chunked(xh.astype(jnp.float32), dt.astype(jnp.float32), A,
                       Bmat.astype(jnp.float32), Cmat.astype(jnp.float32),
                       state0, min(cfg.ssm_chunk, S))
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, H * P).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return y @ params["out_proj"]


def ssm_decode(params, x, cache, cfg, shard=None):
    """One-token decode. x: (B, 1, d).
    cache = {"state": (B,H,P,N) f32, "conv": (B, W-1, inner+2N)}.
    Returns (out (B,1,d), new_cache)."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xt = x[:, 0]
    z = xt @ params["wz"]
    pre = jnp.concatenate([xt @ params["wx"], xt @ params["wB"],
                           xt @ params["wC"]], axis=-1)  # (B, inner+2N)
    hist = jnp.concatenate([cache["conv"], pre[:, None]], axis=1)  # (B,W,·)
    wfull = jnp.concatenate([params["conv_x"], params["conv_B"],
                             params["conv_C"]], axis=-1)  # (W, inner+2N)
    conv_out = (hist * wfull[None]).sum(axis=1)
    inner = cfg.ssm_inner
    xin = jax.nn.silu(conv_out[:, :inner])
    Bmat = jax.nn.silu(conv_out[:, inner:inner + N])
    Cmat = jax.nn.silu(conv_out[:, inner + N:])
    dt = jax.nn.softplus(xt @ params["wdt"] + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A)  # (B,H)
    xh = xin.reshape(B, H, P).astype(jnp.float32)
    state = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt.astype(jnp.float32), xh,
        Bmat.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cmat.astype(jnp.float32), state)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, H * P).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None]
    new_cache = {"state": state, "conv": hist[:, 1:]}
    return out, new_cache
