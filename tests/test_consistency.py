"""Cross-path consistency: decode-with-cache must reproduce the training
forward, layer primitives must match naive references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.builder import materialize
from repro.models.layers import blockwise_attention
from repro.models.transformer import cache_decl, forward_decode, forward_train, model_decl
from repro.kernels import ref


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma3-27b",
                                  "recurrentgemma-2b", "mamba2-2.7b",
                                  "qwen2-moe-a2.7b"])
def test_decode_matches_train_forward(arch):
    """Teacher-forced decode over a prompt gives the same logits as the
    full training forward (validates cache semantics, rope positions,
    ring buffers, SSM state updates)."""
    import dataclasses
    cfg = get_config(arch, smoke=True)
    if cfg.num_experts:
        # capacity drops only exist on the (multi-token) train path;
        # disable them for exact train/decode equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = materialize(model_decl(cfg), key)
    S = 48
    toks = jax.random.randint(key, (1, S), 0, cfg.vocab_size)
    full_logits, _ = forward_train(params, toks, cfg, remat=False,
                                   q_chunk=16, kv_chunk=16)
    caches = materialize(cache_decl(cfg, 1, S), key)
    step = jax.jit(lambda c, t, p: forward_decode(params, c, t, p, cfg))
    outs = []
    for t in range(S):
        logits, caches = step(caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3,
                               atol=2e-3)


def test_blockwise_attention_matches_ref():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, 128, 4, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 2, 32))
    for causal, window in [(True, 0), (True, 32), (False, 0)]:
        got = blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_chunk=32, kv_chunk=32)
        want = ref.attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"causal={causal} w={window}")


def test_ssd_chunked_matches_sequential():
    key = jax.random.PRNGKey(2)
    B, S, H, P, N = 2, 128, 3, 16, 8
    x = jax.random.normal(key, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H))) * 0.1
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (H,))) - 0.1
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N)) * 0.5
    state0 = jnp.zeros((B, H, P, N))
    y_chunk, s_chunk = ssm_lib.ssd_chunked(x, dt, A, Bm, Cm, state0, 32)
    y_ref, s_ref = ref.ssd_scan_ref(x, dt, A, Bm, Cm, state0)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_loop():
    key = jax.random.PRNGKey(3)
    B, S, C = 2, 64, 16
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, C)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, C))
    h = rglru_lib.rglru_scan(a, b)
    ht = jnp.zeros((B, C))
    hs = []
    for t in range(S):
        ht = a[:, t] * ht + b[:, t]
        hs.append(ht)
    np.testing.assert_allclose(np.asarray(h),
                               np.asarray(jnp.stack(hs, 1)),
                               rtol=1e-5, atol=1e-5)


def test_moe_layer_matches_dense_expert_eval():
    """Grouped-dispatch MoE output == direct per-token expert evaluation
    when capacity is not exceeded."""
    import dataclasses
    from repro.models import moe as moe_lib
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    key = jax.random.PRNGKey(4)
    params = materialize(moe_lib.moe_decl(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y, aux = moe_lib.moe_mlp(params, x, cfg)
    # direct: every expert on every token, weighted by renormalized top-k
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    w = w / w.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"])) * \
        jnp.einsum("bsd,edf->bsef", x, params["w_up"])
    all_out = jnp.einsum("bsef,efd->bsed", h, params["w_down"])
    picked = jnp.take_along_axis(all_out, idx[..., None], axis=2)
    want = (picked * w[..., None]).sum(axis=2)
    sp = params["shared"]
    want = want + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With tiny capacity, overflow tokens are dropped (output contribution
    zero), never mis-routed."""
    import dataclasses
    from repro.models import moe as moe_lib
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.05)
    key = jax.random.PRNGKey(5)
    params = materialize(moe_lib.moe_decl(cfg), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, cfg.d_model))
    y, aux = moe_lib.moe_mlp(params, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
