"""Federated training with verified aggregation (repro.fed).

Covers the round lifecycle end to end: clean rounds commit, audit and
finalize; poisoned updates are screened by the defended rule (vs the
undefended FedAvg baseline); a dishonest aggregator is convicted by
recompute-court, slashed, and rolled back with the honest lineage
replayed bit-for-bit; stragglers carry/evict without stalling the round
clock; quorum failures are committed no-ops; and the whole pipeline is
deterministic across identically-seeded runs.
"""
import jax
import numpy as np
import pytest

from repro.data.synthetic import FMNIST, make_image_dataset
from repro.fed import FedAttack, FedConfig, FedCoordinator
from repro.trust.protocol import RoundPhase, TrustConfig


@pytest.fixture(scope="module")
def data():
    return make_image_dataset(FMNIST, n_train=1500, n_test=400, seed=0)


def _cfg(**kw):
    base = dict(num_edges=6, num_experts=6, hidden=16, local_steps=3,
                local_batch=32, seed=0,
                trust=TrustConfig(chunks_per_expert=4, audit_rate=1.0,
                                  challenge_window=2))
    base.update(kw)
    return FedConfig(**base)


def _run(cfg, data, rounds=4):
    x, y, xt, yt = data
    co = FedCoordinator(cfg, x, y)
    for _ in range(rounds):
        co.run_round()
    co.flush_trust()
    return co


def _params_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        if not np.array_equal(np.asarray(la), np.asarray(lb)):
            return False
    return True


# --------------------------------------------------------- clean rounds
def test_clean_rounds_commit_audit_finalize(data):
    co = _run(_cfg(), data, rounds=4)
    p = co.protocol
    assert all(p.rounds[r].phase is RoundPhase.FINALIZED for r in range(4))
    assert p.stats["fraud_proofs"] == 0
    assert co.evaluate(data[2], data[3]) > 0.6
    # every round mined one fed_round block binding the aggregation root
    aggs = co.ledger.aggregations()
    assert len(aggs) == 4
    assert all(b.payload["agg_root"] for b in aggs)
    assert co.ledger.verify_chain()


def test_fed_counters_visible_in_obs_report(data):
    co = _run(_cfg(straggler_prob=0.2, dropout_prob=0.1, seed=3),
              data, rounds=5)
    rep = co.obs_report()
    for key in ("stragglers", "dropouts", "retries", "evictions",
                "quorum_failures", "rejected_updates"):
        assert key in rep["fed"]
        assert f"fed.{key}" in rep["metrics"]
    assert rep["fed"]["rounds"] == 5
    assert rep["chain"]["valid"]


def test_delta_uploads_dedup_across_edges(data):
    """Masked deltas are zero off each edge's expert subset — those
    chunks are identical across edges and dedup away in the store."""
    co = _run(_cfg(), data, rounds=2)
    assert co.store.stats["chunks_deduped"] > 0


# ------------------------------------------------------- update poisons
def test_defended_rule_survives_gradient_scaling(data):
    atk = FedAttack(malicious_edges=(2,), update_attack="grad_scale",
                    scale=200.0)
    clean = _run(_cfg(verify="off"), data)
    undef = _run(_cfg(verify="off", rule="fedavg", attack=atk), data)
    defended = _run(_cfg(verify="off", attack=atk), data)
    x, y = data[2], data[3]
    acc_clean, acc_undef = clean.evaluate(x, y), undef.evaluate(x, y)
    acc_def = defended.evaluate(x, y)
    # the gate the bench enforces: defended within 10% of clean while
    # undefended FedAvg degrades more
    assert acc_def >= 0.9 * acc_clean
    assert acc_undef < acc_def


def test_sign_flip_is_screened_by_cosine_test(data):
    atk = FedAttack(malicious_edges=(2,), update_attack="sign_flip",
                    scale=5.0)
    defended = _run(_cfg(verify="off", attack=atk), data)
    undef = _run(_cfg(verify="off", rule="fedavg", attack=atk), data)
    assert defended.obs_report()["fed"]["rejected_updates"] > 0
    x, y = data[2], data[3]
    assert defended.evaluate(x, y) > undef.evaluate(x, y)


# -------------------------------------------------- dishonest aggregator
def test_dishonest_aggregator_convicted_and_rolled_back(data):
    atk = FedAttack(malicious_edges=(1,), dishonest_aggregator=True)
    clean = _run(_cfg(), data, rounds=5)
    bad = _run(_cfg(attack=atk), data, rounds=5)
    rep = bad.obs_report()
    assert rep["fed"]["convictions"] >= 1
    assert rep["trust"]["rolled_back"] >= 1
    # fraud proof -> slash -> rollback block on the chain
    rbs = bad.ledger.rollbacks()
    assert len(rbs) >= 1
    assert rbs[0].payload["domain"] == "fed"
    assert 1 in rbs[0].payload["slashed"]
    assert bad.ledger.slashes()
    assert bad.protocol.stakes.stake[1] < bad.protocol.stakes.stake[0]
    # the honest replay restores the clean lineage bit-for-bit
    assert rep["fed"]["replayed_rounds"] >= 1
    assert _params_equal(clean.global_params, bad.global_params)


def test_colluding_aggregator_skipping_screen_is_convicted(data):
    """The aggregator commits plain FedAvg (no clip/screen) so its
    accomplice's poison lands — the committed rule is `defended`, so
    auditors' recompute diverges and the fraud proof fires."""
    atk = FedAttack(malicious_edges=(1, 2), update_attack="sign_flip",
                    scale=5.0, dishonest_aggregator=True,
                    aggregator_mode="unscreened")
    bad = _run(_cfg(attack=atk), data, rounds=5)
    rep = bad.obs_report()
    assert rep["fed"]["convictions"] >= 1
    assert len(bad.ledger.rollbacks()) >= 1


# ------------------------------------------------- stragglers / dropouts
def test_straggler_carry_then_evict_never_stalls(data):
    cfg = _cfg(slow_edges=(0,), evict_after=2, verify="off")
    co = _run(cfg, data, rounds=4)
    rep = co.obs_report()
    assert rep["fed"]["rounds"] == 4          # the clock never waited
    assert rep["fed"]["stragglers"] >= 2
    assert rep["fed"]["carried_deltas"] >= 1  # first late delta carried
    assert rep["fed"]["evictions"] == 1
    assert 0 in co._evicted
    # edge 0's carried delta landed in a later round's received set
    landed = [b for b in co.ledger.aggregations()
              if 0 in b.payload["received"]]
    assert landed


def test_quorum_failure_is_a_committed_noop(data):
    cfg = _cfg(slow_edges=tuple(range(6)), evict_after=100,
               verify="off")                  # everyone straggles
    x, y, *_ = data
    co = FedCoordinator(cfg, x, y)
    before = jax.tree_util.tree_map(np.asarray, co.global_params)
    s = co.run_round()
    assert not s["quorum"]
    assert _params_equal(before, co.global_params)
    blocks = co.ledger.aggregations()
    assert len(blocks) == 1 and blocks[0].payload["quorum"] is False
    assert co.obs_report()["fed"]["quorum_failures"] == 1
    # the round clock advanced regardless
    assert co.round == 1


def test_rounds_complete_under_combined_faults(data):
    """ISSUE acceptance: 20% stragglers + 10% dropouts, rounds complete
    without stalling and the counters are visible."""
    cfg = _cfg(straggler_prob=0.2, dropout_prob=0.1, seed=5)
    co = _run(cfg, data, rounds=6)
    rep = co.obs_report()
    assert rep["fed"]["rounds"] == 6
    assert rep["fed"]["stragglers"] > 0
    assert rep["fed"]["dropouts"] > 0
    assert co.ledger.verify_chain()


# --------------------------------------------------------- determinism
def test_two_seeded_runs_bit_identical(data):
    cfg = _cfg(straggler_prob=0.2, dropout_prob=0.1, seed=11)
    a = _run(cfg, data, rounds=3)
    b = _run(cfg, data, rounds=3)
    assert _params_equal(a.global_params, b.global_params)
    ra = [blk.payload.get("agg_root") for blk in a.ledger.aggregations()]
    rb = [blk.payload.get("agg_root") for blk in b.ledger.aggregations()]
    assert ra == rb
    assert a.obs_report()["fed"] == b.obs_report()["fed"]
