"""Multi-device tests (subprocess with virtual CPU devices): sharding
rules, trusted-MoE consensus under attack, small-mesh lower/compile, and
the hloanalysis loop correction."""
from conftest import run_with_devices


def test_trusted_moe_vote_recovers_under_attack(repo_src):
    """r=4 replicas, 1 malicious: faithful AND digest modes reproduce the
    clean expert outputs bit-for-bit; 3 colluding replicas win instead."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.trusted_moe import make_trust, LMAttack
        from repro.models.config import RedundancyConfig
        mesh = jax.make_mesh((1, 4, 2), ("data", "replica", "model"))
        y = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
        for mode in ("faithful", "digest"):
            clean = make_trust(mesh, RedundancyConfig(4, mode), True, None)
            atk = make_trust(mesh, RedundancyConfig(4, mode), True,
                             LMAttack(malicious_replicas=(1,), noise_std=3.0))
            maj = make_trust(mesh, RedundancyConfig(4, mode), True,
                             LMAttack(malicious_replicas=(0, 1, 2),
                                      noise_std=3.0))
            with mesh:
                got_clean = jax.jit(clean)(y)
                got_atk = jax.jit(atk)(y)
                got_maj = jax.jit(maj)(y)
            np.testing.assert_allclose(np.asarray(got_clean),
                                       np.asarray(y), rtol=0, atol=1e-6)
            np.testing.assert_allclose(np.asarray(got_atk),
                                       np.asarray(y), rtol=0, atol=1e-6)
            assert not np.allclose(np.asarray(got_maj), np.asarray(y)), mode
            print(mode, "OK")
    """, 8, repo_src)
    assert "faithful OK" in out and "digest OK" in out


def test_trusted_train_step_end_to_end(repo_src):
    """A trusted MoE train step on a (1, 2, 2) mesh runs under attack and
    produces finite loss equal to the attack-free loss (vote repairs)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.core.trusted_moe import LMAttack
        from repro.models.config import RedundancyConfig
        from repro.optim import adamw
        from repro.train.loop import init_model
        from repro.train.step import make_train_step
        cfg = get_config("bmoe-paper", smoke=True)
        cfg = dataclasses.replace(cfg,
            redundancy=RedundancyConfig(2, "faithful"), train_microbatches=1)
        mesh = jax.make_mesh((1, 2, 2), ("data", "replica", "model"))
        params = init_model(cfg, seed=0)
        opt = adamw.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(0), (4, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        losses = {}
        for name, atk in [("clean", None),
                          ("attacked", LMAttack(malicious_replicas=(1,),
                                                noise_std=5.0))]:
            step = make_train_step(cfg, adamw.AdamWConfig(total_steps=10),
                                   mesh, attack=atk, remat=False)
            with mesh:
                _, _, m = jax.jit(step)(params, opt, batch)
            losses[name] = float(m["loss"])
        assert np.isfinite(losses["clean"])
        assert abs(losses["clean"] - losses["attacked"]) < 1e-3, losses
        print("TRUSTED TRAIN OK", losses)
    """, 4, repo_src)
    assert "TRUSTED TRAIN OK" in out


def test_small_mesh_train_and_decode_compile(repo_src):
    """The production step functions lower+compile on a small (2, 4) mesh
    with real (materialized) params — an executable mini dry-run."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch import shapes as shp
        from repro.models.builder import materialize, partition_specs
        from repro.optim import adamw
        from repro.sharding import logical_rules
        from repro.train.loop import init_model
        from repro.train.step import make_step
        import dataclasses
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("qwen2-moe-a2.7b", "mamba2-2.7b", "gemma3-27b"):
            cfg = get_config(arch, smoke=True)
            cfg = dataclasses.replace(cfg, train_microbatches=1)
            params = init_model(cfg, seed=0)
            toks = jax.random.randint(jax.random.PRNGKey(0), (4, 64), 0,
                                      cfg.vocab_size)
            step = make_step(cfg, "train", mesh,
                             opt_cfg=adamw.AdamWConfig(total_steps=5),
                             remat=False)
            opt = adamw.init(params)
            with mesh:
                _, _, m = jax.jit(step)(params, opt,
                                        {"tokens": toks, "labels": toks})
            assert np.isfinite(float(m["loss"])), arch
            print(arch, "mesh-train OK", float(m["loss"]))
    """, 8, repo_src)
    assert out.count("mesh-train OK") == 3


def test_hloanalysis_loop_correction(repo_src):
    """Scan vs unrolled compile of the same model: loop-corrected
    collective bytes and dot flops from the scanned HLO must match the
    unrolled ground truth within 2%."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch import hloanalysis
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        W = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        X = jax.ShapeDtypeStruct((16, 128), jnp.float32)
        ws = NamedSharding(mesh, P(None, None, "model"))
        xs = NamedSharding(mesh, P("data", None))
        def scanned(x, w):
            def body(c, wi):
                y = c @ wi
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P("data", None)))
                return y, None
            y, _ = jax.lax.scan(body, x, w)
            return y
        def unrolled(x, w):
            for i in range(6):
                y = x @ w[i]
                x = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, P("data", None)))
            return x
        with mesh:
            t1 = jax.jit(scanned, in_shardings=(xs, ws)).lower(X, W).compile().as_text()
            t2 = jax.jit(unrolled, in_shardings=(xs, ws)).lower(X, W).compile().as_text()
        a1 = hloanalysis.analyze(t1)
        a2 = hloanalysis.analyze(t2)
        assert a2["dot_flops"] > 0
        rel = abs(a1["dot_flops"] - a2["dot_flops"]) / a2["dot_flops"]
        assert rel < 0.02, (a1["dot_flops"], a2["dot_flops"])
        c1, c2 = a1["total_collective_bytes"], a2["total_collective_bytes"]
        assert c2 > 0 and abs(c1 - c2) / c2 < 0.02, (c1, c2)
        print("HLO LOOP CORRECTION OK", a1["dot_flops"], c1)
    """, 8, repo_src)
    assert "HLO LOOP CORRECTION OK" in out


def test_fsdp_param_rules(repo_src):
    out = run_with_devices("""
        import jax
        from repro.configs import get_config
        from repro.sharding import logical_rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("qwen3-32b")
        act = logical_rules(mesh, cfg)
        par = logical_rules(mesh, cfg, params=True)
        assert act["embed"] is None
        assert par["embed"] == ("data",)
        assert par["vocab"] == "model"
        print("RULES OK")
    """, 8, repo_src)
    assert "RULES OK" in out


def test_moe_ep_matches_gspmd_path(repo_src):
    """shard_map expert-parallel MoE (all_to_all dispatch) must agree with
    the single-device GSPMD oracle when capacity is ample."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep")
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 32, cfg.d_model))
        y_ref, aux_ref = moe_lib.moe_mlp(params, x, cfg)   # no mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = logical_rules(mesh, cfg)
        with mesh:
            y_ep, aux_ep = jax.jit(lambda p, x: moe_mlp_ep(
                p, x, cfg, mesh, rules, fsdp=False))(params, x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                   rtol=3e-3, atol=3e-3)
        assert abs(float(aux_ep) - float(aux_ref)) < 1e-3
        print("EP MATCHES GSPMD")
    """, 8, repo_src)
    assert "EP MATCHES GSPMD" in out


def test_moe_ep_trusted_vote(repo_src):
    """EP + B-MoE consensus: a malicious replica's manipulation of the
    expert outputs is repaired inside the EP shard_map."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.core.trusted_moe import LMAttack
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.models.config import RedundancyConfig
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep")
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 16, cfg.d_model))
        mesh = jax.make_mesh((1, 2, 4), ("data", "replica", "model"))
        rules = logical_rules(mesh, cfg)
        for mode in ("faithful", "digest"):
            tcfg = dataclasses.replace(
                cfg, redundancy=RedundancyConfig(2, mode))
            with mesh:
                clean, _ = jax.jit(lambda p, x: moe_mlp_ep(
                    p, x, tcfg, mesh, rules, fsdp=False))(params, x)
                attacked, _ = jax.jit(lambda p, x: moe_mlp_ep(
                    p, x, tcfg, mesh, rules, fsdp=False,
                    attack=LMAttack(malicious_replicas=(1,),
                                    noise_std=4.0)))(params, x)
            np.testing.assert_allclose(np.asarray(attacked),
                                       np.asarray(clean), rtol=1e-5,
                                       atol=1e-5)
            print(mode, "EP VOTE OK")
    """, 8, repo_src)
    assert out.count("EP VOTE OK") == 2
