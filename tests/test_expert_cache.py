"""Edge-cache accounting: LRU order under a byte budget, pinning,
exact hit/miss/byte counters, EMA-driven prefetch, and cache-on vs
cache-off bit-identity of the B-MoE system and the serving engine."""
import dataclasses

import jax
import numpy as np

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.core.ledger import digest_tree
from repro.storage import ExpertCache, ExpertStore, GateEMA, StorageNetwork
from repro.trust.protocol import TrustConfig


def _populated_store(num_objects=4, leaf=256, chunk_bytes=256, seed=0):
    """Objects "o0".."oN" of identical known size (leaf float32 values ->
    4*leaf payload bytes each)."""
    net = StorageNetwork(num_nodes=4, replication=2, seed=seed)
    store = ExpertStore(net, chunk_bytes=chunk_bytes)
    rng = np.random.default_rng(seed)
    trees = {}
    for i in range(num_objects):
        t = {"w": rng.normal(size=leaf).astype(np.float32)}
        trees[f"o{i}"] = t
        store.put_version(f"o{i}", t, 0)
    return net, store, trees


def test_lru_eviction_order_under_byte_budget():
    net, store, trees = _populated_store(num_objects=4, leaf=256)
    nbytes = 4 * 256
    cache = ExpertCache(store, budget_bytes=2 * nbytes)   # room for two
    like = trees["o0"]
    cache.get("o0", 0, like)
    cache.get("o1", 0, like)
    cache.get("o2", 0, like)          # evicts o0 (least recent)
    assert "o0" not in cache and "o1" in cache and "o2" in cache
    cache.get("o1", 0, like)          # refresh o1's recency
    cache.get("o3", 0, like)          # now o2 is LRU -> evicted
    assert "o2" not in cache and "o1" in cache and "o3" in cache
    assert cache.stats["evictions"] == 2
    assert cache.stats["evicted_bytes"] == 2 * nbytes


def test_pinned_entries_never_evicted():
    net, store, trees = _populated_store(num_objects=4, leaf=256)
    nbytes = 4 * 256
    cache = ExpertCache(store, budget_bytes=2 * nbytes)
    like = trees["o0"]
    cache.get("o0", 0, like)
    cache.pin(["o0"])                  # activated: must survive
    cache.get("o1", 0, like)
    cache.get("o2", 0, like)           # would evict o0 -> evicts o1
    cache.get("o3", 0, like)           # evicts o2
    assert "o0" in cache
    assert cache.stats["evictions"] == 2
    cache.unpin(["o0"])
    cache.get("o1", 0, like)           # now o0 is evictable again
    assert "o0" not in cache


def test_counters_exact_under_seeded_access_trace():
    net, store, trees = _populated_store(num_objects=5, leaf=128)
    nbytes = 4 * 128
    cache = ExpertCache(store, budget_bytes=3 * nbytes)
    like = trees["o0"]
    rng = np.random.default_rng(42)
    trace = [int(i) for i in rng.integers(0, 5, 60)]
    # shadow simulation of the exact LRU discipline
    resident, hits, misses, evicts = [], 0, 0, 0
    for i in trace:
        oid = f"o{i}"
        cache.get(oid, 0, like)
        if oid in resident:
            hits += 1
            resident.remove(oid)
            resident.append(oid)
        else:
            misses += 1
            resident.append(oid)
            if len(resident) > 3:
                resident.pop(0)
                evicts += 1
    assert cache.stats["hits"] == hits
    assert cache.stats["misses"] == misses
    assert cache.stats["evictions"] == evicts
    assert cache.stats["fetched_bytes"] == misses * nbytes
    assert cache.stats["evicted_bytes"] == evicts * nbytes
    assert cache.resident_bytes == len(resident) * nbytes


def test_prefetch_warms_top_ema_within_budget():
    net, store, trees = _populated_store(num_objects=6, leaf=256)
    nbytes = 4 * 256
    like = trees["o0"]
    ema = GateEMA(6, decay=0.5)
    ema.update([0, 10, 1, 7, 0, 2])
    ema.update([0, 8, 2, 9, 0, 1])
    ranking = ema.ranking()
    assert ranking[:2] in ([1, 3], [3, 1])
    cache = ExpertCache(store, budget_bytes=3 * nbytes)
    fetched = cache.prefetch([f"o{e}" for e in ranking], 0, lambda _: like)
    # exactly the top three hottest fit the budget, in ranking order
    assert fetched == [f"o{e}" for e in ranking[:3]]
    assert cache.stats["prefetches"] == 3
    assert cache.resident_bytes == 3 * nbytes
    # prefetch never evicts: a second pass adds nothing (budget full)
    assert cache.prefetch([f"o{e}" for e in ranking], 0,
                          lambda _: like) == []
    assert cache.stats["evictions"] == 0


def test_prefetched_entries_hit_on_access():
    net, store, trees = _populated_store(num_objects=3, leaf=64)
    cache = ExpertCache(store, budget_bytes=None)
    like = trees["o0"]
    cache.prefetch(["o1"], 0, lambda _: like)
    cache.get("o1", 0, like)
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 0


def test_stale_version_is_a_miss_and_refetches():
    net, store, trees = _populated_store(num_objects=1, leaf=64)
    cache = ExpertCache(store, budget_bytes=None)
    like = trees["o0"]
    cache.get("o0", 0, like)
    t1 = {"w": trees["o0"]["w"] + 1.0}
    store.put_version("o0", t1, 1)
    back = cache.get("o0", 1, like)           # stale -> miss -> refetch
    np.testing.assert_array_equal(back["w"], t1["w"])
    assert cache.stats["misses"] == 2 and cache.stats["hits"] == 0


# ----------------------------------------------------- system identity
def _data(seed=0, n=400):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, 784)).astype(np.float32),
            rng.integers(0, 10, n))


def _run_system(edge_cache, attack=AttackConfig(), rounds=5, seed=0,
                **overrides):
    cfg = BMoEConfig(num_experts=6, num_edges=6, top_k=2,
                     framework="optimistic", pow_difficulty=2, seed=seed,
                     attack=attack, edge_cache=edge_cache,
                     trust=TrustConfig(audit_rate=0.3, challenge_window=2),
                     **overrides)
    s = BMoESystem(cfg)
    x, y = _data()
    rng = np.random.default_rng(1)
    for _ in range(rounds):
        idx = rng.integers(0, len(x), 48)
        s.train_round(x[idx], y[idx])
    s.flush_trust()
    return s


def test_cache_on_off_bit_identical_training_and_inference():
    """The whole point of the resolution path: fetching the bank through
    the chunk store + cache changes nothing — states, audit verdicts and
    inference outputs are bit-identical to the resident-bank oracle."""
    a = _run_system("on")
    b = _run_system("off")
    assert digest_tree(a.experts) == digest_tree(b.experts)
    assert digest_tree(a.gate) == digest_tree(b.gate)
    x, _ = _data(3, 64)
    la, _, _ = a.infer(x, commit=False)
    lb, _, _ = b.infer(x, commit=False)
    np.testing.assert_array_equal(la, lb)
    assert a.edge_cache is not None and b.edge_cache is None


def test_cache_on_off_bit_identical_under_attack_with_rollback():
    atk = AttackConfig(malicious_edges=(3,), attack_prob=1.0, noise_std=5.0)
    a = _run_system("on", attack=atk, rounds=7)
    b = _run_system("off", attack=atk, rounds=7)
    assert digest_tree(a.experts) == digest_tree(b.experts)
    assert [(e.round_id, e.edge) for e in a.protocol.stakes.events] == \
        [(e.round_id, e.edge) for e in b.protocol.stakes.events]
    assert len(a.ledger.rollbacks()) == len(b.ledger.rollbacks()) > 0


def test_tight_budget_thrashes_but_stays_correct():
    """A byte budget below the bank size forces evict/refetch traffic —
    and changes nothing about what is computed."""
    bank_bytes = None
    a = _run_system("on", rounds=4, seed=2)
    bank_bytes = sum(a.expert_store.object_bytes(f"expert/{e}")
                     for e in range(6))
    tight = _run_system("on", rounds=4, seed=2,
                        edge_cache_bytes=bank_bytes // 2)
    b = _run_system("off", rounds=4, seed=2)
    assert digest_tree(tight.experts) == digest_tree(b.experts)
    assert tight.edge_cache.stats["evictions"] > 0
    # the thrash shows on warm accesses: repeated inference against the
    # frozen bank refetches what the budget evicted, while the
    # unbounded cache serves everything from residency
    x, _ = _data(6, 64)
    for s in (a, tight):
        s.infer(x, commit=False)
    base_a = a.edge_cache.stats["fetched_bytes"]
    base_t = tight.edge_cache.stats["fetched_bytes"]
    for s in (a, tight):
        s.infer(x, commit=False)
    assert a.edge_cache.stats["fetched_bytes"] == base_a
    assert tight.edge_cache.stats["fetched_bytes"] > base_t


def test_unrouted_experts_receive_zero_gradient():
    """The dedup-upload premise: an expert the batch never routed to is
    bit-identical after the round, so skipping its re-upload is sound."""
    cfg = BMoEConfig(num_experts=8, num_edges=8, top_k=2,
                     framework="traditional", pow_difficulty=2, seed=0)
    s = BMoESystem(cfg)
    x, y = _data(4, 8)
    before = jax.tree_util.tree_map(np.asarray, s.experts)
    m = s.train_round(x[:1], y[:1])           # one sample: k experts routed
    routed = set(np.nonzero(m["activation"])[0])
    assert len(routed) == 2
    after = jax.tree_util.tree_map(np.asarray, s.experts)
    for e in range(8):
        same = all(np.array_equal(np.asarray(a[e]), np.asarray(b[e]))
                   for a, b in zip(jax.tree_util.tree_leaves(before),
                                   jax.tree_util.tree_leaves(after)))
        assert same == (e not in routed), (e, routed)


def test_warm_cache_inference_fetches_nothing():
    s = _run_system("on", rounds=3)
    x, _ = _data(5, 64)
    s.infer(x, commit=False)                  # first resolve after flush
    fetched = s.edge_cache.stats["fetched_bytes"]
    hits = s.edge_cache.stats["hits"]
    s.infer(x, commit=False)
    s.infer(x, commit=False)
    assert s.edge_cache.stats["fetched_bytes"] == fetched   # all warm
    assert s.edge_cache.stats["hits"] > hits


# ----------------------------------------------------- serving engine
def test_serving_engine_cache_on_off_identical_outputs():
    from repro.configs import get_config
    from repro.data.synthetic import serving_requests
    from repro.serve.engine import EdgeStorageConfig, ServingEngine
    from repro.train.loop import init_model
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = dataclasses.replace(cfg, padded_num_experts=0)
    params = init_model(cfg, seed=0)
    reqs = list(serving_requests(cfg.vocab_size, 4, max_prompt=6,
                                 max_new=4, seed=0))
    plain = ServingEngine(cfg, params, batch_slots=2, cache_len=32)
    plain.submit(reqs)
    done_plain = plain.run()
    edged = ServingEngine(cfg, params, batch_slots=2, cache_len=32,
                          expert_storage=EdgeStorageConfig(prefetch_topk=2))
    edged.submit(reqs)
    done_edged = edged.run()
    assert done_edged == done_plain
    rep = edged.edge.report()
    # cold start fetched each unit at most once; afterwards ticks hit
    assert rep["cache"]["misses"] <= rep["units"]
    assert rep["cache"]["hits"] > 0
    assert rep["store"]["fetched_bytes"] <= \
        rep["units"] * store_unit_bytes(rep)
    assert rep["ticks"] > 0


def store_unit_bytes(rep):
    return rep["store"]["uploaded_bytes"] // max(rep["units"], 1)


def test_kv_blocks_and_experts_compete_under_one_budget():
    """KV blocks resolved through the SAME cache as expert weights:
    under a tight budget the cold KV entries are evicted first while the
    pinned (activated) expert survives — with exact counters."""
    import jax.numpy as jnp

    from repro.models.builder import materialize
    from repro.models.transformer import cache_decl, slice_kv_block
    from repro.storage import KVBlockStore, prefix_chain

    net, store, trees = _populated_store(num_objects=1, leaf=256)
    nbytes = 4 * 256                                  # one expert unit
    from repro.configs import get_config
    cfg = get_config("smollm-360m", smoke=True)
    caches = jax.tree_util.tree_map(
        jnp.asarray, materialize(cache_decl(cfg, 1, 40),
                                 jax.random.PRNGKey(0)))
    blocks = [slice_kv_block(caches, 0, b * 8, (b + 1) * 8)
              for b in range(3)]
    kv_bytes = sum(np.asarray(a).nbytes
                   for a in jax.tree_util.tree_leaves(blocks[0]))

    cache = ExpertCache(store, budget_bytes=nbytes + 2 * kv_bytes)
    kv = KVBlockStore(store, cache)
    chain = prefix_chain(np.arange(24), 8)
    for cid, block in zip(chain, blocks):
        kv.seal(cid, block, 8)
    like = slice_kv_block(caches, 0, 0, 1)
    expert = cache.get("o0", 0, trees["o0"])          # the activated expert
    np.testing.assert_array_equal(expert["w"], trees["o0"]["w"])
    cache.pin(["o0"])
    try:
        for cid in chain:                             # 3 blocks, room for 2
            kv.fetch(cid, like)
    finally:
        cache.unpin(["o0"])
    assert "o0" in cache                              # pinned: survived
    oid = KVBlockStore.object_id
    assert oid(chain[0]) not in cache                 # cold KV went first
    assert oid(chain[1]) in cache and oid(chain[2]) in cache
    assert cache.stats["evictions"] == 1
    assert cache.stats["evicted_bytes"] == kv_bytes
    assert cache.resident_bytes == nbytes + 2 * kv_bytes


def test_serving_engine_shared_budget_kv_and_experts_identical_outputs():
    """An engine running BOTH runtimes shares one store/cache (one byte
    budget); a budget tight enough to force evictions changes nothing
    about the streams."""
    from repro.configs import get_config
    from repro.data.synthetic import serving_requests
    from repro.serve.engine import (EdgeStorageConfig, KVStorageConfig,
                                    ServingEngine)
    from repro.train.loop import init_model
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = dataclasses.replace(cfg, padded_num_experts=0)
    params = init_model(cfg, seed=0)
    reqs = list(serving_requests(cfg.vocab_size, 4, max_prompt=6,
                                 max_new=4, seed=0))

    plain = ServingEngine(cfg, params, batch_slots=2, cache_len=32)
    plain.submit([dict(r) for r in reqs])
    done_plain = plain.run()

    def shared(cache_bytes):
        eng = ServingEngine(
            cfg, params, batch_slots=2, cache_len=32,
            expert_storage=EdgeStorageConfig(cache_bytes=cache_bytes),
            kv_storage=KVStorageConfig(block_tokens=4))
        assert eng.kvrt.cache is eng.edge.cache       # ONE budget
        assert eng.kvrt.store is eng.edge.store
        eng.submit([dict(r) for r in reqs])
        return eng, eng.run()

    eng, done = shared(cache_bytes=None)
    assert done == done_plain
    rep = eng.obs_report()["kv"]
    assert rep["sealed_blocks"] > 0
    # KV objects live in the same store namespace as the experts
    assert any(o.startswith("kv/") for o in eng.edge.store.objects())
    assert any(o.startswith("moe/") for o in eng.edge.store.objects())

    tight, done_tight = shared(cache_bytes=eng.edge.cache.resident_bytes
                               // 2)
    assert done_tight == done_plain                   # thrash, not wrong
    assert tight.edge.cache.stats["evictions"] > 0


def test_gate_ema_ranking_deterministic_ties_by_id():
    ema = GateEMA(4, decay=0.9)
    ema.update([1, 1, 1, 1])
    assert ema.ranking() == [0, 1, 2, 3]
    ema.update([0, 0, 8, 0])
    assert ema.ranking()[0] == 2
