"""End-to-end B-MoE system behaviour (the paper's claims, miniaturized)."""
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.data.synthetic import FMNIST, make_image_dataset


@pytest.fixture(scope="module")
def data():
    xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=2000, n_test=500,
                                            seed=0)
    return (xtr.reshape(len(xtr), -1), ytr,
            xte.reshape(len(xte), -1), yte)


def _train(framework, attack, data, rounds=30, seed=0):
    xtr, ytr, _, _ = data
    cfg = BMoEConfig(framework=framework, expert_kind="mlp", attack=attack,
                     pow_difficulty=2, seed=seed)
    sys_ = BMoESystem(cfg)
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        idx = rng.integers(0, len(xtr), 256)
        sys_.train_round(xtr[idx], ytr[idx])
    return sys_


ATK = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=0.5,
                   noise_std=5.0)


def test_bmoe_robust_traditional_degrades(data):
    """Paper Fig. 4c protocol: both frameworks trained in a trustworthy
    environment, then attacked at inference — the frozen traditional gate
    cannot detect manipulation; B-MoE's consensus filters it out."""
    _, _, xte, yte = data
    trad = _train("traditional", AttackConfig(), data)
    bmoe = _train("bmoe", AttackConfig(), data)
    strong = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                          noise_std=5.0)
    acc_trad = trad.evaluate(xte, yte, attack=strong)
    acc_bmoe = bmoe.evaluate(xte, yte, attack=strong)
    assert acc_bmoe > acc_trad + 0.1, (acc_bmoe, acc_trad)
    # B-MoE under attack ~= clean accuracy
    acc_clean = bmoe.evaluate(xte, yte, attack=AttackConfig())
    assert abs(acc_bmoe - acc_clean) < 0.02


def test_gate_deactivates_poisoned_experts_in_training(data):
    """Fig. 2: under training-time attack the traditional gate's
    activation ratio for malicious experts collapses."""
    trad = _train("traditional", ATK, data, rounds=40)
    ratio = trad.activation_ratio
    assert ratio[list(ATK.malicious_edges)].mean() \
        < 0.5 * ratio[:7].mean()


def test_bmoe_keeps_workload_balanced(data):
    bmoe = _train("bmoe", ATK, data, rounds=40)
    ratio = bmoe.activation_ratio
    # no expert starved: malicious experts stay within 2.5x of the others
    assert ratio[list(ATK.malicious_edges)].mean() \
        > ratio[:7].mean() / 2.5


def test_ledger_records_every_training_round(data):
    bmoe = _train("bmoe", ATK, data, rounds=10)
    assert len(bmoe.ledger.blocks) == 11  # genesis + 10 rounds
    assert bmoe.ledger.verify_chain()
    rounds = [b.payload["round"] for b in bmoe.ledger.blocks[1:]]
    assert rounds == list(range(10))
    assert all("expert_hash" in b.payload for b in bmoe.ledger.blocks[1:])


def test_param_poisoning_rejected_by_hash_vote(data):
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                       noise_std=5.0, poison_params=True)
    bmoe = _train("bmoe", atk, data, rounds=5)
    for b in bmoe.ledger.blocks[1:]:
        assert b.payload["expert_hash_accepted"]
        assert b.payload["expert_hash_support"] == 7  # honest majority
        assert "chain_misled" not in b.payload


def test_majority_poisoning_misleads_chain(data):
    """>50% malicious: the chain accepts the poisoned hash (paper
    §IV-B threshold)."""
    atk = AttackConfig(malicious_edges=(0, 1, 2, 3, 4, 5),
                       attack_prob=1.0, noise_std=5.0, poison_params=True,
                       colluding=True)
    bmoe = _train("bmoe", atk, data, rounds=3)
    assert any(b.payload.get("chain_misled") for b in bmoe.ledger.blocks[1:])


def test_inference_attack_sweep_threshold(data):
    """Fig. 4c shape: B-MoE flat below 50% malicious, collapses above."""
    _, _, xte, yte = data
    bmoe = _train("bmoe", AttackConfig(), data, rounds=30)
    accs = {}
    for m in (0, 3, 6):
        atk = AttackConfig(malicious_edges=tuple(range(10 - m, 10)),
                           attack_prob=1.0, noise_std=5.0)
        accs[m] = bmoe.evaluate(xte[:300], yte[:300], attack=atk)
    assert abs(accs[3] - accs[0]) < 0.03     # robust below threshold
    assert accs[6] < accs[0] - 0.2           # collapse above threshold


def test_latency_report_shows_bmoe_overhead(data):
    trad = _train("traditional", ATK, data, rounds=5)
    bmoe = _train("bmoe", ATK, data, rounds=5)
    lt = trad.latency_report(expert_bytes=850_000, result_bytes=40_000,
                             rounds=5)
    lb = bmoe.latency_report(expert_bytes=850_000, result_bytes=40_000,
                             rounds=5)
    assert lb["total_s"] > lt["total_s"]     # security costs latency
    assert lb["consensus_s"] >= 0 and lb["chain_s"] > 0
