"""The repro.obs subsystem: span tracer, metrics registry, and the
regression pins tying the legacy reports to the one registry.

Timing inside these tests goes through metric-bearing spans (the
subsystem measures itself) — direct wall-clock call sites outside
``src/repro/obs/`` and ``benchmarks/common.py`` are CI-linted away.
"""
import json
import time

import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.obs import (NOOP_SPAN, CounterGroup, MetricsRegistry,
                       Observability, Tracer)
from repro.trust.protocol import TrustConfig

# ------------------------------------------------------------- tracer


def test_nested_spans_child_within_parent():
    tr = Tracer(enabled=True)
    with tr.span("parent", round=1):
        with tr.span("child", expert=3):
            time.sleep(0.002)
        time.sleep(0.002)
    parent, child = {e["name"]: e for e in tr.events}["parent"], \
        {e["name"]: e for e in tr.events}["child"]
    assert child["parent_id"] == parent["span_id"]
    assert parent["parent_id"] == 0
    # the child's interval nests inside the parent's
    assert child["ts_s"] >= parent["ts_s"]
    assert child["ts_s"] + child["dur_s"] <= parent["ts_s"] + parent["dur_s"]
    assert child["dur_s"] <= parent["dur_s"]
    assert child["attrs"] == {"expert": 3}


def test_offpath_child_excluded_from_parent_metric():
    obs = Observability(enabled=True)
    with obs.span("consensus", metric="m.consensus_s") as p:
        time.sleep(0.002)
        with obs.span("audit-drain", metric="m.audit_s", off_path=True):
            time.sleep(0.005)
        time.sleep(0.002)
    audit = obs.metrics.value("m.audit_s")
    consensus = obs.metrics.value("m.consensus_s")
    assert audit >= 0.005
    assert p.off_child_s == pytest.approx(audit)
    # on-path metric + off-path child metric == parent wall
    assert consensus + audit == pytest.approx(p.dur_s)
    assert consensus < p.dur_s


def test_offpath_propagates_through_on_path_ancestors():
    obs = Observability(enabled=True)
    with obs.span("outer", metric="m.outer_s") as outer:
        with obs.span("mid"):                     # on-path, no metric
            with obs.span("leaf", off_path=True):
                time.sleep(0.004)
    assert outer.off_child_s >= 0.004
    assert obs.metrics.value("m.outer_s") == \
        pytest.approx(outer.dur_s - outer.off_child_s)


def test_chrome_trace_roundtrip(tmp_path):
    obs = Observability(enabled=True)
    with obs.span("round", metric="m.round_s", round=7, kind="train"):
        with obs.span("fetch", cid="abc123"):
            pass
    path = tmp_path / "trace.json"
    obs.trace.export_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert len(events) == 2
    by_name = {e["name"]: e for e in events}
    for e in events:
        assert e["ph"] == "X" and e["cat"] == "repro"
        assert e["dur"] >= 0 and e["ts"] >= 0 and e["pid"] == 1
        assert e["tid"] == obs.trace.trace_id
    assert by_name["fetch"]["args"]["parent_id"] \
        == by_name["round"]["args"]["span_id"]
    assert by_name["fetch"]["args"]["cid"] == "abc123"
    assert by_name["round"]["args"]["metric"] == "m.round_s"
    assert by_name["round"]["args"]["round"] == 7
    # JSONL export round-trips the raw event log
    jl = tmp_path / "trace.jsonl"
    assert obs.trace.export_jsonl(str(jl)) == 2
    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert lines == obs.trace.events


def test_noop_mode_zero_allocation_and_bounded():
    obs = Observability()                        # disabled
    assert not obs.enabled
    # no metric, not off-path -> the shared singleton: nothing allocated
    assert obs.span("anything", round=1) is NOOP_SPAN
    assert obs.span("x") is obs.span("y")
    assert obs.metrics.snapshot() == {}
    # a metric-bearing span still times itself even when disabled
    with obs.span("t", metric="m.t_s"):
        pass
    assert obs.metrics.value("m.t_s") > 0
    assert obs.trace.events == []                # ...but records nothing
    # overhead bound: 50k disabled spans, measured by the subsystem
    meter = Observability()
    with meter.span("bound", metric="m.bound_s"):
        for _ in range(50_000):
            with obs.span("hot", round=1):
                pass
    assert meter.metrics.value("m.bound_s") < 0.5   # <10us per no-op span


# ------------------------------------------------------------- metrics


def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    xs = rng.uniform(0.0, 10.0, 5000)
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=np.linspace(0.0, 10.0, 2001))
    for x in xs:
        h.observe(float(x))
    snap = h.snapshot()
    for q, key in ((0.50, "p50"), (0.90, "p90"), (0.99, "p99")):
        assert abs(snap[key] - np.quantile(xs, q)) < 0.05, key
    assert snap["p50"] <= snap["p90"] <= snap["p99"]
    assert snap["count"] == len(xs)
    assert snap["sum"] == pytest.approx(xs.sum())
    assert snap["min"] == pytest.approx(xs.min())
    assert snap["max"] == pytest.approx(xs.max())


def test_histogram_constant_stream_is_exact():
    h = MetricsRegistry().histogram("c", buckets=(1.0, 2.0, 4.0))
    for _ in range(100):
        h.observe(3.0)
    s = h.snapshot()
    # percentiles clamp to the observed range: a constant stream is exact
    assert s["p50"] == s["p90"] == s["p99"] == 3.0


def test_counter_group_is_a_registry_view():
    reg = MetricsRegistry()
    stats = CounterGroup({"hits": 0, "misses": 0}, reg, "edge.cache")
    stats["hits"] += 3
    stats["misses"] += 1
    assert dict(stats) == {"hits": 3, "misses": 1}
    assert reg.value("edge.cache.hits") == 3
    assert isinstance(stats["hits"], int)        # int adds stay exact
    with pytest.raises(TypeError):
        del stats["hits"]
    # without a registry it degrades to a plain local dict
    local = CounterGroup({"n": 0})
    local["n"] += 2
    assert dict(local) == {"n": 2}


# -------------------------------------------------- system-level pins

R = 5


def _data(n=512, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, 784)).astype(np.float32),
            rng.integers(0, 10, n))


def _run(seed=0, obs=None, attack=None, rounds=R):
    atk = attack if attack is not None else AttackConfig(
        malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
    cfg = BMoEConfig(framework="optimistic", num_experts=4, num_edges=4,
                     top_k=2, pow_difficulty=1, seed=seed, attack=atk,
                     trust=TrustConfig(audit_rate=0.5, challenge_window=2,
                                       scheduling="pipelined"))
    s = BMoESystem(cfg, obs=obs)
    x, y = _data(seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        idx = rng.integers(0, len(x), 128)
        s.train_round(x[idx], y[idx])
    s.flush_trust()
    return s


@pytest.fixture(scope="module")
def traced_system():
    obs = Observability(enabled=True)
    return _run(obs=obs), obs


def test_audit_seconds_excluded_from_consensus(traced_system):
    """The satellite pin: pipelined audit drains are booked to
    ``audit_offpath_s`` and structurally subtracted from ``consensus_s``
    (nested off-path spans replaced the old manual subtraction)."""
    s, obs = traced_system
    ev = obs.trace.events
    cons_ids = {e["span_id"] for e in ev if e["name"] == "consensus"}
    drains = [e for e in ev if e["name"] == "audit-drain"]
    nested = [e for e in drains if e["parent_id"] in cons_ids]
    assert drains and nested                 # drains fired, some in-round
    cons_wall = sum(e["dur_s"] for e in ev if e["name"] == "consensus")
    expected = cons_wall - sum(e["dur_s"] for e in nested)
    assert obs.metrics.value("bmoe.consensus_s") \
        == pytest.approx(expected, rel=1e-6)
    assert obs.metrics.value("bmoe.audit_s") \
        == pytest.approx(sum(e["dur_s"] for e in drains), rel=1e-6)
    assert s._timers["audit"] == obs.metrics.value("bmoe.audit_s")


def test_latency_report_total_is_sum_of_components(traced_system):
    s, _ = traced_system
    lr = s.latency_report(1000, 1000, R)
    assert set(lr) == {"compute_s", "comm_s", "consensus_s", "chain_s",
                       "audit_offpath_s", "storage_s", "total_s"}
    assert lr["audit_offpath_s"] > 0
    assert lr["total_s"] == pytest.approx(
        lr["compute_s"] + lr["comm_s"] + lr["consensus_s"] + lr["chain_s"],
        rel=1e-9)                            # audit + storage excluded


def test_legacy_report_shapes_unchanged(traced_system):
    s, _ = traced_system
    assert set(s._timers) == {"compute", "consensus", "chain", "audit",
                              "audit_infer", "storage"}
    sr = s.storage_report()
    assert set(sr) == {"network", "store", "cache", "da", "wall_s"}
    assert set(sr["network"]) >= {"put_requests", "put_bytes",
                                  "get_requests", "get_bytes",
                                  "modeled_put_s", "modeled_get_s"}
    assert set(sr["cache"]) >= {"hits", "misses", "evictions"}
    rep = s.obs_report(1000, 1000, R)
    assert set(rep) == {"metrics", "timers", "storage", "verification",
                        "latency"}
    assert rep["storage"] == sr
    assert rep["latency"] == s.latency_report(1000, 1000, R)
    # the registry snapshot carries every layer's namespace
    names = set(rep["metrics"])
    for prefix in ("bmoe.", "storage.network.", "storage.store.",
                   "trust.train."):
        assert any(n.startswith(prefix) for n in names), prefix


def test_round_spans_cover_wall_and_blocks_link(traced_system):
    s, obs = traced_system
    ev = obs.trace.events
    rounds = [e for e in ev if e["name"] == "round"]
    assert len(rounds) == R
    for r in rounds:
        child = sum(e["dur_s"] for e in ev
                    if e["parent_id"] == r["span_id"])
        assert child >= 0.95 * r["dur_s"]
    # every mined block resolves to a live span in this trace
    ids = {e["span_id"] for e in ev}
    mined = [b for b in s.ledger.blocks if b.index > 0]
    assert mined
    for b in mined:
        assert b.payload["trace_id"] == obs.trace.trace_id
        assert b.payload["span_id"] in ids


def test_metrics_deterministic_and_blocks_unpolluted():
    """Two identical runs with tracing DISABLED: every non-wall-clock
    metric matches exactly (counters and bytes are simulation state, not
    timing) and ledger payloads carry no trace ids — block hashes are
    bit-identical to the pre-obs chain."""
    a, b = _run(seed=0), _run(seed=0)
    sa, sb = a.obs.metrics.snapshot(), b.obs.metrics.snapshot()
    assert set(sa) == set(sb)
    skipped = 0
    for name in sa:
        if name.endswith("_s"):              # wall-clock: machine noise
            skipped += 1
            continue
        assert sa[name] == sb[name], name
    assert skipped < len(sa)                 # the exact set is non-empty
    assert all("trace_id" not in blk.payload for blk in a.ledger.blocks)
    assert [blk.hash for blk in a.ledger.blocks] \
        == [blk.hash for blk in b.ledger.blocks]


def test_serving_engine_token_latency_report():
    """Per-tick spans + per-session token-latency histograms on the
    serving engine, and the edge runtime's legacy report keys."""
    from repro.configs import get_config
    from repro.data.synthetic import serving_requests
    from repro.serve.engine import EdgeStorageConfig, ServingEngine
    from repro.train.loop import init_model

    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    params = init_model(cfg, seed=0)
    obs = Observability(enabled=True)
    eng = ServingEngine(cfg, params, batch_slots=2, cache_len=32,
                        expert_storage=EdgeStorageConfig(
                            cache_bytes=1 << 20), obs=obs)
    reqs = list(serving_requests(cfg.vocab_size, 2, max_prompt=8,
                                 max_new=3, seed=0))
    eng.submit(reqs)
    done = eng.run(max_ticks=50)
    rep = eng.report()
    assert rep == eng.obs_report()
    emitted = int(obs.metrics.value("serve.tokens"))
    assert emitted >= sum(len(v) for v in done.values()) > 0
    assert rep["token_latency"]["count"] == emitted
    # a fused macro-step books to prefill_s while any prompt token is
    # in flight and to decode_s otherwise; short requests may generate
    # entirely inside prefill chunks, so assert over the pair
    assert rep["tick_s"] >= rep["prefill_s"] + rep["decode_s"] > 0
    # one latency histogram per served session, observations summing up
    assert set(rep["sessions"]) == {str(r["id"]) for r in reqs}
    assert sum(s["count"] for s in rep["sessions"].values()) == emitted
    # the edge runtime's legacy report shape is unchanged
    assert set(rep["edge"]) == {"cache", "store", "network", "units",
                                "ticks"}
    assert obs.metrics.value("edge.cache.hits") \
        == rep["edge"]["cache"]["hits"]
    # one "step" span per fused macro-step (each covers C engine ticks;
    # the final drained step records a span too, before reporting no
    # work left)
    steps = [e for e in obs.trace.events if e["name"] == "step"]
    assert len(steps) >= eng.steps > 0
    assert eng.tick >= eng.steps
