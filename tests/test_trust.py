"""Optimistic verification subsystem (repro.trust): Merkle commitments,
audit sampling vs the analytic detection bound, fraud proofs, slashing +
reputation exclusion, dispute escalation, and the end-to-end
``framework="optimistic"`` / verified-serving integration."""
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.core.reputation import ReputationConfig, ReputationLedger
from repro.data.synthetic import FMNIST, make_image_dataset
from repro.kernels import ref as kref
from repro.trust.audit import VerifierPool, verify_fraud_proof
from repro.trust.commitments import MerkleTree, commit_outputs, leaf_digest
from repro.trust.protocol import (ChallengeWindow, OptimisticProtocol,
                                  RoundPhase, TrustConfig)
from repro.trust.slashing import (DisputeCourt, StakeBook, Verdict,
                                  reputation_fraud_update)


@pytest.fixture(scope="module")
def data():
    xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=2000, n_test=400,
                                            seed=0)
    return xtr.reshape(len(xtr), -1), ytr, xte.reshape(len(xte), -1), yte


# --------------------------------------------------------- commitments
@pytest.mark.parametrize("n_leaves", [1, 2, 3, 7, 8, 13])
def test_merkle_commit_verify_roundtrip(n_leaves):
    rng = np.random.default_rng(0)
    leaves = [leaf_digest(rng.normal(size=(4,)).astype(np.float32))
              for _ in range(n_leaves)]
    tree = MerkleTree(leaves)
    for i, leaf in enumerate(leaves):
        assert MerkleTree.verify(tree.root, leaf, tree.prove(i))
    # a different leaf (or a shifted path) must not verify
    bogus = leaf_digest(np.ones(4, np.float32) * 99)
    assert not MerkleTree.verify(tree.root, bogus, tree.prove(0))
    if n_leaves > 1:
        assert not MerkleTree.verify(tree.root, leaves[0], tree.prove(1))


def test_commitment_covers_expert_chunks():
    rng = np.random.default_rng(1)
    outs = rng.normal(size=(3, 10, 5)).astype(np.float32)
    com = commit_outputs(outs, round_id=0, executor=2, chunks_per_expert=4)
    assert com.num_leaves == 3 * 4
    # leaf coords tile the batch exactly, and leaf data matches the slice
    for leaf in range(com.num_leaves):
        e, c, sl = com.leaf_coords(leaf)
        np.testing.assert_array_equal(com.leaf_chunk(leaf), outs[e, sl])
        assert com.leaf_digests[leaf] == leaf_digest(outs[e, sl])
    # root binds every leaf: flipping one value changes the digest chain
    tampered = outs.copy()
    tampered[1, 3, 0] += 1e-3
    assert commit_outputs(tampered, round_id=0, executor=2,
                          chunks_per_expert=4).root != com.root


# --------------------------------------------------------------- audit
def test_detection_probability_matches_analytic_bound():
    """Empirical P[detect] over many audit lotteries matches
    1-(1-audit_rate)^k for k corrupted leaves, single honest verifier."""
    rate, k, num_leaves, trials = 0.15, 5, 40, 4000
    pool = VerifierPool(num_verifiers=1, audit_rate=rate, seed=3)
    corrupted = set(range(k))
    hits = sum(bool(set(pool.sample_leaves(t, 0, num_leaves)) & corrupted)
               for t in range(trials))
    analytic = 1.0 - (1.0 - rate) ** k
    assert abs(hits / trials - analytic) < 0.03
    assert pool.detection_probability(k, honest_verifiers=1) == \
        pytest.approx(analytic)


def test_fraud_proof_construction_and_court_check():
    rng = np.random.default_rng(2)
    honest = rng.normal(size=(2, 8, 3)).astype(np.float32)
    claimed = honest.copy()
    claimed[1] += 1.0                              # expert 1 corrupted
    com = commit_outputs(claimed, round_id=5, executor=0,
                         chunks_per_expert=2)
    pool = VerifierPool(num_verifiers=1, audit_rate=1.0, seed=0)
    [report] = pool.audit(com, lambda e, sl: honest[e, sl])
    assert report.recomputed_leaves == com.num_leaves
    assert {p.expert for p in report.fraud_proofs} == {1}
    for proof in report.fraud_proofs:
        e, _, sl = com.leaf_coords(proof.leaf_index)
        # the court re-checks path + recompute; honest chunks yield none
        assert verify_fraud_proof(com.root, proof,
                                  lambda e_, sl_: honest[e_, sl_], sl)
        assert proof.compact_size_bytes() < claimed.nbytes


def test_fabricated_fraud_proof_rejected():
    """A lying verifier cannot grief: a 'proof' whose chunk recomputes
    clean (or was never committed) fails the court check."""
    rng = np.random.default_rng(3)
    honest = rng.normal(size=(2, 8, 3)).astype(np.float32)
    com = commit_outputs(honest, round_id=0, executor=0, chunks_per_expert=2)
    pool = VerifierPool(num_verifiers=1, audit_rate=1.0, seed=0)
    [report] = pool.audit(com, lambda e, sl: honest[e, sl])
    assert report.clean                        # honest commitment: no proofs
    # fabricate one against a committed-but-honest leaf
    from repro.trust.audit import FraudProof
    tree = com.tree()
    fake = FraudProof(round_id=0, executor=0, leaf_index=0, expert=0,
                      claimed_chunk=com.leaf_chunk(0), path=tree.prove(0),
                      claimed_digest=com.leaf_digests[0],
                      recomputed_digest="deadbeef", verifier=0)
    e, _, sl = com.leaf_coords(0)
    assert not verify_fraud_proof(com.root, fake,
                                  lambda e_, sl_: honest[e_, sl_], sl)


def test_lazy_verifiers_never_raise_proofs():
    rng = np.random.default_rng(4)
    honest = rng.normal(size=(2, 8, 3)).astype(np.float32)
    com = commit_outputs(honest + 5.0, round_id=0, executor=0,
                         chunks_per_expert=2)       # everything corrupted
    pool = VerifierPool(num_verifiers=4, audit_rate=1.0, lazy_prob=1.0,
                        seed=0)
    reports = pool.audit(com, lambda e, sl: honest[e, sl])
    assert all(r.lazy and r.clean and r.recomputed_leaves == 0
               for r in reports)


# ---------------------------------------------------- slashing + court
def test_slashing_excludes_repeat_offenders_via_reputation():
    rep = ReputationLedger(6, ReputationConfig(init=0.5, gain=0.01,
                                               slash=0.2,
                                               exclusion_threshold=0.15))
    for _ in range(2):
        reputation_fraud_update(rep, guilty_edge=4, num_edges=6)
    assert rep.excluded[4]
    assert not rep.excluded[[0, 1, 2, 3, 5]].any()
    assert 4 not in rep.active_edges()


def test_stake_book_bonding_and_bounty():
    from repro.trust.audit import FraudProof
    from repro.trust.commitments import MerklePath
    book = StakeBook(4, stake=1.0, slash_fraction=0.5, bounty_fraction=0.5,
                     min_stake=0.3)
    proof = FraudProof(round_id=0, executor=2, leaf_index=0, expert=0,
                       claimed_chunk=np.zeros(1), path=MerklePath(0, ()),
                       claimed_digest="x", recomputed_digest="y", verifier=1)
    ev = book.slash(proof)
    assert book.stake[2] == pytest.approx(0.5) and ev.amount == 0.5
    assert book.bounties[1] == pytest.approx(0.25)
    assert book.bonded(2)
    book.slash(proof)
    assert not book.bonded(2)                   # below min stake: unbonded
    assert book.bonded_edges() == [0, 1, 3]


def test_dispute_escalation_reproduces_full_redundancy_verdict():
    """The court's verdict is exactly the paper's M-way majority vote:
    a minority coalition (executor included) loses and the trusted
    outputs equal the honest ones; a >50% coalition misleads it."""
    rng = np.random.default_rng(5)
    E, M, B, C = 3, 10, 6, 4
    honest = rng.normal(size=(E, B, C)).astype(np.float32)
    bad = honest + 3.0
    court = DisputeCourt(M)

    def make_pub(coalition):
        pub = np.broadcast_to(honest[:, None], (E, M, B, C)).copy()
        for m in coalition:
            pub[:, m] = bad
        return pub

    v = court.escalate(0, make_pub((0, 1, 2)), executor=0)
    assert v.executor_guilty
    np.testing.assert_allclose(v.trusted, honest)
    ref_trusted, ref_support, _ = kref.redundancy_vote_masked_ref(
        make_pub((0, 1, 2)), np.ones(M, np.float32))
    np.testing.assert_allclose(v.trusted, np.asarray(ref_trusted))
    np.testing.assert_array_equal(v.support, np.asarray(ref_support))
    # above the 50% threshold the vote (and so the court) is misled
    v2 = court.escalate(1, make_pub(tuple(range(6))), executor=0)
    assert not v2.executor_guilty
    np.testing.assert_allclose(v2.trusted, bad)


# ------------------------------------------------------------ protocol
def test_challenge_window_finalization_timing():
    proto = OptimisticProtocol(TrustConfig(challenge_window=3), num_edges=4)
    outs = np.zeros((2, 4, 3), np.float32)
    proto.commit(0, executor=1, outputs=outs)
    assert proto.rounds[0].phase is RoundPhase.ACCEPTED
    assert proto.advance(1) == [] and proto.advance(2) == []
    assert proto.advance(3) == [0]
    assert proto.rounds[0].phase is RoundPhase.FINALIZED
    assert proto.pending() == []


def test_zero_challenge_window_audits_before_finalize():
    """window=0: the round finalizes the same round it commits, but only
    after its audit pass — a closed round cannot be re-audited, an
    unresolved dispute blocks every later finalization (sequential
    finality), and a guilty verdict invalidates the chain built on it."""
    proto = OptimisticProtocol(TrustConfig(challenge_window=0, audit_rate=1.0,
                                           num_verifiers=1), num_edges=2)
    outs = np.zeros((2, 4, 3), np.float32)
    proto.commit(0, executor=1, outputs=outs)
    bad = outs + 1.0
    assert proto.run_audits(0, lambda e, sl: bad[e, sl])  # fraud caught first
    assert proto.rounds[0].phase is RoundPhase.CHALLENGED
    assert proto.advance(0) == []          # challenged: advance won't close
    proto.commit(1, executor=0, outputs=outs)
    assert proto.run_audits(1, lambda e, sl: outs[e, sl]) == []
    # sequential finality: clean round 1 cannot close past round 0's
    # open dispute — it is built on disputed state
    assert proto.advance(1) == []
    state = proto.resolve(0, Verdict(
        round_id=0, trusted=outs, support=np.full(2, 2.0),
        flags=np.ones((2, 2), np.int32), executor_guilty=True))
    assert state.phase is RoundPhase.ROLLED_BACK
    # ... and is invalidated with its convicted ancestor (no slash for
    # its executor: round 0's executor alone pays)
    assert proto.rounds[1].phase is RoundPhase.INVALIDATED
    assert len(proto.stakes.events) == 1
    assert proto.rollbacks[-1].invalidated == [1]
    proto.commit(2, executor=0, outputs=outs)
    assert proto.run_audits(2, lambda e, sl: outs[e, sl]) == []
    assert proto.advance(2) == [2]         # clean chain: closes immediately
    assert proto.run_audits(2, lambda e, sl: bad[e, sl]) == []  # window shut


def test_challenge_window_tracker():
    win = ChallengeWindow(2)
    win.enter(7, now=10)
    win.enter(8, now=11)
    assert win.expire(11) == []
    assert win.expire(12) == [7]
    win.revoke(8)
    assert win.expire(20) == [] and win.revoked == [8] and len(win) == 0


def test_executor_rotation_skips_unbonded_and_excluded():
    rep = ReputationLedger(4, ReputationConfig(exclusion_threshold=0.15))
    proto = OptimisticProtocol(TrustConfig(), num_edges=4, reputation=rep)
    rep.rep[1] = 0.0                                    # excluded
    proto.stakes.stake[2] = 0.0                         # unbonded
    picks = {proto.pick_executor(r) for r in range(8)}
    assert picks == {0, 3}


# ----------------------------------------------- end-to-end (BMoESystem)
def _optimistic_system(attack, rounds_cfg=None, **kw):
    cfg = BMoEConfig(framework="optimistic", attack=attack, pow_difficulty=2,
                     reputation=ReputationConfig(init=0.5, gain=0.01,
                                                 slash=0.4,
                                                 exclusion_threshold=0.2),
                     trust=rounds_cfg or TrustConfig(audit_rate=0.2,
                                                     challenge_window=2),
                     **kw)
    return BMoESystem(cfg)


def test_optimistic_detects_and_slashes_adversary_within_bound(data):
    """A persistent cheating executor is caught the first round it
    executes: full-tensor corruption makes detection ~certain, the court
    convicts, the stake is slashed, and reputation exclusion removes it
    from the rotation — all malicious edges are out within ~2 rotations
    of the executor schedule."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                       noise_std=5.0)
    s = _optimistic_system(atk)
    rng = np.random.default_rng(0)
    for _ in range(20):
        idx = rng.integers(0, len(xtr), 128)
        s.train_round(xtr[idx], ytr[idx])
    slashed = {ev.edge for ev in s.protocol.stakes.events}
    assert slashed == {7, 8, 9}                  # all caught...
    assert s.reputation.excluded[[7, 8, 9]].all()  # ...and excluded
    assert not s.reputation.excluded[:7].any()   # no honest edge punished
    assert s.protocol.stats["rolled_back"] == len(s.protocol.stakes.events)
    # bounded: every malicious edge is caught the first time the rotation
    # hands it the executor role (within two rotations of the schedule)
    last_slash = max(ev.round_id for ev in s.protocol.stakes.events)
    assert last_slash < 16
    # once excluded, the rotation never hands them the executor role again
    # (rollback blocks carry the convicted executor — skip them here)
    execs_after = [b.payload["executor"] for b in s.ledger.blocks[1:]
                   if b.payload.get("kind") == "train"
                   and b.payload["round"] > last_slash]
    assert execs_after and not set(execs_after) & {7, 8, 9}


def test_optimistic_paper_adversary_caught(data):
    """Paper §V setting: colluding minority, attack_prob=0.2 — cheating
    rounds are rarer but still detected and slashed within the run."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=0.2,
                       noise_std=5.0)
    s = _optimistic_system(atk)
    rng = np.random.default_rng(0)
    for _ in range(40):
        idx = rng.integers(0, len(xtr), 64)
        s.train_round(xtr[idx], ytr[idx])
    slashed = {ev.edge for ev in s.protocol.stakes.events}
    assert slashed, "no fraud detected in 40 rounds"
    assert slashed <= {7, 8, 9}                 # only malicious slashed
    assert s.protocol.stats["fraud_proofs"] > 0


def test_optimistic_rollback_matches_clean_training(data):
    """Rollback-on-fraud: every detected poisoned round is undone and
    re-run on the court's honest result, so training under attack tracks
    the clean run."""
    xtr, ytr, xte, yte = data
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, len(xtr), 128) for _ in range(12)]

    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                       noise_std=5.0)
    attacked = _optimistic_system(atk)
    clean = _optimistic_system(AttackConfig())
    for idx in batches:
        attacked.train_round(xtr[idx], ytr[idx])
        clean.train_round(xtr[idx], ytr[idx])
    assert attacked.protocol.stats["rolled_back"] >= 1
    acc_a = attacked.evaluate(xte, yte, attack=AttackConfig())
    acc_c = clean.evaluate(xte, yte, attack=AttackConfig())
    assert abs(acc_a - acc_c) < 0.02, (acc_a, acc_c)


def test_optimistic_verification_5x_cheaper_than_redundancy(data):
    """Acceptance: per-round verification compute at audit_rate=0.1 is
    >=5x below framework="bmoe" full redundancy at M=10, adversary
    included (paper §V attack_prob=0.2)."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=0.2,
                       noise_std=5.0)
    rng = np.random.default_rng(0)
    batches = [rng.integers(0, len(xtr), 128) for _ in range(10)]

    bmoe = BMoESystem(BMoEConfig(framework="bmoe", attack=atk,
                                 pow_difficulty=2))
    opt = _optimistic_system(atk, TrustConfig(audit_rate=0.1,
                                              challenge_window=2))
    for idx in batches:
        bmoe.train_round(xtr[idx], ytr[idx])
        opt.train_round(xtr[idx], ytr[idx])
    vb = bmoe.verification_report()["total_verification_per_round"]
    vo = opt.verification_report()["total_verification_per_round"]
    assert vb >= 5.0 * vo, (vb, vo)


def test_ledger_integrity_with_audit_blocks(data):
    """Every optimistic round appends an audit block (commit root,
    executor, drained audits, finalizations) and every confirmed fraud
    appends a rollback block naming the whole voided chain; the chain
    stays verifiable throughout."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(9,), attack_prob=1.0, noise_std=5.0)
    s = _optimistic_system(atk)
    rng = np.random.default_rng(0)
    evidence_seen = False
    for _ in range(12):
        idx = rng.integers(0, len(xtr), 64)
        s.train_round(xtr[idx], ytr[idx])
        # audit-evidence blobs live in storage only while a round is
        # open (its window not yet closed / dispute not yet resolved):
        # the data-availability invariant holds after every round, and
        # drained-but-still-open rounds stay fetchable by CID
        open_rounds = set(s.protocol.pending())
        assert set(s._audit_cids) <= open_rounds
        for cids in s._audit_cids.values():
            for cid in cids:
                assert s.storage.get(cid)        # available by CID
        evidence_seen = evidence_seen or bool(s._audit_cids)
    assert evidence_seen
    assert s.ledger.verify_chain()
    rounds = [b.payload for b in s.ledger.blocks[1:]
              if b.payload.get("kind") == "train"]
    rollbacks = [b.payload for b in s.ledger.blocks[1:]
                 if b.payload.get("kind") == "rollback"]
    # genesis + one block per round + one block per confirmed fraud
    assert len(rounds) == 12
    assert len(s.ledger.blocks) == 13 + len(rollbacks)
    assert all("commit_root" in p and "executor" in p
               and "audited_leaves" in p for p in rounds)
    assert any(p.get("finalized_rounds") for p in rounds)    # windows close
    # edge 9's fraud (round 9, detected after descendants committed)
    # produced a rollback block recording the voided chain + the slash
    assert rollbacks and all(p["slashed"] == [9] for p in rollbacks)
    chain = rollbacks[0]["chain"]
    assert chain[0] == rollbacks[0]["rollback_of"]
    assert chain == sorted(chain)
    rolled = [st for st in s.protocol.rounds.values()
              if st.phase is RoundPhase.ROLLED_BACK]
    assert rolled and all(st.proofs for st in rolled)
    assert {st.round_id for st in rolled} == \
        {p["rollback_of"] for p in rollbacks}
    # a flush settles every still-open round and releases all evidence
    s.flush_trust()
    assert s.protocol.pending() == [] and not s._audit_cids
    # tampering any block breaks the chain
    s.ledger.blocks[3].payload["executor"] = 99
    assert not s.ledger.verify_chain()


# -------------------------------------------------- serving integration
def _tiny_engine(**kw):
    from repro.configs import get_config
    from repro.serve.engine import ServingEngine
    from repro.train.loop import init_model
    cfg = get_config("smollm-360m", smoke=True)
    params = init_model(cfg, seed=0)
    return ServingEngine(cfg, params, batch_slots=2, cache_len=64, **kw)


def test_serving_completed_preserves_submission_order():
    from repro.data.synthetic import serving_requests
    eng = _tiny_engine()
    reqs = list(serving_requests(eng.cfg.vocab_size, 6, max_prompt=8,
                                 max_new=4, seed=1))
    eng.submit(reqs)
    done = eng.run()
    assert list(done) == [r["id"] for r in reqs]
    for r in reqs:
        assert len(done[r["id"]]) == r["max_new_tokens"]


def test_verified_serving_finalizes_after_window_and_revokes_tampering():
    from repro.data.synthetic import serving_requests
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=5)
    eng = _tiny_engine(trust=trust)
    plain = _tiny_engine()
    reqs = list(serving_requests(eng.cfg.vocab_size, 4, max_prompt=8,
                                 max_new=4, seed=2))
    eng.submit(reqs)
    plain.submit(reqs)
    # drive until generation finishes: completions wait in their windows
    while eng.pending_finalization == [] and eng.step():
        pass
    assert eng.pending_finalization != []        # optimistic: not yet final
    done = eng.run()
    assert eng.pending_finalization == []
    assert done == plain.run()                   # same tokens, just audited
    events = [e["event"] for e in eng.session_log]
    assert events.count("commit") == len(reqs)
    assert events.count("finalize") == len(reqs)
    # tamper one served stream: the audit revokes it, it leaves completed
    rid = reqs[1]["id"]
    eng.records[rid].tokens = [t ^ 1 for t in eng.records[rid].tokens]
    rep = eng.audit_session(rid)
    assert rep["revoked"] and rid not in eng.completed
    assert rid in done and rid in eng._done      # data kept for forensics
