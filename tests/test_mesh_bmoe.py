"""Mesh-executed B-MoE rounds (BMoEConfig.mesh="on").

Acceptance pins for the mesh tentpole: with >= 4 simulated edge devices
(forced host devices in a subprocess), the full round loop — sparse
all_to_all dispatch, shard-local trust corruption/vote, shard-local
commitments, owning-shard audit recompute, fraud proofs, slashing, and
chained rollback — is BIT-IDENTICAL to the single-device oracle
(``mesh="off"``): same parameter digests every round, same commitment
and bank roots, same audit verdicts, same post-rollback state.  The
scalar loss is the one quantity compared with tolerance only (its mean
reduces over a sharded output in a different order), which is also why
block hashes — whose payloads embed the float loss — are never
compared.

Host-side tests cover the shard-local commitment algebra: per-edge
Merkle subtrees reduce to exactly the flat single-device root whenever
leaves-per-shard is a power of two (each shard subtree is then a
complete subtree of the flat tree), so every authentication path and
fraud proof is unchanged.
"""
import numpy as np
import pytest

from conftest import run_with_devices
from repro.trust.commitments import MerkleTree, commit_outputs


# ------------------------------------------------ shard-local commitments
def test_sharded_commitment_root_equals_flat_root():
    rng = np.random.default_rng(0)
    outs = rng.standard_normal((8, 16, 10), dtype=np.float32)
    flat = commit_outputs(outs, round_id=0, executor=1, chunks_per_expert=4)
    for shards in (2, 4, 8):
        com = commit_outputs(outs, round_id=0, executor=1,
                             chunks_per_expert=4, num_shards=shards)
        assert com.num_shards == shards
        assert len(com.shard_roots) == shards
        assert com.root == flat.root
        assert com.leaf_digests == flat.leaf_digests
        # the published shard roots ARE level log2(leaves/shard) of the
        # flat tree: reducing them reproduces the round root
        assert MerkleTree(com.shard_roots).root == com.root
        # ... and every fraud proof is byte-identical
        tree_f, tree_s = flat.tree(), com.tree()
        for leaf in (0, 7, 31):
            assert tree_s.prove(leaf) == tree_f.prove(leaf)


def test_sharded_commitment_single_leaf_shards():
    """leaves-per-shard == 1 (E_l == chunks == 1 ... or any product of
    one): the shard root IS the leaf digest; reduction still matches."""
    rng = np.random.default_rng(1)
    outs = rng.standard_normal((4, 3, 5), dtype=np.float32)
    flat = commit_outputs(outs, round_id=0, executor=0, chunks_per_expert=1)
    com = commit_outputs(outs, round_id=0, executor=0, chunks_per_expert=1,
                         num_shards=4)
    assert com.shard_roots == flat.leaf_digests
    assert com.root == flat.root


def test_sharded_commitment_rejects_non_pow2_leaves_per_shard():
    outs = np.zeros((6, 8, 4), np.float32)
    with pytest.raises(ValueError, match="power of two"):
        commit_outputs(outs, round_id=0, executor=0, chunks_per_expert=3,
                       num_shards=2)                     # 3*3 = 9 leaves
    with pytest.raises(ValueError, match="divide"):
        commit_outputs(outs, round_id=0, executor=0, num_shards=4)


def test_mesh_config_validation():
    from repro.core.bmoe import BMoEConfig, BMoESystem
    from repro.trust.protocol import TrustConfig
    with pytest.raises(ValueError, match="sparse"):
        BMoESystem(BMoEConfig(framework="optimistic", dispatch="dense",
                              mesh="on"))
    # on one device the edge mesh degenerates to a single shard and the
    # system must still construct (the subprocess tests cover >= 4)
    s = BMoESystem(BMoEConfig(framework="optimistic", dispatch="sparse",
                              mesh="on", num_experts=8, top_k=2,
                              pow_difficulty=2,
                              trust=TrustConfig(audit_rate=0.5,
                                                num_verifiers=1,
                                                challenge_window=1)))
    assert s.mesh_shards == 1


def test_mesh_rejects_non_pow2_shard_leaves(repo_src):
    """num_experts/shards * chunks_per_expert must be a power of two for
    the root-of-roots reduction to stay bit-identical — reject at system
    construction, before any round commits.  (Needs >1 shard: a single
    shard commits the flat tree, where any leaf count is legal.)"""
    out = run_with_devices("""
        import pytest
        from repro.core.bmoe import BMoEConfig, BMoESystem
        from repro.trust.protocol import TrustConfig
        with pytest.raises(ValueError, match="power-of-two"):
            BMoESystem(BMoEConfig(framework="optimistic", dispatch="sparse",
                                  mesh="on", num_experts=6, top_k=2,
                                  mesh_shards=2, pow_difficulty=2,
                                  trust=TrustConfig(audit_rate=0.5,
                                                    num_verifiers=1,
                                                    challenge_window=1,
                                                    chunks_per_expert=3)))
        print("NON POW2 REJECTED")
    """, 2, repo_src)
    assert "NON POW2 REJECTED" in out


# --------------------------------------------------- mesh == oracle
_COMMON = """
        import numpy as np
        import jax
        from repro.core.attacks import AttackConfig
        from repro.core.bmoe import BMoEConfig, BMoESystem
        from repro.core.ledger import digest_tree
        from repro.core.reputation import ReputationConfig
        from repro.data.synthetic import FMNIST, make_image_dataset
        from repro.trust.protocol import TrustConfig
        xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=600,
                                                n_test=100, seed=0)
        xtr = xtr.reshape(len(xtr), -1)
        xte = xte.reshape(len(xte), -1)
"""


def test_mesh_optimistic_round_loop_bit_identical(repo_src):
    """The headline acceptance: 5 attacked optimistic rounds + audits +
    slash + rollback on an 8-edge mesh vs the single-device oracle —
    parameters, commitment roots, shard-root reduction, fraud proofs,
    phases, inference logits, and per-shard audit-row accounting."""
    out = run_with_devices(_COMMON + """
        def build(mesh):
            return BMoESystem(BMoEConfig(
                framework="optimistic", dispatch="sparse", mesh=mesh,
                num_experts=8, top_k=2, capacity_factor=1.25,
                pow_difficulty=2,
                attack=AttackConfig(malicious_edges=(2,), attack_prob=1.0,
                                    noise_std=5.0),
                reputation=ReputationConfig(init=0.5, gain=0.01, slash=0.4,
                                            exclusion_threshold=0.2),
                trust=TrustConfig(audit_rate=1.0, num_verifiers=2,
                                  challenge_window=2,
                                  audit_backend="batched")))
        def run(mesh):
            s = build(mesh)
            rng = np.random.default_rng(0)
            for idx in [rng.integers(0, len(xtr), 48) for _ in range(5)]:
                s.train_round(xtr[idx], ytr[idx])
            s.flush_trust()
            return s
        a, b = run("off"), run("on")
        assert b.mesh_shards == 8, b.mesh_shards
        assert digest_tree(a.experts) == digest_tree(b.experts)
        assert digest_tree(a.gate) == digest_tree(b.gate)
        for rid in a.protocol.rounds:
            ra, rb = a.protocol.rounds[rid], b.protocol.rounds[rid]
            assert ra.commitment.root == rb.commitment.root, rid
            assert ra.phase is rb.phase, rid
            assert [(p.leaf_index, p.expert, p.claimed_digest,
                     p.recomputed_digest) for p in ra.proofs] == \
                   [(p.leaf_index, p.expert, p.claimed_digest,
                     p.recomputed_digest) for p in rb.proofs], rid
        com = b.protocol.rounds[0].commitment
        assert com.num_shards == 8
        from repro.trust.commitments import MerkleTree
        assert MerkleTree(com.shard_roots).root == com.root
        assert a.protocol.stats["rolled_back"] == \
            b.protocol.stats["rolled_back"] >= 1
        la, _, _ = a.infer(xte[:64], commit=False)
        lb, _, _ = b.infer(xte[:64], commit=False)
        assert np.asarray(la).tobytes() == np.asarray(lb).tobytes()
        # audit recompute ran shard-local: every sampled row was booked
        # against the shard owning its expert, ~uniformly (audit_rate=1
        # samples every leaf, so each of the 8 shards re-executes ~1/8
        # of the rows the oracle re-executes in one call)
        rows = {s: b.obs.metrics.value("bmoe.mesh.audit_rows", shard=str(s))
                for s in range(8)}
        total = sum(rows.values())
        cap_pad = 16                            # one capacity bucket of slack
        assert total > 0 and all(r > 0 for r in rows.values()), rows
        assert max(rows.values()) <= total / 8 + cap_pad, rows
        print("MESH ORACLE OK", b.protocol.stats["rolled_back"], total)
    """, 8, repo_src, timeout=900)
    assert "MESH ORACLE OK" in out


def test_mesh_frameworks_bit_identical(repo_src):
    """traditional (per-edge corruption) and bmoe (full redundancy vote)
    frameworks, mesh on/off, explicit 4-wide shards (E_l == 2): params
    and inference bitwise equal."""
    out = run_with_devices(_COMMON + """
        atk = AttackConfig(malicious_edges=(1, 2), attack_prob=1.0,
                           noise_std=3.0)
        for fw in ("traditional", "bmoe"):
            def run(mesh):
                s = BMoESystem(BMoEConfig(framework=fw, dispatch="sparse",
                                          mesh=mesh, mesh_shards=4,
                                          num_experts=8, top_k=2,
                                          pow_difficulty=2, attack=atk))
                for r in range(3):
                    s.train_round(xtr[r * 48:(r + 1) * 48],
                                  ytr[r * 48:(r + 1) * 48])
                return s
            a, b = run("off"), run("on")
            assert b.mesh_shards == 4
            assert digest_tree(a.experts) == digest_tree(b.experts), fw
            assert digest_tree(a.gate) == digest_tree(b.gate), fw
            la, _, _ = a.infer(xte[:32])
            lb, _, _ = b.infer(xte[:32])
            assert np.asarray(la).tobytes() == np.asarray(lb).tobytes(), fw
            print(fw, "MESH OK")
    """, 8, repo_src, timeout=900)
    assert out.count("MESH OK") == 2


def test_mesh_bank_actually_sharded(repo_src):
    """The expert bank must really live sharded over the edge mesh (one
    E/msize slice per device), not replicated."""
    out = run_with_devices("""
        import numpy as np, jax
        from repro.core.bmoe import BMoEConfig, BMoESystem
        from repro.trust.protocol import TrustConfig
        s = BMoESystem(BMoEConfig(framework="optimistic", dispatch="sparse",
                                  mesh="on", num_experts=8, top_k=2,
                                  pow_difficulty=2,
                                  trust=TrustConfig(audit_rate=0.5,
                                                    num_verifiers=1,
                                                    challenge_window=1)))
        assert s.mesh_shards == 8
        leaf = jax.tree_util.tree_leaves(s.experts)[0]
        shard_shapes = {d.data.shape[0] for d in leaf.addressable_shards}
        assert shard_shapes == {1}, shard_shapes     # E_l = 8/8 experts
        assert len(leaf.addressable_shards) == 8
        print("BANK SHARDED OK")
    """, 8, repo_src)
    assert "BANK SHARDED OK" in out
