"""Protocol property tests (hypothesis stateful + deterministic walks).

``ProtocolModel`` drives an ``OptimisticProtocol`` through arbitrary
interleavings of commit / run_audits / resolve / advance / drain and
checks the protocol invariants after every step:

- conservation: every committed round is in exactly one of
  {finalized} ∪ {rolled_back, invalidated} ∪ {pending}, and the stats
  counters agree with the phase census;
- phases only move forward (and terminal phases never change);
- a CHALLENGED round never finalizes via ``advance``;
- sequential finality: nothing finalizes past an open round;
- stake is never negative and never exceeds the initial deposit;
- ``pending()`` is deadline-ordered and phase-consistent;
- with audit_rate=1.0, one confirmed slash per convicted round.

The hypothesis machine explores random interleavings in CI; the
deterministic random walks below always run (hypothesis is optional —
see conftest), so the invariants are exercised in every environment.

Also here: ``ChallengeWindow`` edge cases and the ``advance``
O(rounds^2) regression pin (deadline heap, not a full-history scan).
"""
import random

import numpy as np
import pytest
from hypothesis import settings, strategies as st
from hypothesis.stateful import RuleBasedStateMachine, invariant, rule

from repro.trust.protocol import (PHASE_RANK, TERMINAL_PHASES,
                                  ChallengeWindow, OptimisticProtocol,
                                  RoundPhase, TrustConfig)
from repro.trust.slashing import Verdict

E, B, C, EDGES = 2, 4, 3, 4


class ProtocolModel:
    """The protocol plus just enough book-keeping to know ground truth
    (which rounds were committed fraudulently) and phase history."""

    def __init__(self, window: int = 2):
        self.proto = OptimisticProtocol(
            TrustConfig(challenge_window=window, audit_rate=1.0,
                        num_verifiers=1, seed=0), num_edges=EDGES)
        self.honest = np.zeros((E, B, C), np.float32)
        self.bad = self.honest + 1.0
        self.fraudulent = {}
        self.next_rid = 0
        self.clock = 0
        self.last_phase = {}
        # rounds that were open when an ancestor was convicted: they
        # must NEVER finalize, whatever the interleaving
        self.doomed = set()

    # ------------------------------------------------------------ steps
    def do_commit(self, fraud: bool, schedule: bool) -> None:
        rid = self.next_rid
        self.next_rid += 1
        executor = self.proto.pick_executor(rid)
        self.proto.commit(rid, executor,
                          self.bad if fraud else self.honest)
        self.fraudulent[rid] = fraud
        if schedule:                    # park the audit off-path
            self.proto.schedule_audit(
                rid, lambda e, sl: self.honest[e, sl])
        self.clock = max(self.clock, rid)
        self.check()

    def do_audit(self, offset: int) -> None:
        open_rounds = self.proto.pending()
        if not open_rounds:
            return
        rid = open_rounds[offset % len(open_rounds)]
        proofs = self.proto.run_audits(rid,
                                       lambda e, sl: self.honest[e, sl])
        # audit_rate=1.0: an ACCEPTED fraudulent round is always caught
        if proofs:
            assert self.fraudulent[rid]
            assert self.proto.rounds[rid].phase is RoundPhase.CHALLENGED
        self.check()

    def do_drain(self) -> None:
        self.proto.drain_audits(self.clock)
        self.check()

    def do_grief(self, offset: int) -> None:
        """A lying verifier pass: recomputes against a WRONG tensor, so
        it challenges honest rounds (a fraudulent round's claimed output
        matches the bad tensor and audits clean).  The court later
        acquits — unless an ancestor's conviction tainted the round."""
        open_rounds = self.proto.pending()
        if not open_rounds:
            return
        rid = open_rounds[offset % len(open_rounds)]
        self.proto.run_audits(rid, lambda e, sl: self.bad[e, sl])
        self.check()

    def do_resolve(self) -> None:
        challenged = [rid for rid in self.proto.pending()
                      if self.proto.rounds[rid].phase
                      is RoundPhase.CHALLENGED]
        if not challenged:
            return
        rid = challenged[0]
        guilty = self.fraudulent[rid]
        before_open = set(self.proto.pending())
        state = self.proto.resolve(rid, Verdict(
            round_id=rid, trusted=self.honest,
            support=np.full(E, float(EDGES)),
            flags=np.ones((E, EDGES), np.int32), executor_guilty=guilty))
        if guilty:
            # everything open above the convicted round is doomed
            self.doomed |= {r for r in before_open if r > rid}
        else:
            # acquittal: ACCEPTED again, unless a rolled-back ancestor
            # tainted it — then it invalidates, never finalizes
            assert state.phase is (RoundPhase.INVALIDATED
                                   if rid in self.doomed
                                   else RoundPhase.ACCEPTED)
        self.check()

    def do_advance(self, dt: int) -> None:
        self.clock += dt
        challenged_before = {
            rid for rid in self.proto.pending()
            if self.proto.rounds[rid].phase is RoundPhase.CHALLENGED}
        done = self.proto.advance(self.clock)
        # a CHALLENGED round never finalizes via advance
        assert not set(done) & challenged_before
        self.check()

    # -------------------------------------------------------- invariants
    def check(self) -> None:
        proto = self.proto
        phases = {rid: s.phase for rid, s in proto.rounds.items()}
        n_fin = sum(p is RoundPhase.FINALIZED for p in phases.values())
        n_rb = sum(p is RoundPhase.ROLLED_BACK for p in phases.values())
        n_inv = sum(p is RoundPhase.INVALIDATED for p in phases.values())
        pending = proto.pending()
        # conservation: committed == finalized + rolled_back + pending
        # (rolled_back counts the convicted round AND the invalidated
        # descendants voided with it — both are undone state)
        assert proto.stats["committed"] == len(phases)
        assert proto.stats["committed"] == \
            n_fin + (n_rb + n_inv) + len(pending)
        assert proto.stats["finalized"] == n_fin
        assert proto.stats["rolled_back"] == n_rb
        assert proto.stats["invalidated"] == n_inv
        # one slash per convicted round, and stake stays in [0, initial]
        assert len(proto.stakes.events) == n_rb
        assert (proto.stakes.stake >= 0).all()
        assert proto.stakes.stake.max() <= proto.stakes.initial + 1e-9
        # pending(): deadline-ordered (== round-ordered) phase census
        assert pending == sorted(pending)
        assert set(pending) == {rid for rid, p in phases.items()
                                if p in (RoundPhase.ACCEPTED,
                                         RoundPhase.CHALLENGED)}
        # sequential finality: nothing finalizes past an open round
        finalized = [rid for rid, p in phases.items()
                     if p is RoundPhase.FINALIZED]
        if finalized and pending:
            assert max(finalized) < min(pending)
        # a round open at an ancestor's conviction never finalizes
        assert not self.doomed & set(finalized)
        # phases only move forward; terminal phases never change
        for rid, phase in phases.items():
            prev = self.last_phase.get(rid)
            if prev is not None:
                assert PHASE_RANK[phase] >= PHASE_RANK[prev]
                if prev in TERMINAL_PHASES:
                    assert phase is prev
            self.last_phase[rid] = phase

    def settle(self) -> None:
        """Close everything out, then re-check conservation at rest."""
        self.proto.drain_audits(None)
        for _ in range(self.next_rid + 1):
            self.do_resolve()
        self.do_advance(self.proto.cfg.challenge_window + self.next_rid)
        assert self.proto.pending() == []
        convicted = [rid for rid, f in self.fraudulent.items()
                     if self.proto.rounds[rid].phase
                     is RoundPhase.ROLLED_BACK]
        assert all(self.fraudulent[rid] for rid in convicted)


class ProtocolMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.model = ProtocolModel()

    @rule(fraud=st.booleans(), schedule=st.booleans())
    def commit(self, fraud, schedule):
        self.model.do_commit(fraud, schedule)

    @rule(offset=st.integers(min_value=0, max_value=7))
    def audit(self, offset):
        self.model.do_audit(offset)

    @rule(offset=st.integers(min_value=0, max_value=7))
    def grief(self, offset):
        self.model.do_grief(offset)

    @rule()
    def drain(self):
        self.model.do_drain()

    @rule()
    def resolve(self):
        self.model.do_resolve()

    @rule(dt=st.integers(min_value=0, max_value=3))
    def advance(self, dt):
        self.model.do_advance(dt)

    @invariant()
    def invariants(self):
        self.model.check()


TestProtocolMachine = ProtocolMachine.TestCase
TestProtocolMachine.settings = settings(max_examples=25,
                                        stateful_step_count=50,
                                        deadline=None)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_protocol_random_walk(seed):
    """Deterministic stand-in for the hypothesis machine: a seeded random
    interleaving of the same steps, invariant-checked at every step and
    settled at the end — runs even where hypothesis is not installed."""
    rng = random.Random(seed)
    model = ProtocolModel(window=rng.choice([0, 1, 2, 3]))
    steps = [
        lambda: model.do_commit(rng.random() < 0.3, rng.random() < 0.5),
        lambda: model.do_audit(rng.randrange(8)),
        lambda: model.do_grief(rng.randrange(8)),
        lambda: model.do_drain(),
        lambda: model.do_resolve(),
        lambda: model.do_advance(rng.randrange(4)),
    ]
    for _ in range(250):
        rng.choice(steps)()
    model.settle()


# --------------------------------------------- advance scaling regression
def test_advance_touches_only_open_rounds():
    """``advance``/``pending`` used to scan every historical round per
    call (O(rounds^2) over a run); the deadline heap keeps them O(open).
    Pins both the pending() contents and the bounded heap size."""
    proto = OptimisticProtocol(TrustConfig(challenge_window=3,
                                           audit_rate=0.0,
                                           num_verifiers=1), num_edges=4)
    outs = np.zeros((E, B, C), np.float32)
    for r in range(200):
        proto.commit(r, r % 4, outs)
        done = proto.advance(r)
        assert done == ([r - 3] if r >= 3 else [])
        # exactly the open window, deadline-ordered
        assert proto.pending() == list(range(max(0, r - 2), r + 1))
        # the heap holds only open rounds — advance never re-walks history
        assert len(proto._open_heap) <= 3
    assert proto.stats["finalized"] == 197


# ------------------------------------------------ ChallengeWindow edges
def test_challenge_window_revoke_after_expire_is_noop():
    win = ChallengeWindow(2)
    win.enter(1, now=0)
    assert win.expire(2) == [1]
    win.revoke(1)                      # already final: nothing to revoke
    assert win.revoked == [] and len(win) == 0


def test_challenge_window_duplicate_enter_refreshes_deadline():
    win = ChallengeWindow(3)
    win.enter(5, now=0)
    win.enter(5, now=2)                # re-commit: window restarts
    assert win.deadline(5) == 5
    assert win.expire(3) == []         # old deadline no longer applies
    assert win.expire(5) == [5]
    assert len(win) == 0


def test_challenge_window_expire_exactly_at_deadline_tick():
    win = ChallengeWindow(4)
    win.enter(9, now=10)
    assert win.expire(13) == []        # one tick early: still open
    assert win.expire(14) == [9]       # now == deadline: closes
    win.enter(7, now=20)
    win.revoke(7)                      # revoke before expiry sticks
    assert win.expire(24) == [] and win.revoked == [7]
