"""Pipelined optimistic rounds: chained rollback, scheduling/backend
determinism, the batch-inference pipeline, and serving-tick revocation.

The acceptance pin lives here: a fraud proof confirmed for round r AFTER
rounds r+1..r+k committed on the optimistic state rolls back the full
chain — state restored to the pre-r snapshot (bit-identical to a clean
twin after honest re-execution), the ledger records the rollback, and
exactly one slash is booked for round r.
"""
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.core.ledger import digest_tree
from repro.core.reputation import ReputationConfig
from repro.data.synthetic import FMNIST, make_image_dataset
from repro.trust.protocol import RoundPhase, TrustConfig


@pytest.fixture(scope="module")
def data():
    xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=1500, n_test=300,
                                            seed=0)
    return xtr.reshape(len(xtr), -1), ytr, xte.reshape(len(xte), -1), yte


def _system(attack, trust, seed=0):
    cfg = BMoEConfig(framework="optimistic", attack=attack, pow_difficulty=2,
                     reputation=ReputationConfig(init=0.5, gain=0.01,
                                                 slash=0.4,
                                                 exclusion_threshold=0.2),
                     trust=trust, seed=seed)
    return BMoESystem(cfg)


# ------------------------------------------------- chained rollback pin
def test_fraud_after_descendants_rolls_back_whole_chain(data):
    """Acceptance pin.  window=3 and a malicious edge 2: round 2's fraud
    is only drained at round 3 (round 0's deadline), AFTER round 3 has
    committed on the poisoned state.  The conviction must roll back the
    whole chain {2, 3}: snapshot restored + honest re-execution
    (bit-identical to a clean twin), rollback block in the ledger,
    exactly one slash — for round 2."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=3)
    s = _system(atk, trust)
    clean = _system(AttackConfig(), trust)
    rng = np.random.default_rng(0)
    digests, backlogs = [], []
    for idx in [rng.integers(0, len(xtr), 64) for _ in range(4)]:
        s.train_round(xtr[idx], ytr[idx])
        clean.train_round(xtr[idx], ytr[idx])
        digests.append((digest_tree(s.experts), digest_tree(clean.experts)))
        backlogs.append(s.protocol.audit_backlog())
    # rounds 0, 1: honest executors — trajectories identical
    assert digests[0][0] == digests[0][1] and digests[1][0] == digests[1][1]
    # round 2: the poisoned update went live (optimistic accept, audit
    # still queued — verification is off the critical path) and the
    # backlog only drained at round 3, in one burst
    assert digests[2][0] != digests[2][1]
    assert backlogs == [[0], [0, 1], [0, 1, 2], []]
    # round 3's drain convicted round 2 after descendant 3 had committed
    assert s.protocol.rounds[2].phase is RoundPhase.ROLLED_BACK
    assert s.protocol.rounds[3].phase is RoundPhase.INVALIDATED
    assert [(r.round_id, r.invalidated) for r in s.protocol.rollbacks] == \
        [(2, [3])]
    # exactly one slash, booked for round 2's executor
    assert [(ev.round_id, ev.edge) for ev in s.protocol.stakes.events] == \
        [(2, 2)]
    assert s.reputation.excluded[2]
    # the ledger records the rollback (and stays verifiable)
    blocks = s.ledger.rollbacks()
    assert len(blocks) == 1
    assert blocks[0].payload["rollback_of"] == 2
    assert blocks[0].payload["chain"] == [2, 3]
    assert blocks[0].payload["slashed"] == [2]
    assert s.ledger.verify_chain()
    # chain re-executed honestly from the pre-round-2 snapshot:
    # bit-identical to the clean twin
    assert digests[3][0] == digests[3][1]
    assert digest_tree(s.gate) == digest_tree(clean.gate)


def test_pipelined_rounds_commit_past_unaudited_ancestors(data):
    """The point of the pipeline: rounds r+1..r+w commit while round r's
    audit is still queued; backlogs drain in bursts; every round still
    reaches a terminal phase on flush."""
    xtr, ytr, _, _ = data
    s = _system(AttackConfig(),
                TrustConfig(audit_rate=0.3, challenge_window=4))
    rng = np.random.default_rng(0)
    backlog_sizes = []
    for idx in [rng.integers(0, len(xtr), 64) for _ in range(9)]:
        s.train_round(xtr[idx], ytr[idx])
        backlog_sizes.append(len(s.protocol.audit_backlog()))
    # the backlog grows between drains instead of emptying every round
    assert max(backlog_sizes) >= 4
    # drains are bursts: far fewer than one per round
    assert 1 <= s.protocol.stats["audit_drains"] <= 3
    s.flush_trust()
    assert s.protocol.pending() == [] and not s._round_ctx
    assert s.protocol.stats["finalized"] == 9


# ------------------------------------------------------- determinism
def _run(trust, atk, xtr, ytr, rounds=8, batch=64):
    s = _system(atk, trust)
    rng = np.random.default_rng(0)
    for idx in [rng.integers(0, len(xtr), batch) for _ in range(rounds)]:
        s.train_round(xtr[idx], ytr[idx])
    s.flush_trust()
    return s


def test_backend_determinism_batched_vs_eager(data):
    """Same TrustConfig.seed => identical audit plans (sampled leaves,
    lazy coins) and identical fraud verdicts under
    audit_backend="batched" vs "eager" — and an identical post-rollback
    model state."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                       noise_std=5.0)
    a = _run(TrustConfig(audit_rate=0.3, challenge_window=2,
                         audit_backend="batched"), atk, xtr, ytr)
    b = _run(TrustConfig(audit_rate=0.3, challenge_window=2,
                         audit_backend="eager"), atk, xtr, ytr)
    assert set(a.protocol.rounds) == set(b.protocol.rounds)
    for rid in a.protocol.rounds:
        ra, rb = a.protocol.rounds[rid], b.protocol.rounds[rid]
        assert [(r.verifier, r.sampled_leaves, r.lazy)
                for r in ra.reports] == \
               [(r.verifier, r.sampled_leaves, r.lazy) for r in rb.reports]
        assert [(p.leaf_index, p.expert) for p in ra.proofs] == \
               [(p.leaf_index, p.expert) for p in rb.proofs]
        assert ra.phase is rb.phase
    assert [(ev.round_id, ev.edge, ev.amount)
            for ev in a.protocol.stakes.events] == \
           [(ev.round_id, ev.edge, ev.amount)
            for ev in b.protocol.stakes.events]
    for k in ("committed", "finalized", "rolled_back", "invalidated",
              "fraud_proofs"):
        assert a.protocol.stats[k] == b.protocol.stats[k], k
    assert digest_tree(a.experts) == digest_tree(b.experts)


def test_scheduling_determinism_pipelined_vs_synchronous(data):
    """Same seed => identical audit lotteries (keyed by round id, not by
    drain time) and identical fraud verdicts under pipelined vs
    synchronous scheduling; after settlement the model states agree
    bit-for-bit (the chained replay reproduces the synchronous
    trajectory)."""
    xtr, ytr, _, _ = data
    # a single fraud opportunity: executor rotation diverges between the
    # schedules only after a conviction shifts the eligible set, so keep
    # one malicious edge that both schedules see exactly once
    atk = AttackConfig(malicious_edges=(3,), attack_prob=1.0, noise_std=5.0)
    p = _run(TrustConfig(audit_rate=0.5, challenge_window=2,
                         scheduling="pipelined"), atk, xtr, ytr, rounds=6)
    q = _run(TrustConfig(audit_rate=0.5, challenge_window=2,
                         scheduling="synchronous"), atk, xtr, ytr, rounds=6)
    for rid in range(6):
        assert [(r.verifier, r.sampled_leaves)
                for r in p.protocol.rounds[rid].reports] == \
               [(r.verifier, r.sampled_leaves)
                for r in q.protocol.rounds[rid].reports]
    for s_ in (p, q):
        assert [(ev.round_id, ev.edge)
                for ev in s_.protocol.stakes.events] == [(3, 3)]
        assert s_.protocol.rounds[3].phase is RoundPhase.ROLLED_BACK
    # the pipelined run invalidated round 3's descendants; the
    # synchronous one settled round 3 before round 4 existed
    assert p.protocol.stats["invalidated"] > 0
    assert q.protocol.stats["invalidated"] == 0
    assert digest_tree(p.experts) == digest_tree(q.experts)
    assert digest_tree(p.gate) == digest_tree(q.gate)


# ------------------------------------------------- inference pipeline
def test_optimistic_infer_commits_audits_and_slashes(data):
    """Batch inference runs the same commit-challenge-audit pipeline on
    its own round clock: a cheating executor is convicted and slashed
    (shared stake book — it leaves the training rotation too), while
    independent clean batches still finalize (inference rounds do not
    chain: weights are frozen)."""
    xtr, ytr, xte, _ = data
    atk = AttackConfig(malicious_edges=(0,), attack_prob=1.0, noise_std=5.0)
    s = _system(atk, TrustConfig(audit_rate=1.0, num_verifiers=1,
                                 challenge_window=2))
    x = xte[:64]
    bad_logits, _, _ = s.infer(x, attack=atk)       # executor 0 cheats
    good_logits, _, _ = s.infer(x, attack=AttackConfig())
    # the optimistic view returned round 0's corrupted aggregate
    assert not np.allclose(bad_logits, good_logits)
    assert [e["event"] for e in s.infer_log[:2]] == ["commit", "commit"]
    assert s.pending_inference() == [0, 1]
    out = s.flush_trust()
    # round 0 convicted: revoked + slashed; round 1 clean: finalized
    assert s._infer_protocol.rounds[0].phase is RoundPhase.ROLLED_BACK
    assert s._infer_protocol.rounds[1].phase is RoundPhase.FINALIZED
    assert out["infer_finalized"] == [1]
    assert s.pending_inference() == []
    assert [(ev.round_id, ev.edge) for ev in s.protocol.stakes.events] == \
        [(0, 0)]
    assert any(e["event"] == "revoke" and e["round"] == 0
               for e in s.infer_log)
    rb = s.ledger.rollbacks()
    assert len(rb) == 1 and rb[0].payload["domain"] == "infer"
    # the shared stake book bars the convicted executor from BOTH
    # rotations from now on
    assert s.reputation.excluded[0]
    assert s._infer_protocol.pick_executor(2) != 0
    assert s.protocol.pick_executor(0) != 0


# --------------------------------------------------- serving pipeline
def _tiny_engine(**kw):
    from repro.configs import get_config
    from repro.serve.engine import ServingEngine
    from repro.train.loop import init_model
    cfg = get_config("smollm-360m", smoke=True)
    params = init_model(cfg, seed=0)
    return ServingEngine(cfg, params, batch_slots=2, cache_len=64, **kw)


def test_serving_dependent_revocation(data):
    """A revoked session revokes its co-batched (tick-overlapping)
    in-window neighbours — the serving analogue of the training chain
    rollback — while non-overlapping batches finalize untouched.

    Batch-synchronous (fixed) scheduling: the pair structure this test
    asserts — requests (2, 3) sharing no ticks with (0, 1) — only holds
    when admission waits for the whole batch to drain.  Continuous
    admission deliberately overlaps them (and chains the revocation
    further); that is covered in tests/test_serving.py."""
    from repro.data.synthetic import serving_requests
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                        challenge_window=60)
    eng = _tiny_engine(trust=trust, scheduling="fixed")
    reqs = list(serving_requests(eng.cfg.vocab_size, 4, max_prompt=6,
                                 max_new=4, seed=3))
    eng.submit(reqs)
    while len(eng._done) < 4 and eng.step():
        pass
    assert eng.completed == {}                   # all windows still open
    pair1 = [reqs[0]["id"], reqs[1]["id"]]
    pair2 = [reqs[2]["id"], reqs[3]["id"]]
    rec = eng.records[pair1[0]]
    rec.tokens = [t ^ 1 for t in rec.tokens]     # executor alters stream
    rep = eng.audit_session(pair1[0])
    assert rep["revoked"]
    assert eng.records[pair1[1]].revoked         # same batch ticks: voided
    assert not eng.records[pair2[0]].revoked     # later batch: untouched
    assert not eng.records[pair2[1]].revoked
    assert any(e["event"] == "revoke_dependent"
               and e["cause"] == pair1[0] for e in eng.session_log)
    done = eng.run()
    assert set(done) == set(pair2)


def test_serving_finality_waits_for_overlapping_streams():
    """Serving-side sequential finality: a short stream whose window
    expires while a co-batched longer stream is still generating (or is
    sealed but unchecked) must not finalize until that neighbour is
    audited — if the neighbour was tampered, both are revoked."""
    from repro.data.synthetic import serving_requests
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=6)
    eng = _tiny_engine(trust=trust)
    short, long_ = list(serving_requests(eng.cfg.vocab_size, 2,
                                         max_prompt=6, max_new=3, seed=5))
    short["max_new_tokens"] = 1
    long_["max_new_tokens"] = 24                 # outlives short's window
    eng.submit([short, long_])
    eng.step()                                   # fills slots + records
    while len(eng.records[long_["id"]].tokens) < 4 and eng.step():
        pass
    # short finished and its window expired, but its co-batched
    # neighbour is still streaming: deferred, not finalized
    assert short["id"] in eng._done
    assert short["id"] not in eng.completed
    rec = eng.records[long_["id"]]
    rec.tokens[:2] = [t ^ 1 for t in rec.tokens[:2]]   # tamper mid-stream
    done = eng.run()
    # at seal, the deferred neighbour forces long_'s audit: the fraud is
    # confirmed and voids BOTH streams — short never finalizes on top of
    # a corrupted co-batched stream
    assert done == {}
    assert eng.records[long_["id"]].revoked
    assert eng.records[short["id"]].revoked


def test_serving_auto_audit_blocks_tampered_finalization():
    """Audits drain off the critical path at the window deadline: a
    stream tampered inside its window never finalizes, with no manual
    audit call."""
    from repro.data.synthetic import serving_requests
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                        challenge_window=30)
    eng = _tiny_engine(trust=trust)
    reqs = list(serving_requests(eng.cfg.vocab_size, 2, max_prompt=6,
                                 max_new=4, seed=4))
    eng.submit(reqs)
    while len(eng._done) < 2 and eng.step():
        pass
    rid = reqs[0]["id"]
    eng.records[rid].tokens = [t ^ 1 for t in eng.records[rid].tokens]
    done = eng.run()                             # deadline audit catches it
    assert eng.records[rid].revoked and rid not in done
    # co-batched neighbour revoked with it (shared decode ticks)
    assert reqs[1]["id"] not in done
    assert any(e["event"] == "revoke" and e["request"] == rid
               for e in eng.session_log)
