"""Property tests (hypothesis) for the MoE router invariants."""
import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.moe import capacity_for, route

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(B=st.integers(1, 3), S=st.sampled_from([4, 16, 33]),
       E=st.sampled_from([4, 8, 10]), k=st.integers(1, 3),
       cap=st.sampled_from([1, 4, 64]), seed=st.integers(0, 3))
def test_route_invariants(B, S, E, k, cap, seed):
    k = min(k, E)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (B, S, E))
    weights, expert_id, position, keep, aux = route(logits, k, cap)
    w, eid = np.asarray(weights), np.asarray(expert_id)
    pos, kp = np.asarray(position), np.asarray(keep)

    # weights: renormalized over selected experts, nonnegative
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)
    assert (w >= 0).all()
    # expert ids valid; top-k unique per token
    assert (eid >= 0).all() and (eid < E).all()
    for b in range(B):
        for s in range(S):
            assert len(set(eid[b, s])) == k
    # kept slots fit capacity; (expert, position) unique per row
    assert (pos[kp] < cap).all()
    for b in range(B):
        pairs = [(int(e), int(p)) for e, p, kk in
                 zip(eid[b].ravel(), pos[b].ravel(), kp[b].ravel()) if kk]
        assert len(pairs) == len(set(pairs))
    # aux is a finite positive scalar near 1 for balanced random logits
    assert np.isfinite(float(aux)) and float(aux) > 0


@settings(**SETTINGS)
@given(E=st.sampled_from([8, 16]), real=st.integers(2, 7),
       seed=st.integers(0, 3))
def test_route_never_selects_padded_expert(E, real, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, E)) * 3
    _, expert_id, _, _, _ = route(logits, 2, 8, num_real=real)
    assert int(np.asarray(expert_id).max()) < real


def test_capacity_for_bounds():
    from repro.configs import get_config
    cfg = get_config("qwen2-moe-a2.7b")
    c = capacity_for(cfg, 4096)
    assert c % 8 == 0
    assert c >= 4096 * cfg.num_experts_per_tok // cfg.num_experts
    # degenerate: single token still gets a slot
    assert capacity_for(cfg, 1) >= 1
