"""Expert-parallel edge cases (PR: mesh B-MoE rounds).

Pins the fixes that unblocked mesh execution of the B-MoE round loop:

- ragged token counts (``T_full % msize != 0``) pad the token axis and
  route pad rows to the sentinel expert, instead of the old fallback
  that dispatched every token from every model shard (msize-duplicate
  wire bytes and expert FLOPs);
- the router aux loss reduces the same psum'd global statistics whether
  or not the token axis is ragged (the old per-shard pmean disagreed
  between the msplit==1 and msplit>1 regimes);
- shared experts vote over the replica axis like routed buckets (they
  used to bypass ``_ep_vote`` entirely — a tampered shared expert was
  invisible to redundancy voting);
- ``launch.mesh`` factories derive widths from the live device count
  instead of hardcoding 16-device pods.

Host-side tests cover ``route_masked``; everything touching a mesh runs
in a forced-device subprocess (see conftest.run_with_devices).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_with_devices
from repro.models.moe import route, route_masked


# --------------------------------------------------------- route_masked
def test_route_masked_matches_route_when_unmasked():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 8))
    w0, e0, p0, k0, _ = route(logits, 2, 4, 8)
    w1, e1, p1, k1, stats = route_masked(logits, 2, 4, 8)
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
    np.testing.assert_array_equal(np.asarray(e0), np.asarray(e1))
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(k0), np.asarray(k1))
    assert float(stats[2]) == 2 * 16                     # every token valid


def test_route_masked_pad_rows_are_inert():
    """Pad rows get the sentinel expert id (== num_experts), zero
    weight, no capacity slot, and are excluded from the routing stats —
    so they consume no capacity, no wire bytes, and no aux mass."""
    E, T, k = 4, 6, 2
    logits = jax.random.normal(jax.random.PRNGKey(1), (1, T, E))
    valid = jnp.asarray([[True, True, True, True, False, False]])
    w, eid, pos, keep, stats = route_masked(logits, k, 2, E, valid=valid)
    assert np.all(np.asarray(eid)[0, 4:] == E)           # sentinel id
    assert np.all(np.asarray(w)[0, 4:] == 0.0)
    assert not np.any(np.asarray(keep)[0, 4:])           # no bucket slot
    assert float(stats[2]) == 4.0                        # n_valid
    # stats must match routing only the valid prefix
    _, _, _, _, ref = route_masked(logits[:, :4], k, 2, E)
    np.testing.assert_allclose(np.asarray(stats[0]), np.asarray(ref[0]))
    np.testing.assert_allclose(np.asarray(stats[1]), np.asarray(ref[1]),
                               rtol=1e-6)


def test_route_masked_pad_rows_do_not_steal_capacity():
    """A pad row routed (pre-mask) to a popular expert must not occupy
    one of its capacity slots: real assignments keep their positions."""
    E, k = 2, 1
    logits = jnp.zeros((1, 4, E)).at[:, :, 0].set(5.0)   # all pick expert 0
    valid = jnp.asarray([[True, False, True, True]])
    _, eid, pos, keep, _ = route_masked(logits, k, 2, E, valid=valid)
    eid, pos, keep = (np.asarray(a)[0, :, 0] for a in (eid, pos, keep))
    assert eid[1] == E and not keep[1]
    # real rows 0, 2, 3 contend for 2 slots of expert 0: first two fit
    assert keep[0] and keep[2] and not keep[3]
    assert {pos[0], pos[2]} == {0, 1}


# ------------------------------------------------ ragged EP dispatch
def test_ep_ragged_tokens_match_oracle(repo_src):
    """T_full % msize != 0 (the seq length makes each data shard hold 60
    tokens on a 4-wide model axis): the padded token path must still
    match the single-device GSPMD oracle, aux included."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep")
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = logical_rules(mesh, cfg)
        for S in (31, 7):
            x = jax.random.normal(jax.random.fold_in(key, S),
                                  (4, S, cfg.d_model))
            assert (2 * S) % 4 != 0, S          # genuinely ragged per shard
            y_ref, aux_ref = moe_lib.moe_mlp(params, x, cfg)
            with mesh:
                y_ep, aux_ep = jax.jit(lambda p, x: moe_mlp_ep(
                    p, x, cfg, mesh, rules, fsdp=False))(params, x)
            np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                       rtol=3e-3, atol=3e-3)
            assert abs(float(aux_ep) - float(aux_ref)) < 1e-3, S
            print("RAGGED OK", S, float(aux_ep))
    """, 8, repo_src)
    assert out.count("RAGGED OK") == 2


def test_ep_ragged_wire_bytes_parity(repo_src):
    """Regression for the old ragged fallback, which dispatched the FULL
    token set from every model shard (msize x wire bytes, msize x expert
    FLOPs).  The padded path's collective bytes for a ragged 31-token
    seq must stay within 1.25x of the even 32-token compile — not ~4x."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.launch import hloanalysis
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep")
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = logical_rules(mesh, cfg)
        def bytes_for(S):
            x = jax.ShapeDtypeStruct((4, S, cfg.d_model), jnp.float32)
            with mesh:
                txt = jax.jit(lambda p, xx: moe_mlp_ep(
                    p, xx, cfg, mesh, rules, fsdp=False)
                ).lower(params, x).compile().as_text()
            return hloanalysis.analyze(txt)["total_collective_bytes"]
        ragged, even = bytes_for(31), bytes_for(32)
        assert even > 0
        assert ragged <= even * 1.25, (ragged, even)
        print("WIRE PARITY OK", ragged, even)
    """, 8, repo_src)
    assert "WIRE PARITY OK" in out


def test_ep_tiny_token_count(repo_src):
    """Decode-shaped inputs (fewer tokens than model shards): capacity
    still >= 1, pad rows stay inert, output matches the oracle."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep")
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        rules = logical_rules(mesh, cfg)
        for B, S in ((1, 1), (2, 1), (1, 3)):   # T_full < msize or ragged
            x = jax.random.normal(jax.random.fold_in(key, 10 * B + S),
                                  (B, S, cfg.d_model))
            y_ref, aux_ref = moe_lib.moe_mlp(params, x, cfg)
            with mesh:
                y_ep, aux_ep = jax.jit(lambda p, x: moe_mlp_ep(
                    p, x, cfg, mesh, rules, fsdp=False))(params, x)
            np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                                       rtol=3e-3, atol=3e-3)
            assert abs(float(aux_ep) - float(aux_ref)) < 1e-3, (B, S)
            print("TINY OK", B, S)
    """, 8, repo_src)
    assert out.count("TINY OK") == 3


# --------------------------------------------------- consensus modes
def test_ep_digest_vote_agrees_with_faithful_when_honest(repo_src):
    """With no attacker the cheap digest vote must select exactly the
    outputs the faithful full-tensor vote selects."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.models.config import RedundancyConfig
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep")
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 16, cfg.d_model))
        mesh = jax.make_mesh((1, 2, 4), ("data", "replica", "model"))
        rules = logical_rules(mesh, cfg)
        ys = {}
        for mode in ("faithful", "digest"):
            tcfg = dataclasses.replace(
                cfg, redundancy=RedundancyConfig(2, mode))
            with mesh:
                ys[mode], _ = jax.jit(lambda p, x: moe_mlp_ep(
                    p, x, tcfg, mesh, rules, fsdp=False))(params, x)
        np.testing.assert_allclose(np.asarray(ys["digest"]),
                                   np.asarray(ys["faithful"]),
                                   rtol=1e-5, atol=1e-6)
        print("HONEST AGREEMENT OK")
    """, 8, repo_src)
    assert "HONEST AGREEMENT OK" in out


def test_ep_shared_expert_tamper_covered_by_vote(repo_src):
    """Shared experts used to run outside the shard_map and skip
    ``_ep_vote`` — a tampered shared expert was invisible to redundancy
    voting.  Now (a) a minority attacker's tampering of the shared rows
    is repaired, and (b) a majority coalition corrupts the SHARED
    component too (isolated by differencing runs with and without the
    shared expert): the shared path demonstrably flows through the
    vote."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.core.trusted_moe import LMAttack
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_mlp_ep
        from repro.models.builder import materialize
        from repro.models.config import RedundancyConfig
        from repro.sharding import logical_rules
        cfg = get_config("qwen2-moe-a2.7b", smoke=True)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0,
                                  padded_num_experts=4, moe_impl="ep",
                                  redundancy=RedundancyConfig(2, "faithful"))
        assert cfg.num_shared_experts >= 1
        no_sh = dataclasses.replace(cfg, num_shared_experts=0)
        key = jax.random.PRNGKey(0)
        params = materialize(moe_lib.moe_decl(cfg), key)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 16, cfg.d_model))
        mesh = jax.make_mesh((1, 2, 4), ("data", "replica", "model"))
        rules = logical_rules(mesh, cfg)
        def run(c, attack):
            with mesh:
                y, _ = jax.jit(lambda p, x: moe_mlp_ep(
                    p, x, c, mesh, rules, fsdp=False,
                    attack=attack))(params, x)
            return np.asarray(y)
        minority = LMAttack(malicious_replicas=(1,), noise_std=4.0)
        majority = LMAttack(malicious_replicas=(0, 1), noise_std=4.0)
        clean = run(cfg, None)
        np.testing.assert_allclose(run(cfg, minority), clean,
                                   rtol=1e-5, atol=1e-5)
        print("MINORITY REPAIRED")
        # shared contribution under majority collusion: y(with shared) -
        # y(routed only) must no longer equal the clean shared output
        sh_corrupt = run(cfg, majority) - run(no_sh, majority)
        sh_clean = clean - run(no_sh, None)
        assert not np.allclose(sh_corrupt, sh_clean, atol=1e-4)
        print("MAJORITY REACHES SHARED")
    """, 8, repo_src)
    assert "MINORITY REPAIRED" in out and "MAJORITY REACHES SHARED" in out


# --------------------------------------------------- mesh factories
def test_mesh_factories_derive_widths_from_device_count(repo_src):
    """launch.mesh used to assume 16x16 pods; the trusted/host/edge
    factories must now fold whatever jax.devices() reports."""
    out = run_with_devices("""
        import jax, pytest
        from repro.launch.mesh import (make_edge_mesh, make_host_mesh,
                                       make_trusted_mesh)
        def shape(m):
            return dict(zip(m.axis_names, m.devices.shape))
        m = make_trusted_mesh(2)
        assert shape(m) == {"data": 1, "replica": 2, "model": 4}, shape(m)
        m = make_trusted_mesh(4)
        assert shape(m) == {"data": 1, "replica": 4, "model": 2}, shape(m)
        with pytest.raises(ValueError):
            make_trusted_mesh(3)                 # 8 % 3 != 0
        m = make_host_mesh()
        assert shape(m) == {"data": 1, "model": 8}
        m = make_host_mesh(num_experts=6)        # widest divisor of both
        assert shape(m) == {"data": 4, "model": 2}, shape(m)
        m = make_edge_mesh(8)
        assert shape(m) == {"data": 1, "model": 8}
        m = make_edge_mesh(6)
        assert shape(m) == {"data": 4, "model": 2}, shape(m)
        m = make_edge_mesh(8, shards=4)
        assert shape(m) == {"data": 2, "model": 4}
        with pytest.raises(ValueError):
            make_edge_mesh(8, shards=3)          # 8 devices % 3 != 0
        with pytest.raises(ValueError):
            make_edge_mesh(6, shards=4)          # 6 experts % 4 != 0
        print("MESH FACTORIES OK")
    """, 8, repo_src)
    assert "MESH FACTORIES OK" in out
