"""KV-block storage: seal -> chunk -> put -> fetch -> restore must be
bit-identical (fp32 AND int8, scales included), and prefix-hash CID
chaining must dedup equal prefixes while diverging from the first
differing block on.

Property tests run under hypothesis when installed (see requirements-
dev.txt); deterministic seeded variants of every property always run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.builder import materialize
from repro.models.transformer import (cache_decl, check_kv_pageable,
                                      restore_kv_block, slice_kv_block)
from repro.storage import (KV_GENESIS, ExpertCache, ExpertStore,
                           KVBlockStore, StorageNetwork, prefix_chain,
                           prefix_cid)

ARCH = "smollm-360m"


# ------------------------------------------------------------ fixtures
def _kv_store(chunk_bytes=1 << 12, seed=0):
    net = StorageNetwork(num_nodes=4, replication=2, seed=seed)
    store = ExpertStore(net, chunk_bytes=chunk_bytes)
    return KVBlockStore(store, ExpertCache(store, None))


def _random_caches(cfg, batch, cache_len, seed=0):
    """A materialized decode cache with every leaf filled with random
    values of its own dtype (int8 K/V rows + f32 scale rows under
    ``kv_cache_dtype="int8"``)."""
    caches = materialize(cache_decl(cfg, batch, cache_len),
                         jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)

    def fill(a):
        a = np.asarray(a)
        if np.issubdtype(a.dtype, np.integer):
            return rng.integers(-127, 128, a.shape).astype(a.dtype)
        return rng.normal(size=a.shape).astype(a.dtype)

    return jax.tree_util.tree_map(lambda a: jnp.asarray(fill(a)), caches)


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = {jax.tree_util.keystr(k): v
          for k, v in jax.tree_util.tree_leaves_with_path(b)}
    assert len(la) == len(lb)
    for k, x in la:
        y = lb[jax.tree_util.keystr(k)]
        assert np.asarray(x).dtype == np.asarray(y).dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------- seal/fetch round trip
@pytest.mark.parametrize("kv_dtype", ["fp32", "int8"])
def test_seal_fetch_restore_round_trip_bit_identical(kv_dtype):
    """A sealed block survives chunking, the replicated network, and
    cache-mediated fetch bit-for-bit — including the int8 scale leaves —
    and restores into exactly the rows it was sliced from."""
    cfg = get_config(ARCH, smoke=True)
    if kv_dtype == "int8":
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    caches = _random_caches(cfg, batch=2, cache_len=24, seed=3)
    block = slice_kv_block(caches, slot=1, start=4, end=12)
    if kv_dtype == "int8":
        ks = {jax.tree_util.keystr(k)
              for k, _ in jax.tree_util.tree_leaves_with_path(block)}
        assert any("k_scale" in k for k in ks)       # scales ride along
        assert any(np.asarray(v).dtype == np.int8
                   for _, v in jax.tree_util.tree_leaves_with_path(block))

    kv = _kv_store()
    cid = prefix_cid(KV_GENESIS, np.arange(8))
    man = kv.seal(cid, block, 8)
    assert cid in kv and man.total_bytes > 0
    like = slice_kv_block(caches, 0, 0, 1)           # structure-only
    back = kv.fetch(cid, like)
    _assert_trees_equal(block, back)

    zeros = materialize(cache_decl(cfg, 2, 24), jax.random.PRNGKey(0))
    restored = restore_kv_block(zeros, 1, 4, back)
    _assert_trees_equal(block, slice_kv_block(restored, 1, 4, 12))
    # nothing outside the target rows was touched
    for a in jax.tree_util.tree_leaves(restored["blocks"]):
        a = np.asarray(a)
        assert not a[:, 0].any()                     # other slot untouched
        assert not a[:, 1, :4].any() and not a[:, 1, 12:].any()
    if "remainder" in restored:
        for a in jax.tree_util.tree_leaves(restored["remainder"]):
            a = np.asarray(a)
            assert not a[0].any()
            assert not a[1, :4].any() and not a[1, 12:].any()


def test_seal_dedup_is_a_noop_and_warm_prefix_counts():
    """Re-sealing a known CID books a dedup (no new store version); the
    warm-prefix probe counts exactly the leading sealed run."""
    cfg = get_config(ARCH, smoke=True)
    caches = _random_caches(cfg, 1, 40, seed=5)
    kv = _kv_store()
    chain = prefix_chain(np.arange(32), 8)           # 4 full blocks
    for b in range(2):                               # seal blocks 0..1
        kv.seal(chain[b], slice_kv_block(caches, 0, b * 8, (b + 1) * 8), 8)
    versions = kv.store.stats["versions"]
    kv.seal(chain[0], None, 0)                       # dedup: block untouched
    assert kv.stats["dedup_blocks"] == 1
    assert kv.store.stats["versions"] == versions
    assert kv.stats["sealed_blocks"] == 2
    assert kv.warm_prefix(chain[:3]) == 2            # run breaks at block 2
    assert kv.stats["warm_hits"] == 2
    assert kv.stats["warm_misses"] == 1
    assert kv.warm_prefix(chain[:2]) == 2            # fully sealed: no miss
    assert kv.stats["warm_misses"] == 1
    assert kv.sealed_cids() == sorted(chain[:2])
    assert set(kv.manifests(chain[:3])) == \
        {KVBlockStore.object_id(c) for c in chain[:2]}


# ----------------------------------------- prefix chains (deterministic)
def test_prefix_chain_equal_prefixes_share_cids():
    """Two token streams sharing a prefix derive IDENTICAL CIDs for
    every full block inside the shared region — the dedup invariant."""
    rng = np.random.default_rng(0)
    shared = rng.integers(0, 1000, 37)
    a = np.concatenate([shared, rng.integers(0, 1000, 11)])
    b = np.concatenate([shared, rng.integers(0, 1000, 19)])
    for T in (1, 4, 8, 16):
        ca, cb = prefix_chain(a, T), prefix_chain(b, T)
        n_shared = len(shared) // T
        assert ca[:n_shared] == cb[:n_shared]
        # the first block crossing the divergence point differs (tails
        # are distinct with overwhelming probability under this seed)
        if len(ca) > n_shared and len(cb) > n_shared:
            assert ca[n_shared] != cb[n_shared]


def test_prefix_chain_divergence_propagates_from_first_differing_block():
    """Flipping ONE token makes every block from its block index on
    diverge — and every earlier block keep its CID."""
    rng = np.random.default_rng(1)
    toks = rng.integers(0, 1000, 48)
    T = 8
    base = prefix_chain(toks, T)
    for j in (0, 7, 8, 23, 47):
        mut = toks.copy()
        mut[j] += 1
        chain = prefix_chain(mut, T)
        pivot = j // T
        assert chain[:pivot] == base[:pivot]
        assert all(chain[b] != base[b] for b in range(pivot, len(base)))


def test_prefix_cid_binds_token_count():
    """A tail block over a PREFIX of a full block's tokens never
    collides with the full block (int64 encoding binds the count), and
    the chain only ever contains full blocks."""
    toks = np.arange(16)
    full = prefix_cid(KV_GENESIS, toks[:8])
    for k in range(1, 8):
        assert prefix_cid(KV_GENESIS, toks[:k]) != full
    assert len(prefix_chain(toks[:15], 8)) == 1      # partial tail excluded
    assert prefix_chain(toks[:7], 8) == []
    assert prefix_chain(toks, 8) == [full, prefix_cid(full, toks[8:])]


# --------------------------------------------- prefix chains (hypothesis)
@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=64),
       st.integers(min_value=1, max_value=8))
def test_chain_covers_exactly_the_full_blocks(tokens, block_tokens):
    chain = prefix_chain(tokens, block_tokens)
    assert len(chain) == len(tokens) // block_tokens
    assert len(set(chain)) == len(chain)             # chained: all distinct


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=0, max_size=48),
       st.lists(st.integers(0, 10_000), min_size=0, max_size=16),
       st.lists(st.integers(0, 10_000), min_size=0, max_size=16),
       st.integers(min_value=1, max_value=8))
def test_equal_prefixes_imply_equal_cids(shared, tail_a, tail_b, T):
    ca = prefix_chain(list(shared) + list(tail_a), T)
    cb = prefix_chain(list(shared) + list(tail_b), T)
    n = len(shared) // T
    assert ca[:n] == cb[:n]


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=48),
       st.integers(min_value=0, max_value=47),
       st.integers(min_value=1, max_value=8))
def test_one_token_divergence_diverges_from_that_block_on(tokens, j, T):
    j = j % len(tokens)
    mut = list(tokens)
    mut[j] += 1
    base, chain = prefix_chain(tokens, T), prefix_chain(mut, T)
    pivot = j // T
    assert chain[:pivot] == base[:pivot]
    assert all(chain[b] != base[b] for b in range(pivot, len(base)))


# ------------------------------------------------------------ validation
def test_non_attn_configs_are_rejected():
    """Paging needs row-addressable caches: a config with a local_attn
    (ring-window) layer is rejected up front."""
    check_kv_pageable(get_config(ARCH, smoke=True))  # dense attn: fine
    with pytest.raises(ValueError, match="local_attn"):
        check_kv_pageable(get_config("gemma3-27b", smoke=True))
