"""Property tests for the federated subsystem (hypothesis).

- The aggregation rule conserves parameter mass: over ANY received
  subset of edges, the mixing coefficients are a convex combination
  (sum to 1 over the accepted set), so the aggregated delta never
  leaves the convex hull of the accepted clipped deltas.
- The round clock never deadlocks: whatever straggler/dropout/eviction
  draw the adversary gets, N run_round() calls advance the clock N
  times.
- Honest runs are bit-deterministic: identically-seeded coordinators
  produce identical aggregation roots and identical global parameters.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.synthetic import FMNIST, make_image_dataset
from repro.fed import FedConfig, FedCoordinator, aggregate, tree_to_flat


def _delta(rng, scale=1.0):
    return {"w": (scale * rng.normal(size=(6, 4))).astype(np.float32),
            "b": (scale * rng.normal(size=(4,))).astype(np.float32)}


BASE = {"w": np.zeros((6, 4), np.float32), "b": np.zeros(4, np.float32)}


# ------------------------------------------------ conservation of mass
@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.integers(1, 8),
       st.sampled_from(["fedavg", "defended"]),
       st.floats(0.2, 50.0))
def test_aggregation_is_convex_over_any_received_subset(seed, m, rule,
                                                        scale):
    """Whatever subset arrives (any size, any scales), the coefficients
    returned sum to 1 over the accepted set and the aggregated delta is
    inside the convex hull of the accepted clipped deltas."""
    rng = np.random.default_rng(seed)
    deltas = [_delta(rng, scale=float(rng.uniform(0.1, scale)))
              for _ in range(m)]
    weights = [int(rng.integers(1, 500)) for _ in range(m)]
    new, info = aggregate(BASE, deltas, weights, rule=rule)
    if info.accepted:
        assert sum(info.coeffs) == pytest.approx(1.0, abs=1e-9)
        assert all(c >= 0 for c in info.coeffs)
        # convex hull bound: ||agg delta|| <= max accepted clipped norm
        agg = tree_to_flat(new).astype(np.float64)
        clipped_norms = [info.norms[i] * info.clip[i]
                         for i in info.accepted]
        assert np.linalg.norm(agg) <= max(clipped_norms) + 1e-6
    else:
        # everyone screened out: the round is a no-op, not a crash
        np.testing.assert_array_equal(tree_to_flat(new),
                                      tree_to_flat(BASE))
    assert set(info.accepted) | set(info.rejected) == set(range(m))
    assert not set(info.accepted) & set(info.rejected)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6))
def test_identical_deltas_aggregate_to_exactly_one_delta(seed, m):
    """m copies of the same delta must average back to that delta —
    the mass-conservation fixed point (no inflation with quorum size)."""
    rng = np.random.default_rng(seed)
    d = _delta(rng)
    new, info = aggregate(BASE, [d] * m, [7] * m, rule="defended")
    assert info.accepted == list(range(m))
    np.testing.assert_allclose(tree_to_flat(new), tree_to_flat(d),
                               rtol=1e-6, atol=1e-7)


# --------------------------------------------------- round clock safety
@pytest.fixture(scope="module")
def tiny_data():
    return make_image_dataset(FMNIST, n_train=400, n_test=100, seed=0)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 50),
       st.floats(0.0, 0.9),
       st.floats(0.0, 0.6),
       st.integers(1, 3),
       st.integers(1, 4))
def test_round_clock_never_deadlocks(tiny_data, seed, straggler_prob,
                                     dropout_prob, evict_after,
                                     min_quorum):
    """N run_round() calls advance the clock N times under any
    straggler/dropout/eviction draw — late or missing edges can make a
    round a no-op, never a stall."""
    x, y, *_ = tiny_data
    cfg = FedConfig(num_edges=4, num_experts=4, hidden=8, local_steps=1,
                    local_batch=16, seed=seed, verify="off",
                    straggler_prob=straggler_prob,
                    dropout_prob=dropout_prob, evict_after=evict_after,
                    min_quorum=min_quorum)
    co = FedCoordinator(cfg, x, y)
    for expect in range(1, 4):
        co.run_round()
        assert co.round == expect
    rep = co.obs_report()
    assert rep["fed"]["rounds"] == 3
    assert len(co.ledger.aggregations()) == 3   # one block per round,
    assert co.ledger.verify_chain()             # quorum no-ops included


# ------------------------------------------------------- bit determinism
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 20))
def test_honest_runs_bit_identical_across_seeds(tiny_data, seed):
    """Two identically-seeded honest runs: identical aggregation roots
    on-chain, identical finalization verdicts, identical parameters."""
    x, y, *_ = tiny_data

    def run():
        cfg = FedConfig(num_edges=4, num_experts=4, hidden=8,
                        local_steps=1, local_batch=16, seed=seed)
        co = FedCoordinator(cfg, x, y)
        for _ in range(3):
            co.run_round()
        co.flush_trust()
        roots = [b.payload["agg_root"] for b in co.ledger.aggregations()]
        phases = [co.protocol.rounds[r].phase.name for r in range(3)]
        flat = tree_to_flat(co.global_params)
        return roots, phases, flat

    ra, pa, fa = run()
    rb, pb, fb = run()
    assert ra == rb
    assert pa == pb == ["FINALIZED"] * 3
    np.testing.assert_array_equal(fa, fb)
