"""Substrate tests: optimizer, data pipeline, checkpointing, serving
engine, builder, attacks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.attacks import AttackConfig, round_attack_mask
from repro.data.synthetic import CIFAR10, FMNIST, lm_batches, make_image_dataset, serving_requests
from repro.models.builder import Leaf, abstract, count_params, materialize, partition_specs, stack
from repro.optim import adamw


# ------------------------------------------------------------- builder
def test_builder_three_materializations_consistent():
    decl = {"w": Leaf((8, 4), ("embed", "ff")),
            "sub": {"b": Leaf((4,), ("ff",), "zeros")}}
    params = materialize(decl, jax.random.PRNGKey(0))
    shapes = abstract(decl)
    specs = partition_specs(decl, {"embed": None, "ff": "model"})
    assert params["w"].shape == shapes["w"].shape == (8, 4)
    assert specs["w"] == jax.sharding.PartitionSpec(None, "model")
    assert count_params(decl) == 36
    stacked = stack(decl, 5)
    assert materialize(stacked, jax.random.PRNGKey(0))["w"].shape == (5, 8, 4)


def test_builder_deterministic_and_path_keyed():
    decl = {"a": Leaf((4,), (None,)), "b": Leaf((4,), (None,))}
    p1 = materialize(decl, jax.random.PRNGKey(0))
    p2 = materialize(decl, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(p1["a"]), np.asarray(p2["a"]))
    assert not np.allclose(np.asarray(p1["a"]), np.asarray(p1["b"]))


# ------------------------------------------------------------ optimizer
def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=100, schedule="constant")
    params = {"x": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adamw_state_dtype_roundtrip():
    params = {"x": jnp.ones(3, jnp.bfloat16)}
    state = adamw.AdamWState(jnp.zeros((), jnp.int32),
                             {"x": jnp.zeros(3, jnp.bfloat16)},
                             {"x": jnp.zeros(3, jnp.bfloat16)})
    cfg = adamw.AdamWConfig()
    new_p, new_s, _ = adamw.update(cfg, {"x": jnp.ones(3, jnp.bfloat16)},
                                   state, params)
    assert new_p["x"].dtype == jnp.bfloat16
    assert new_s.m["x"].dtype == jnp.bfloat16


def test_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.lr_at(cfg, jnp.int32(0))) < 0.2
    assert float(adamw.lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0,
                                                                   abs=0.01)
    assert float(adamw.lr_at(cfg, jnp.int32(100))) < 0.01


def test_grad_clip():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    params = {"x": jnp.zeros(4)}
    state = adamw.init(params)
    _, _, m = adamw.update(cfg, {"x": jnp.full(4, 100.0)}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ------------------------------------------------------------- data
def test_image_datasets_shapes_and_determinism():
    for spec, shape in [(FMNIST, (28, 28, 1)), (CIFAR10, (32, 32, 3))]:
        x1, y1, xt, yt = make_image_dataset(spec, 100, 50, seed=3)
        x2, y2, _, _ = make_image_dataset(spec, 100, 50, seed=3)
        assert x1.shape == (100,) + shape and xt.shape == (50,) + shape
        np.testing.assert_array_equal(x1, x2)
        assert set(np.unique(y1)) <= set(range(10))


def test_image_dataset_learnable():
    """A linear probe separates the synthetic classes far above chance."""
    x, y, xt, yt = make_image_dataset(FMNIST, 2000, 400, seed=1)
    X = x.reshape(len(x), -1)
    Xt = xt.reshape(len(xt), -1)
    # one ridge-regression step to 10 one-hot targets
    Y = np.eye(10)[y]
    W = np.linalg.solve(X.T @ X + 10.0 * np.eye(X.shape[1]), X.T @ Y)
    acc = (Xt @ W).argmax(-1).__eq__(yt).mean()
    assert acc > 0.8, acc


def test_lm_batches_structured():
    it = lm_batches(64, 4, 32, seed=0, p_structured=1.0)
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    # fully structured: labels are a fixed permutation of tokens
    t = np.asarray(b["tokens"])
    lab = np.asarray(b["labels"])
    mapping = {}
    for a, bb in zip(t.ravel(), lab.ravel()):
        assert mapping.setdefault(int(a), int(bb)) == int(bb)


def test_serving_requests():
    reqs = list(serving_requests(100, 5, seed=0))
    assert len(reqs) == 5
    assert all(1 <= r["max_new_tokens"] < 16 for r in reqs)


# ---------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import io as ckpt
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(2)}}
    path = str(tmp_path / "ck.npz")
    digest = ckpt.save(path, tree)
    back = ckpt.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    assert len(digest) == 64


def test_checkpoint_via_storage_with_ledger():
    from repro.checkpoint import io as ckpt
    from repro.core.ledger import Ledger
    from repro.core.storage import StorageNetwork
    store = StorageNetwork()
    led = Ledger()
    tree = {"w": jnp.ones((4, 4))}
    cid = ckpt.save_to_storage(store, tree, ledger=led, meta={"step": 7})
    assert led.head.payload["cid"] == cid
    back = ckpt.restore_from_storage(store, cid, tree)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.ones((4, 4)))


# ------------------------------------------------------------- attacks
@settings(max_examples=10, deadline=None)
@given(prob=st.sampled_from([0.0, 1.0]), colluding=st.booleans())
def test_round_attack_mask(prob, colluding):
    atk = AttackConfig(malicious_edges=(1, 3), attack_prob=prob,
                       colluding=colluding)
    mask = np.asarray(round_attack_mask(atk, 5, jax.random.PRNGKey(0)))
    assert mask.shape == (5,)
    if prob == 0.0:
        assert mask.sum() == 0
    else:
        assert mask[1] == 1 and mask[3] == 1 and mask[[0, 2, 4]].sum() == 0


# -------------------------------------------------------------- serve
def test_serving_engine_completes_requests():
    from repro.configs import get_config
    from repro.serve.engine import ServingEngine
    from repro.train.loop import init_model
    cfg = get_config("smollm-360m", smoke=True)
    params = init_model(cfg, seed=0)
    eng = ServingEngine(cfg, params, batch_slots=2, cache_len=64)
    reqs = list(serving_requests(cfg.vocab_size, 5, max_prompt=10,
                                 max_new=5, seed=0))
    eng.submit(reqs)
    done = eng.run()
    assert set(done) == {r["id"] for r in reqs}
    for r in reqs:
        assert len(done[r["id"]]) == r["max_new_tokens"]


def test_serving_engine_greedy_matches_forward():
    """The engine's first generated token equals the argmax of the full
    forward at the prompt's last position."""
    from repro.configs import get_config
    from repro.models.builder import materialize
    from repro.models.transformer import forward_train, model_decl
    from repro.serve.engine import ServingEngine
    cfg = get_config("qwen2.5-3b", smoke=True)
    params = materialize(model_decl(cfg), jax.random.PRNGKey(0))
    prompt = np.array([5, 17, 400, 23, 99], np.int32)
    eng = ServingEngine(cfg, params, batch_slots=1, cache_len=32)
    eng.submit([{"id": 0, "prompt": prompt, "max_new_tokens": 1}])
    done = eng.run()
    logits, _ = forward_train(params, jnp.asarray(prompt)[None], cfg,
                              remat=False, q_chunk=8, kv_chunk=8)
    want = int(jnp.argmax(logits[0, len(prompt) - 1]))
    assert done[0][0] == want
