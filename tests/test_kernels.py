"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes and dtypes (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.redundancy_vote import pairwise_agreement
from repro.kernels.ssd_scan import ssd_scan

SETTINGS = dict(max_examples=12, deadline=None)


# ------------------------------------------------------------ moe_gemm
@settings(**SETTINGS)
@given(E=st.sampled_from([1, 3, 4]),
       C=st.sampled_from([8, 40, 128, 200]),
       d=st.sampled_from([32, 96, 128]),
       f=st.sampled_from([16, 128, 192]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_moe_gemm_matches_ref(E, C, d, f, dtype):
    key = jax.random.PRNGKey(E * 1000 + C)
    buf = jax.random.normal(key, (E, C, d), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, d, f), dtype)
    got = moe_gemm(buf, w, interpret=True)
    want = ref.moe_gemm_ref(buf, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


def test_moe_gemm_nondivisible_blocks():
    buf = jax.random.normal(jax.random.PRNGKey(0), (2, 100, 50))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 70))
    got = moe_gemm(buf, w, block_c=32, block_d=32, block_f=32,
                   interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.moe_gemm_ref(buf, w)),
                               rtol=1e-5, atol=1e-4)


# ----------------------------------------------------- redundancy_vote
@settings(**SETTINGS)
@given(E=st.sampled_from([1, 4, 10]),
       M=st.sampled_from([3, 5, 10]),
       T=st.sampled_from([7, 64, 1500]),
       n_bad=st.integers(0, 2))
def test_pairwise_agreement_matches_ref(E, M, T, n_bad):
    key = jax.random.PRNGKey(E + M + T)
    pub = jnp.broadcast_to(jax.random.normal(key, (E, 1, T)),
                           (E, M, T)).copy()
    if n_bad:
        noise = jax.random.normal(jax.random.fold_in(key, 2),
                                  (E, n_bad, T))
        pub = pub.at[:, :n_bad].add(noise)
    got = pairwise_agreement(pub, interpret=True, tile=64)
    want_unpadded = ref.pairwise_agreement_ref(pub)
    pad = (-T) % min(64, T)   # kernel clamps tile to T
    # padded zeros agree for every pair: constant offset
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(want_unpadded) + pad)


@pytest.mark.parametrize("M,n_bad", [(10, 3), (10, 4), (5, 2), (3, 1)])
def test_vote_rejects_minority(M, n_bad):
    """Colluding minority (paper §IV-B scenario 2, ratio < 50%) never
    flips the vote, in both ref and kernel backends."""
    key = jax.random.PRNGKey(0)
    honest = jax.random.normal(key, (4, 16, 8))
    pub = jnp.broadcast_to(honest[:, None], (4, M, 16, 8)).copy()
    delta = jax.random.normal(jax.random.fold_in(key, 1), (4, 1, 16, 8))
    pub = pub.at[:, :n_bad].add(jnp.broadcast_to(delta, (4, n_bad, 16, 8)))
    for backend in ("ref", "interpret"):
        from repro.kernels import ops
        trusted, support = ops.redundancy_vote(pub, backend=backend)
        np.testing.assert_allclose(np.asarray(trusted), np.asarray(honest),
                                   rtol=0, atol=0)
        assert int(support.min()) == M - n_bad


def test_vote_majority_collusion_wins():
    """> 50% colluding attackers mislead the chain (paper's threshold)."""
    key = jax.random.PRNGKey(0)
    honest = jax.random.normal(key, (2, 8, 4))
    pub = jnp.broadcast_to(honest[:, None], (2, 10, 8, 4)).copy()
    delta = jax.random.normal(jax.random.fold_in(key, 1), (2, 1, 8, 4))
    pub = pub.at[:, :6].add(jnp.broadcast_to(delta, (2, 6, 8, 4)))
    from repro.kernels import ops
    trusted, support = ops.redundancy_vote(pub)
    assert not np.allclose(np.asarray(trusted), np.asarray(honest))
    assert int(support.min()) == 6


# ------------------------------------------------------ flash attention
@settings(**SETTINGS)
@given(B=st.sampled_from([1, 2]),
       S=st.sampled_from([64, 128, 256]),
       H=st.sampled_from([2, 4]),
       KH=st.sampled_from([1, 2]),
       D=st.sampled_from([32, 64]),
       causal=st.booleans(),
       window=st.sampled_from([0, 32]))
def test_flash_attention_matches_ref(B, S, H, KH, D, causal, window):
    if H % KH:
        KH = 1
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, H, S, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, KH, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, KH, S, D))
    got = flash_attention(q, k, v, causal=causal, window=window, bq=64,
                          bk=64, interpret=True)
    want = ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                             jnp.moveaxis(v, 1, 2), causal=causal,
                             window=window)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.moveaxis(want, 2, 1)),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 32))
    got = flash_attention(q, k, v, causal=True, softcap=20.0, bq=64, bk=64,
                          interpret=True)
    want = ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                             jnp.moveaxis(v, 1, 2), causal=True,
                             softcap=20.0)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.moveaxis(want, 2, 1)),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ ssd scan
@settings(**SETTINGS)
@given(B=st.sampled_from([1, 2]),
       S=st.sampled_from([64, 256]),
       H=st.sampled_from([1, 3]),
       P=st.sampled_from([16, 32]),
       N=st.sampled_from([8, 16]),
       chunk=st.sampled_from([32, 64]))
def test_ssd_scan_matches_ref(B, S, H, P, N, chunk):
    key = jax.random.PRNGKey(S + P)
    x = jax.random.normal(key, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, H))) * 0.1
    A = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (H,))) - 0.1
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, S, N)) * 0.5
    got = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    want, _ = ref.ssd_scan_ref(x, dt, A, Bm, Cm, state0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ops_dispatch_backends():
    """ops.* must agree across ref and interpret backends."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    buf = jax.random.normal(key, (2, 16, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 24))
    np.testing.assert_allclose(
        np.asarray(ops.moe_gemm(buf, w, backend="ref")),
        np.asarray(ops.moe_gemm(buf, w, backend="interpret")),
        rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ audit gather-MLP
def _mlp_bank(key, E, d, h, o):
    ks = jax.random.split(key, 4)
    return {"w1": jax.random.normal(ks[0], (E, d, h)) * 0.1,
            "b1": jax.random.normal(ks[1], (E, h)) * 0.1,
            "w2": jax.random.normal(ks[2], (E, h, o)) * 0.1,
            "b2": jax.random.normal(ks[3], (E, o)) * 0.1}


@settings(**SETTINGS)
@given(E=st.sampled_from([1, 3, 8]),
       S=st.sampled_from([1, 5, 16]),
       C=st.sampled_from([8, 33, 128]),
       d=st.sampled_from([64, 200, 784]))
def test_audit_mlp_matches_ref(E, S, C, d):
    """The fused grouped gather-MLP kernel vs the gathered-vmap oracle,
    with repeated group ids (duplicate sampled experts)."""
    from repro.kernels.audit_gemm import audit_mlp
    key = jax.random.PRNGKey(E * 100 + S + C + d)
    params = _mlp_bank(key, E, d, h=128, o=10)
    x = jax.random.normal(jax.random.fold_in(key, 9), (S, C, d))
    gid = jax.random.randint(jax.random.fold_in(key, 10), (S,), 0, E)
    got = audit_mlp(params, x, gid, interpret=True)
    want = ref.audit_mlp_ref(params, x, gid.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_audit_mlp_ref_bitwise_matches_per_chunk_apply():
    """The ref backend must be BIT-identical to the eager per-chunk
    expert apply — that is what makes batched leaf digests reproduce the
    executor's commitment exactly (hash equality, not allclose)."""
    from repro.core import experts as ex
    from repro.kernels import ops
    key = jax.random.PRNGKey(3)
    params, _ = ex.make_expert_bank("mlp", 4, key, in_dim=96, out=10)
    x = jax.random.normal(jax.random.fold_in(key, 1), (6, 17, 96))
    gid = jnp.asarray(np.array([0, 3, 3, 1, 2, 0], np.int32))
    got = np.asarray(jax.jit(ops.audit_mlp)(params, x, gid))
    for s in range(6):
        p = jax.tree_util.tree_map(lambda a: a[gid[s]], params)
        want = np.asarray(ex.mlp_expert_apply(p, x[s]))
        np.testing.assert_array_equal(got[s], want)


# ------------------------------------------------------------ rglru scan
@settings(**SETTINGS)
@given(B=st.sampled_from([1, 2]), S=st.sampled_from([64, 128, 256]),
       C=st.sampled_from([128, 256]),
       seq_block=st.sampled_from([32, 64]))
def test_rglru_scan_matches_ref(B, S, C, seq_block):
    from repro.kernels.rglru_scan import rglru_scan_pallas
    from repro.models.rglru import rglru_scan
    key = jax.random.PRNGKey(S + C)
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, C)))
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, C))
    got = rglru_scan_pallas(a, b, seq_block=seq_block, chan_block=128,
                            interpret=True)
    want = rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
