"""Stake-weighted verifier lottery + lazy-verifier slashing.

The pool-wide audit budget is fixed; stakes decide how it is split:
verifier v samples each leaf w.p. ``audit_rate * stake_v / sum(stakes)``
(x pool size under the per-verifier rate convention).  Properties:

- conservation: the summed per-verifier rates equal the pool-wide rate
  (absent clipping at 1.0), whatever the stake vector;
- proportionality: rates — and empirical sampling frequencies — follow
  stakes;
- exactness: a uniform stake vector reproduces the unweighted pool's
  sampling streams bit-for-bit (determinism pins stay valid);
- accountability: a rubber-stamping verifier (echoing the executor's
  published digests instead of attesting its salted recompute) is caught
  by re-audit even on HONEST rounds, slashed, and its future lottery
  share shrinks while the honest verifiers' shares grow.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.trust.audit import VerifierPool, attestation_digest
from repro.trust.commitments import commit_outputs
from repro.trust.protocol import OptimisticProtocol, RoundPhase, TrustConfig

SETTINGS = dict(max_examples=25, deadline=None)


def _commitment(seed=0, shape=(3, 16, 4), round_id=1):
    rng = np.random.default_rng(seed)
    honest = rng.normal(size=shape).astype(np.float32)
    return honest, commit_outputs(honest, round_id=round_id, executor=0,
                                  chunks_per_expert=4)


# ------------------------------------------------------------- rates
def test_uniform_stakes_reproduce_unweighted_streams():
    p0 = VerifierPool(3, 0.3, seed=5)
    p1 = VerifierPool(3, 0.3, seed=5, stakes=[2.0, 2.0, 2.0])
    for r in range(20):
        for v in range(3):
            assert p0.sample_leaves(r, v, 17) == p1.sample_leaves(r, v, 17)
            assert p1.rate_of(v) == p0.audit_rate


@settings(**SETTINGS)
@given(stakes=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6),
       rate=st.floats(0.01, 0.15))
def test_rates_follow_stakes_and_conserve_pool_budget(stakes, rate):
    pool = VerifierPool(len(stakes), rate, seed=0, stakes=stakes)
    rates = [pool.rate_of(v) for v in range(len(stakes))]
    # conservation: the pool-wide sampled fraction is unchanged by the
    # weighting (rate small enough that no share clips at 1.0)
    if max(rates) < 1.0:
        assert sum(rates) == pytest.approx(rate * len(stakes), rel=1e-9)
    # proportionality
    for v in range(len(stakes)):
        assert rates[v] == pytest.approx(
            min(1.0, rate * len(stakes) * stakes[v] / sum(stakes)),
            rel=1e-9)


def test_empirical_sampling_frequency_follows_stakes():
    stakes = [4.0, 1.0, 1.0]
    pool = VerifierPool(3, 0.1, seed=2, stakes=stakes)
    counts = np.zeros(3)
    rounds, leaves = 400, 50
    for r in range(rounds):
        for v in range(3):
            counts[v] += len(pool.sample_leaves(r, v, leaves))
    freq = counts / (rounds * leaves)
    for v in range(3):
        assert freq[v] == pytest.approx(pool.rate_of(v), abs=0.01)
    assert counts[0] > 2.5 * counts[1]


def test_detection_probability_stake_aware_and_conservative():
    pool = VerifierPool(2, 0.1, stakes=[1.0, 3.0])
    k = 4
    r0, r1 = pool.rate_of(0), pool.rate_of(1)       # 0.05, 0.15
    assert (r0, r1) == (pytest.approx(0.05), pytest.approx(0.15))
    # whole pool honest: product over both true rates
    assert pool.detection_probability(k) == pytest.approx(
        1 - (1 - r0) ** k * (1 - r1) ** k)
    # one honest verifier of unknown identity: assume the LOWEST rate
    # (the uniform formula would overstate detection 2x here)
    assert pool.detection_probability(k, honest_verifiers=1) == \
        pytest.approx(1 - (1 - r0) ** k)


def test_fully_slashed_pool_samples_nothing():
    pool = VerifierPool(2, 0.5, seed=0, stakes=[0.0, 0.0])
    assert pool.rate_of(0) == 0.0
    assert pool.sample_leaves(0, 0, 100) == []


def test_bad_stake_vectors_rejected():
    with pytest.raises(ValueError):
        VerifierPool(3, 0.1, stakes=[1.0, 1.0])
    with pytest.raises(ValueError):
        VerifierPool(2, 0.1, stakes=[1.0, -1.0])


# ---------------------------------------------------------- re-audit
def test_attestation_underivable_from_published_digest():
    honest, com = _commitment()
    chunk = com.leaf_chunk(0)
    assert attestation_digest(1, 0, chunk) != com.leaf_digests[0]
    assert attestation_digest(1, 0, chunk) != attestation_digest(1, 1, chunk)
    assert attestation_digest(2, 0, chunk) != attestation_digest(1, 0, chunk)


def test_lazy_verifier_caught_on_honest_round_and_loses_lottery_share():
    """The point of salted attestations: on an honest round the lazy
    verifier's echoed digests are 'correct' leaf digests — but not the
    salted recompute digest only a real recompute can produce, so the
    re-audit still catches it."""
    honest, com = _commitment()
    recompute = lambda e, sl: honest[e, sl]                     # noqa: E731
    pool = VerifierPool(2, 0.4, seed=3, stakes=[1.0, 1.0], reaudit_rate=1.0)
    reports = pool.audit(com, recompute)
    assert all(r.sampled_leaves and r.attestations for r in reports)
    # verifier 1 rubber-stamps: echoes the executor's published digests
    reports[1].attestations = {leaf: com.leaf_digests[leaf]
                               for leaf in reports[1].sampled_leaves}
    rate_before = pool.rate_of(1)
    caught = pool.reaudit(com, reports, recompute)
    assert caught == [1]
    [ev] = pool.lazy_slashes
    assert (ev.round_id, ev.verifier, ev.amount) == (1, 1, 0.5)
    assert pool.stakes[1] == 0.5 and pool.stakes[0] == 1.0
    # its lottery share shrank, the honest verifier's grew, budget kept
    assert pool.rate_of(1) < rate_before < pool.rate_of(0)
    assert pool.rate_of(0) + pool.rate_of(1) == pytest.approx(0.8)
    # an honest verifier is never slashed, however often re-audited
    for _ in range(3):
        assert pool.reaudit(com, [reports[0]], recompute) == []
    assert pool.stakes[0] == 1.0


def test_batched_attestations_match_eager():
    honest, com = _commitment()
    pool_e = VerifierPool(3, 0.6, seed=1, stakes=[1, 2, 3], reaudit_rate=1.0)
    pool_b = VerifierPool(3, 0.6, seed=1, stakes=[1, 2, 3], reaudit_rate=1.0)

    def batch_fn(experts, slices):
        cmax = max(sl.stop - sl.start for sl in slices)
        out = np.zeros((len(experts), cmax) + honest.shape[2:],
                       honest.dtype)
        for s, (e, sl) in enumerate(zip(experts, slices)):
            out[s, :sl.stop - sl.start] = honest[e, sl]
        return out

    eager = pool_e.audit(com, lambda e, sl: honest[e, sl])
    batched = pool_b.audit_batched(com, batch_fn)
    for a, b in zip(batched, eager):
        assert a.attestations == b.attestations


# ------------------------------------------------- protocol integration
def test_protocol_reaudit_slashes_lazy_verifiers_only():
    cfg = TrustConfig(audit_rate=1.0, num_verifiers=4, challenge_window=1,
                      lazy_verifier_prob=0.5, reaudit_rate=1.0, seed=7)
    proto = OptimisticProtocol(cfg, num_edges=3)
    honest = np.zeros((2, 8, 3), np.float32)
    lazy_seen = set()
    for rid in range(6):
        proto.commit(rid, executor=rid % 3, outputs=honest)
        proto.run_audits(rid, lambda e, sl: honest[e, sl])
        for rep in proto.rounds[rid].reports:
            if rep.lazy and rep.sampled_leaves:  # empty lottery: nothing
                lazy_seen.add((rid, rep.verifier))   # to attest or catch
        proto.advance(rid)
    assert lazy_seen, "seed produced no lazy draws — adjust seed"
    # every lazy (round, verifier) pass was caught; nobody else was
    assert {(ev.round_id, ev.verifier)
            for ev in proto.verifiers.lazy_slashes} == lazy_seen
    assert (proto.verifiers.stakes <= 1.0).all()
    assert (proto.verifiers.stakes >= 0.0).all()
    # honest rounds still finalize: catching auditors never blocks rounds
    assert all(st.phase is RoundPhase.FINALIZED
               for rid, st in proto.rounds.items() if rid < 5)


def test_serving_session_reaudit_slashes_lazy_auditor():
    """ServingEngine session audits run the same second-layer lottery: a
    rubber-stamping session auditor (lazy_prob=1: it samples but echoes
    published digests instead of recomputing) is caught by re-audit and
    slashed, even though the served stream itself is honest."""
    from repro.configs import get_config
    from repro.data.synthetic import serving_requests
    from repro.serve.engine import ServingEngine
    from repro.train.loop import init_model
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=4,
                        lazy_verifier_prob=1.0, reaudit_rate=1.0,
                        verifier_stakes=(1.0,))
    cfg = get_config("smollm-360m", smoke=True)
    eng = ServingEngine(cfg, init_model(cfg, seed=0), batch_slots=2,
                        cache_len=64, trust=trust)
    eng.submit(list(serving_requests(cfg.vocab_size, 2, max_prompt=6,
                                     max_new=4, seed=0)))
    eng.run()
    # the lazy auditor rubber-stamped an honest stream: sessions pass...
    assert not any(rec.revoked for rec in eng.records.values())
    # ...but the re-audit caught the auditor and burned its stake
    assert eng._auditors.lazy_slashes
    assert eng._auditors.stakes[0] < 1.0


def test_system_end_to_end_lazy_verifier_slashed_and_frauds_still_caught():
    """BMoESystem integration: with a weighted pool, re-audits on, and a
    lazy-ish pool, training still catches the cheating executor AND the
    rubber-stampers lose stake."""
    from repro.data.synthetic import FMNIST, make_image_dataset
    xtr, ytr, _, _ = make_image_dataset(FMNIST, n_train=600, n_test=100,
                                        seed=0)
    xtr = xtr.reshape(len(xtr), -1)
    cfg = BMoEConfig(
        framework="optimistic", pow_difficulty=2,
        attack=AttackConfig(malicious_edges=(1,), attack_prob=1.0,
                            noise_std=5.0),
        trust=TrustConfig(audit_rate=1.0, num_verifiers=3,
                          challenge_window=1, lazy_verifier_prob=0.4,
                          verifier_stakes=(1.0, 1.0, 2.0),
                          reaudit_rate=1.0, seed=3))
    s = BMoESystem(cfg)
    rng = np.random.default_rng(0)
    for idx in [rng.integers(0, len(xtr), 48) for _ in range(6)]:
        s.train_round(xtr[idx], ytr[idx])
    s.flush_trust()
    # executor fraud: caught and slashed despite lazy verifiers
    assert {ev.edge for ev in s.protocol.stakes.events} == {1}
    # verifier fraud: every lazy pass was caught by re-audit
    lazy_passes = {(st.round_id, r.verifier)
                   for st in s.protocol.rounds.values()
                   for r in st.reports if r.lazy and r.sampled_leaves}
    caught = {(ev.round_id, ev.verifier)
              for ev in s.protocol.verifiers.lazy_slashes}
    assert lazy_passes and caught == lazy_passes
