"""Sparse top-k dispatch for the B-MoE hot path.

Routing equivalence: the capacity-bucketed scatter-dispatch + grouped
GEMM + gather-combine forward (``BMoEConfig.dispatch="sparse"``, the
default) must match the dense ``apply_all`` oracle
(``dispatch="dense"``) — same outputs, and identical gate/expert
gradients — whenever no token is dropped; capacity overflow must be
*accounted* (the ``dropped`` metric), never mis-routed.  The sparse
trust layer must behave exactly like the dense one: commitments over the
bucketed buffers (routing indices carried in the commitment so auditors
re-derive the same buckets), identical audit verdicts on the same
attacked round, and batched audits bit-identical to the eager oracle.
"""
import numpy as np
import pytest

import jax

from repro.core import experts as ex
from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem, sparse_capacity
from repro.core.ledger import digest_tree
from repro.core.reputation import ReputationConfig
from repro.data.synthetic import FMNIST, make_image_dataset
from repro.models.moe import capacity_positions
from repro.trust.protocol import RoundPhase, TrustConfig


@pytest.fixture(scope="module")
def data():
    xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=1200, n_test=200,
                                            seed=0)
    return xtr.reshape(len(xtr), -1), ytr, xte.reshape(len(xte), -1), yte


def _cfg(dispatch, attack=AttackConfig(), *, capacity_factor=1.25, trust=None,
         **kw):
    kw.setdefault("num_experts", 8)
    kw.setdefault("top_k", 2)
    return BMoEConfig(framework="optimistic", attack=attack,
                      pow_difficulty=2, dispatch=dispatch,
                      capacity_factor=capacity_factor,
                      reputation=ReputationConfig(init=0.5, gain=0.01,
                                                  slash=0.4,
                                                  exclusion_threshold=0.2),
                      trust=trust or TrustConfig(audit_rate=1.0,
                                                 num_verifiers=2,
                                                 challenge_window=2),
                      **kw)


NO_DROPS = 4.0          # capacity_factor = N/k: capacity == batch, 0 drops


# ------------------------------------------------------------ helpers
def test_sparse_capacity_bounds():
    cfg = BMoEConfig(num_experts=8, top_k=2, capacity_factor=1.0)
    assert sparse_capacity(cfg, 512) == 128          # exactly B*k/N
    assert sparse_capacity(cfg, 512) % 8 == 0
    assert sparse_capacity(cfg, 4) == 4              # capped at batch
    assert sparse_capacity(BMoEConfig(capacity_factor=0.01), 64) >= 1


def test_capacity_positions_bucket_invariants():
    eid = np.array([[0, 1, 0, 0, 1, 2, 0]])
    pos, keep, _ = (np.asarray(a) for a in capacity_positions(
        jax.numpy.asarray(eid), 3, capacity=2))
    np.testing.assert_array_equal(pos[0], [0, 0, 1, 2, 1, 0, 3])
    np.testing.assert_array_equal(keep[0], [1, 1, 1, 0, 1, 1, 0])


def test_grouped_mlp_apply_matches_vmap_oracle_and_grads():
    key = jax.random.PRNGKey(0)
    params, _ = ex.make_expert_bank("mlp", 4, key, in_dim=12, hidden=16,
                                    out=5)
    buf = jax.random.normal(jax.random.fold_in(key, 1), (4, 6, 12))
    got = ex.mlp_expert_apply_grouped(params, buf)
    want = jax.vmap(ex.mlp_expert_apply)(params, buf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    gg = jax.grad(lambda p, b: (ex.mlp_expert_apply_grouped(p, b) ** 2).sum(),
                  argnums=(0, 1))(params, buf)
    gr = jax.grad(lambda p, b:
                  (jax.vmap(ex.mlp_expert_apply)(p, b) ** 2).sum(),
                  argnums=(0, 1))(params, buf)
    for a, b in zip(jax.tree_util.tree_leaves(gg),
                    jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------- dense-oracle parity
def test_sparse_infer_matches_dense_oracle_no_drops(data):
    _, _, xte, _ = data
    sp = BMoESystem(_cfg("sparse", capacity_factor=NO_DROPS))
    de = BMoESystem(_cfg("dense"))
    ls, _, _ = sp.infer(xte[:64], commit=False)
    ld, _, _ = de.infer(xte[:64], commit=False)
    np.testing.assert_allclose(ls, ld, rtol=1e-4, atol=1e-5)


def test_sparse_train_matches_dense_grads_no_drops(data):
    """With capacity >= batch nothing drops, so one SGD step through the
    scatter/grouped-GEMM/gather path must land on the same updated
    parameters as the dense einsum path — gate grads (through the
    combine weights) and expert grads (through the buckets) both."""
    xtr, ytr, _, _ = data
    sp = BMoESystem(_cfg("sparse", capacity_factor=NO_DROPS))
    de = BMoESystem(_cfg("dense"))
    rng = np.random.default_rng(0)
    for idx in [rng.integers(0, len(xtr), 48) for _ in range(3)]:
        ms = sp.train_round(xtr[idx], ytr[idx])
        md = de.train_round(xtr[idx], ytr[idx])
        assert float(ms["dropped"]) == 0.0
        assert float(ms["loss"]) == pytest.approx(float(md["loss"]),
                                                  abs=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves((sp.gate, sp.experts)),
                    jax.tree_util.tree_leaves((de.gate, de.experts))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


# ------------------------------------------------------ drop accounting
def test_capacity_overflow_drop_accounting(data):
    """Tiny capacity: the dropped metric counts exactly the assignments
    that overflowed their expert's bucket (host-side recount from the
    same routing), and the forward stays finite — drops zero out, they
    never mis-route."""
    xtr, ytr, _, _ = data
    s = BMoESystem(_cfg("sparse", capacity_factor=0.25))
    cfg = s.cfg
    idx = np.arange(64)
    m = s.train_round(xtr[idx], ytr[idx])
    # recount drops from the committed routing of the same round
    com = s.protocol.rounds[0].commitment
    cap = sparse_capacity(cfg, 64)
    assert com.row_index.shape == (cfg.num_experts, cap)
    filled = int((com.row_index < 64).sum())
    assert float(m["dropped"]) == 64 * cfg.top_k - filled
    assert float(m["dropped"]) > 0           # capacity_factor=0.25 overflows
    assert np.isfinite(float(m["loss"]))


# ------------------------------------------------- sparse trust layer
def _run(dispatch, backend, xtr, ytr, *, rounds=5, atk=None,
         scheduling="pipelined"):
    atk = atk or AttackConfig(malicious_edges=(2,), attack_prob=1.0,
                              noise_std=5.0)
    s = BMoESystem(_cfg(dispatch, atk,
                        trust=TrustConfig(audit_rate=1.0, num_verifiers=2,
                                          challenge_window=2,
                                          audit_backend=backend,
                                          scheduling=scheduling)))
    rng = np.random.default_rng(0)
    for idx in [rng.integers(0, len(xtr), 48) for _ in range(rounds)]:
        s.train_round(xtr[idx], ytr[idx])
    s.flush_trust()
    return s


def test_sparse_commitment_carries_routing_and_audits_clean(data):
    xtr, ytr, _, _ = data
    s = _run("sparse", "batched", xtr, ytr, atk=AttackConfig())
    for state in s.protocol.rounds.values():
        com = state.commitment
        assert com.row_index is not None and com.routing_digest
        assert com.rows_per_expert == sparse_capacity(s.cfg, 48)
        assert state.phase is RoundPhase.FINALIZED
        assert all(r.clean for r in state.reports)
    # the ledger carries the routing digest next to the commit root
    trains = [b.payload for b in s.ledger.blocks[1:]
              if b.payload.get("kind") == "train"]
    assert all("routing" in p for p in trains)


def test_sparse_audit_verdicts_match_dense_scheme(data):
    """The same attacked rounds produce the same convictions under the
    sparse per-(expert, bucket-chunk) commitment scheme as under the
    dense per-(expert, batch-chunk) scheme."""
    xtr, ytr, _, _ = data
    sp = _run("sparse", "batched", xtr, ytr)
    de = _run("dense", "batched", xtr, ytr)
    assert [(e.round_id, e.edge) for e in sp.protocol.stakes.events] == \
           [(e.round_id, e.edge) for e in de.protocol.stakes.events]
    assert {r: st.phase for r, st in sp.protocol.rounds.items()} == \
           {r: st.phase for r, st in de.protocol.rounds.items()}
    assert sp.protocol.stats["rolled_back"] == \
        de.protocol.stats["rolled_back"] >= 1
    # ... at a fraction of the verification compute
    vs = sp.verification_report()["total_verification_per_round"]
    vd = de.verification_report()["total_verification_per_round"]
    cap = sparse_capacity(sp.cfg, 48)
    assert vs == pytest.approx(vd * cap / 48, rel=1e-6)


def test_sparse_batched_audits_bit_identical_to_eager(data):
    """Acceptance pin: under sparse dispatch the grouped-kernel audit
    path reproduces the eager per-leaf oracle bit-for-bit — same sampled
    leaves, same digests, same proofs, same post-rollback state."""
    xtr, ytr, _, _ = data
    a = _run("sparse", "batched", xtr, ytr)
    b = _run("sparse", "eager", xtr, ytr)
    assert set(a.protocol.rounds) == set(b.protocol.rounds)
    for rid in a.protocol.rounds:
        ra, rb = a.protocol.rounds[rid], b.protocol.rounds[rid]
        assert [(r.verifier, r.sampled_leaves, r.lazy)
                for r in ra.reports] == \
               [(r.verifier, r.sampled_leaves, r.lazy) for r in rb.reports]
        assert [(p.leaf_index, p.expert, p.claimed_digest,
                 p.recomputed_digest) for p in ra.proofs] == \
               [(p.leaf_index, p.expert, p.claimed_digest,
                 p.recomputed_digest) for p in rb.proofs]
        assert ra.phase is rb.phase
    assert digest_tree(a.experts) == digest_tree(b.experts)
    assert digest_tree(a.gate) == digest_tree(b.gate)


def test_auditors_rederive_buckets_from_committed_routing(data):
    """Every honest sparse leaf recomputes bit-identically from only the
    commitment's routing indices + the published task + the expert
    version fetched from the storage layer by its on-chain manifest (the
    executor's gate — and its live bank — are never consulted): per-leaf
    digests match the committed ones."""
    from repro.trust.commitments import leaf_digest
    xtr, ytr, _, _ = data
    s = BMoESystem(_cfg("sparse"))
    xin = np.asarray(xtr[:48])
    s.train_round(xin, ytr[:48])
    com = s.protocol.rounds[0].commitment
    recompute = s._make_recompute(xin, s._audit_cids[0], com.row_index)
    for leaf in range(com.num_leaves):
        e, _, sl = com.leaf_coords(leaf)
        assert leaf_digest(recompute(e, sl)) == com.leaf_digests[leaf]
