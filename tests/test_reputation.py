"""Paper §VI extensions: reputation-aided consensus, workload balance,
incentive/exclusion mechanics."""
import numpy as np
import pytest

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.core.reputation import ReputationConfig, ReputationLedger, WorkloadBalancer
from repro.data.synthetic import FMNIST, make_image_dataset


@pytest.fixture(scope="module")
def data():
    xtr, ytr, xte, yte = make_image_dataset(FMNIST, n_train=2000, n_test=400,
                                            seed=0)
    return xtr.reshape(len(xtr), -1), ytr, xte.reshape(len(xte), -1), yte


def test_reputation_ledger_dynamics():
    led = ReputationLedger(4, ReputationConfig(init=0.5, gain=0.1,
                                               slash=0.3))
    # edges 0-2 honest, edge 3 always rejected
    flags = np.array([[1, 1, 1, 0]] * 5)     # (E=5, M=4)
    for _ in range(3):
        led.update_from_flags(flags)
    assert led.rep[0] > 0.5 and led.rep[3] < 0.5
    assert led.rewards[3] < 0 < led.rewards[0]
    for _ in range(5):
        led.update_from_flags(flags)
    assert led.excluded[3] and not led.excluded[0]
    assert 3 not in led.active_edges()


def test_reputation_scales_mining_power():
    led = ReputationLedger(3, ReputationConfig(difficulty_scale=4))
    led.rep = np.array([1.0, 0.5, 0.0])
    p = led.effective_power()
    assert p[0] > p[1] > p[2]
    assert p[0] / p[2] == pytest.approx(16.0)  # 2**4


def test_workload_balancer_pushes_toward_uniform():
    bal = WorkloadBalancer(4, eta=1.0)
    bal.update(np.array([100.0, 0.0, 0.0, 0.0]))
    assert bal.bias[0] < 0 and (bal.bias[1:] > 0).all()


def test_reputation_excludes_persistent_attackers(data):
    """Persistent attackers get slashed below the exclusion threshold and
    barred from the electorate — afterwards even a vote tie cannot elect
    them (paper §VI-D damage bounding)."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                       noise_std=5.0)
    cfg = BMoEConfig(framework="bmoe", attack=atk, pow_difficulty=2,
                     reputation=ReputationConfig(init=0.5, gain=0.02,
                                                 slash=0.15,
                                                 exclusion_threshold=0.2))
    s = BMoESystem(cfg)
    rng = np.random.default_rng(0)
    for _ in range(8):
        idx = rng.integers(0, len(xtr), 128)
        s.train_round(xtr[idx], ytr[idx])
    rep = s.reputation.rep
    assert rep[7:].max() < rep[:7].min()
    assert s.reputation.excluded[7:].all()
    assert not s.reputation.excluded[:7].any()


def test_workload_balance_in_system(data):
    """Under attacked training the gate starves malicious experts; the
    §VI-C bias controller pulls activation back toward uniform."""
    xtr, ytr, _, _ = data
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=0.5,
                       noise_std=5.0)

    def run(balance):
        cfg = BMoEConfig(framework="traditional", attack=atk,
                         pow_difficulty=2, workload_balance=balance)
        s = BMoESystem(cfg)
        rng = np.random.default_rng(0)
        for _ in range(40):
            idx = rng.integers(0, len(xtr), 128)
            s.train_round(xtr[idx], ytr[idx])
        r = s.activation_ratio
        return float(np.std(r))

    assert run(True) < run(False)


def test_hybrid_consensus_reputation_mining(data):
    """Reputation-weighted PoW: honest (high-rep) nodes win most blocks."""
    from repro.core.consensus import ProofOfWork
    led = ReputationLedger(4, ReputationConfig(difficulty_scale=5))
    led.rep = np.array([0.9, 0.9, 0.1, 0.1])
    pow_ = ProofOfWork(4, difficulty_bits=4,
                       mining_power=led.effective_power(), seed=0)
    miners = [pow_.mine(i, "0" * 64, {}).miner for i in range(30)]
    honest = sum(1 for m in miners if m in (0, 1))
    assert honest >= 24
