"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real (1-device) CPU; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.fixture(scope="session")
def repo_src():
    return os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def run_with_devices(code: str, n_devices: int, repo_src: str,
                     timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with n virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = repo_src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
