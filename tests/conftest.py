"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real (1-device) CPU; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys
import textwrap
import types
import unittest

import pytest

# ------------------------------------------------------------------
# hypothesis is optional (see requirements-dev.txt): when it is absent,
# install an importorskip-style shim so the 4 property-test modules
# still collect — @given tests turn into skips, everything else runs.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _skip = pytest.mark.skip(reason="hypothesis not installed "
                                    "(pip install -r requirements-dev.txt)")

    def _given(*_a, **_k):
        return lambda f: _skip(f)

    def _settings(*_a, **_k):
        return lambda f: f

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):           # st.integers, st.sampled_from…
            return lambda *a, **k: None

    _st = _Strategies("hypothesis.strategies")

    # hypothesis.stateful shim: rule/initialize/invariant/precondition
    # become identity decorators (so machine methods stay plain callables
    # for the deterministic fallback drivers) and Machine.TestCase skips.
    class _SkipCase(unittest.TestCase):
        def runTest(self):
            pytest.skip("hypothesis not installed "
                        "(pip install -r requirements-dev.txt)")

    class _RuleBasedStateMachine:
        TestCase = _SkipCase

    def _marker(*args, **_kwargs):
        if len(args) == 1 and callable(args[0]) and not _kwargs:
            return args[0]
        return lambda f: f

    _stateful = types.ModuleType("hypothesis.stateful")
    _stateful.RuleBasedStateMachine = _RuleBasedStateMachine
    _stateful.rule = _marker
    _stateful.initialize = _marker
    _stateful.invariant = _marker
    _stateful.precondition = _marker
    _stateful.Bundle = lambda *_a, **_k: None

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.strategies = _st
    _stub.stateful = _stateful
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _st
    sys.modules["hypothesis.stateful"] = _stateful


@pytest.fixture(scope="session")
def repo_src():
    return os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def run_with_devices(code: str, n_devices: int, repo_src: str,
                     timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with n virtual CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = repo_src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
