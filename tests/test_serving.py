"""Continuous-batching scheduler + engine edge cases, and the
fixed-vs-continuous trust-verdict equivalence contract.

The model-level tests run on the smallest dense config (smollm-360m
smoke) — scheduling is architecture-agnostic, and the MoE paths are
exercised by tests/test_substrate.py and tests/test_expert_cache.py.
"""
import numpy as np
import pytest

from repro.serve.scheduler import SlotScheduler, SlotState
from repro.trust.commitments import MerkleTree
from repro.trust.protocol import TrustConfig
from repro.trust.session import commit_tick, verify_session_inclusion


def _req(rid, plen, new, vocab=64, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return {"id": rid,
            "prompt": rng.integers(0, vocab, size=plen).astype(np.int32),
            "max_new_tokens": new}


def _engine(batch_slots=2, cache_len=64, **kw):
    from repro.configs import get_config
    from repro.serve.engine import ServingEngine
    from repro.train.loop import init_model
    cfg = get_config("smollm-360m", smoke=True)
    params = init_model(cfg, seed=0)
    return ServingEngine(cfg, params, batch_slots=batch_slots,
                         cache_len=cache_len, **kw)


# ----------------------------------------------------------- scheduler
def test_scheduler_admission_under_full_batch():
    """A full batch admits nothing; eviction frees exactly one slot and
    the head of the queue takes it (FIFO) on the next admit."""
    sched = SlotScheduler(2, policy="continuous")
    sched.submit([_req(0, 4, 2), _req(1, 4, 2), _req(2, 4, 2),
                  _req(3, 4, 2)], tick=0)
    assert len(sched.admit(0)) == 2              # slots filled, 2 queued
    assert sched.depth() == 2
    assert sched.admit(1) == []                  # full batch: no admission
    assert sched.release(0, tick=5) == 0
    admitted = sched.admit(6)
    assert [(i, s.request_id) for i, s in admitted] == [(0, 2)]
    assert sched.meta[2]["admitted_tick"] == 6
    assert sched.meta[0]["finished_tick"] == 5
    assert sched.depth() == 1
    assert sched.occupancy() == 1.0


def test_scheduler_fixed_policy_waits_for_drain():
    """The fixed baseline only refills a fully drained batch."""
    sched = SlotScheduler(2, policy="fixed")
    sched.submit([_req(0, 4, 2), _req(1, 4, 2), _req(2, 4, 2)], tick=0)
    assert len(sched.admit(0)) == 2
    sched.release(0, tick=3)
    assert sched.admit(4) == []                  # slot 1 still active
    sched.release(1, tick=6)
    assert [s.request_id for _, s in sched.admit(7)] == [2]


def test_scheduler_prefill_lengths_caps():
    """Chunk consumption is capped by chunk size, remaining prompt, and
    cache headroom — and is 0 for decoding/idle slots."""
    sched = SlotScheduler(3, policy="continuous")
    sched.slots[0] = SlotState(request_id=0, pos=0,
                               prompt=np.zeros(20, np.int32), cursor=0,
                               to_generate=1)
    sched.slots[1] = SlotState(request_id=1, pos=6,
                               prompt=np.zeros(8, np.int32), cursor=6,
                               to_generate=1)
    # slot 2 decoding: prompt fully consumed
    sched.slots[2] = SlotState(request_id=2, pos=4,
                               prompt=np.zeros(4, np.int32), cursor=4,
                               to_generate=3)
    n = sched.prefill_lengths(chunk=16, cache_len=10)
    assert n.tolist() == [9, 2, 0]     # headroom 9; remaining prompt 2; 0


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError):
        SlotScheduler(2, policy="clairvoyant")


# ------------------------------------------------------- tick commitments
def test_commit_tick_inclusion_roundtrip():
    """One append per tick; every session's leaf proves membership in
    the tick root, and a rewritten leaf fails its inclusion proof."""
    leaves = [MerkleTree([f"x{i}"]).root for i in range(3)]
    tc, refs = commit_tick(7, list(zip([10, 11, 12], leaves)))
    assert tc.num_leaves == 3 and tc.request_ids == (10, 11, 12)
    for rid, leaf in zip([10, 11, 12], leaves):
        assert refs[rid].verify(leaf)
        assert refs[rid].root == tc.root and refs[rid].tick == 7
    assert not refs[10].verify(leaves[1])
    # session-side check: index 1 rewritten post-hoc
    tampered = [leaves[0], leaves[2], leaves[2]]
    assert verify_session_inclusion(
        tampered, [refs[10], refs[11], refs[12]], [0, 1, 2]) == [1]


def test_commit_tick_rejects_bad_entries():
    with pytest.raises(ValueError):
        commit_tick(0, [])
    with pytest.raises(ValueError):
        commit_tick(0, [(1, "a"), (1, "b")])    # one token per stream/tick


# ----------------------------------------------------------- the engine
def test_engine_warmup_compiles_every_bucket_without_state_change():
    """``warmup()`` visits every pow2 width bucket up to prefill_chunk
    (just C=1 under the fixed policy) and leaves generation unchanged —
    a warmed engine produces the same stream as a cold one."""
    cold = _engine(prefill_chunk=8)
    warm = _engine(prefill_chunk=8)
    assert warm.warmup() == 4          # C in {1, 2, 4, 8}
    assert warm.tick == 0 and warm.steps == 0
    reqs = [_req(0, 11, 4), _req(1, 3, 4)]
    assert warm.run() == {} and (warm.submit(reqs) or warm.run()) \
        == (cold.submit(reqs) or cold.run())

    fixed = _engine(scheduling="fixed")
    assert fixed.warmup() == 1         # fixed policy only ever runs C=1


def test_engine_zero_max_new_tokens():
    """A zero-token request still runs prefill, finishes with an empty
    output, and (verified) still seals a one-leaf commitment that
    finalizes through the normal window."""
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=2)
    eng = _engine(trust=trust)
    eng.submit([_req(0, 6, 0), _req(1, 6, 3)])
    done = eng.run()
    assert done[0] == [] and len(done[1]) == 3
    rec = eng.records[0]
    assert rec.finalized and len(rec.leaves) == 1   # boundary token sealed
    assert any(e["event"] == "commit" and e["request"] == 0
               for e in eng.session_log)


def test_engine_eviction_of_revoked_session_mid_window():
    """Revoking a session mid-challenge-window: the request never
    reaches ``completed``, its window entry dies, and its former slot is
    reused by later requests."""
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                        challenge_window=40)
    eng = _engine(trust=trust)
    eng.submit([_req(0, 5, 2), _req(1, 5, 2)])
    while 0 not in eng._done and eng.step():
        pass
    assert len(eng._window) >= 1                 # window still open
    eng.records[0].tokens = [t ^ 1 for t in eng.records[0].tokens]
    rep = eng.audit_session(0)                   # mid-window audit
    assert rep["revoked"] and len(eng._window) <= 1
    eng.submit([_req(2, 5, 2)])                  # reuses the freed slot
    done = eng.run()
    assert 0 not in done and 2 in done
    assert eng.records[0].revoked and not eng.records[0].finalized


def test_engine_no_queue_starvation_under_long_prompts():
    """Chunked prefill + continuous admission: short requests behind a
    long-prompt request finish in strictly fewer ticks than the
    batch-synchronous baseline, and the long prompt costs ~len/chunk
    prefill dispatches instead of len decode ticks."""
    reqs = [_req(0, 48, 2), _req(1, 4, 2), _req(2, 4, 2), _req(3, 4, 2)]

    def run(scheduling):
        eng = _engine(scheduling=scheduling, prefill_chunk=16)
        eng.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
        done = eng.run()
        return eng, done

    cont_eng, cont_done = run("continuous")
    fix_eng, fix_done = run("fixed")
    # identical outputs per request — scheduling must not change tokens
    assert set(cont_done) == set(fix_done) == {0, 1, 2, 3}
    for rid in fix_done:
        assert cont_done[rid] == fix_done[rid], rid
    # continuous drains the workload in strictly fewer ticks: the long
    # prompt chunks through in ~len/16 fused dispatches while the short
    # requests stream through the other slot back-to-back
    assert cont_eng.tick < fix_eng.tick
    # and a QUEUED request is admitted the moment a slot frees instead
    # of waiting for the long prompt's whole batch to drain — its first
    # token lands dozens of ticks earlier than the fixed baseline's
    cont_first = cont_eng.request_meta[2]["first_token_tick"]
    fix_first = fix_eng.request_meta[2]["first_token_tick"]
    assert cont_first < fix_first
    # the long prompt costs ceil(48/16)=3 fused dispatches for its
    # prefill instead of 48 single-token calls: total compiled-call
    # count stays far below the tick count
    assert cont_eng.steps < cont_eng.tick


def test_engine_batched_tick_commitments():
    """ONE Merkle append per batch tick (not per stream), leaves in slot
    order, and per-session inclusion refs verifying against the tick
    roots."""
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=2)
    eng = _engine(trust=trust)
    eng.submit([_req(0, 5, 3), _req(1, 5, 3)])
    done = eng.run()
    assert set(done) == {0, 1}
    emitting_ticks = {t for rec in eng.records.values() for t in rec.ticks}
    assert len(eng.tick_commitments) == len(emitting_ticks)
    by_tick = {tc.tick: tc for tc in eng.tick_commitments}
    for rid, rec in eng.records.items():
        assert len(rec.refs) == len(rec.leaves)
        for leaf, ref in zip(rec.leaves, rec.refs):
            assert ref.verify(leaf)
            assert by_tick[ref.tick].root == ref.root
            assert rid in by_tick[ref.tick].request_ids
    # a tick both streams emitted in carries both, slot order
    both = [tc for tc in eng.tick_commitments if tc.num_leaves == 2]
    assert both and both[0].request_ids == (0, 1)
    rep = eng.obs_report()
    assert rep["commit_appends"] == len(eng.tick_commitments)
    assert rep["commit_leaves"] == sum(tc.num_leaves
                                       for tc in eng.tick_commitments)


def test_engine_audit_catches_tick_inclusion_break():
    """A session whose leaf list is consistently rewritten (leaves AND
    per-session root recomputed) is still caught by the batch tick
    trees: the committed tick roots can't be rewritten retroactively."""
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                        challenge_window=50)
    eng = _engine(trust=trust)
    eng.submit([_req(0, 5, 4)])
    while 0 not in eng._done and eng.step():
        pass
    rec = eng.records[0]
    # consistent rewrite: alter records, re-derive leaves, re-seal
    rec.tokens = [t ^ 1 for t in rec.tokens]
    from repro.serve.engine import _tick_leaf
    rec.leaves = [_tick_leaf(0, t, tok)
                  for t, tok in zip(rec.ticks, rec.tokens)]
    rec.seal()
    rep = eng.audit_session(0)
    assert rep["revoked"]                        # inclusion proofs fail


def test_fixed_vs_continuous_trust_verdict_equivalence():
    """The trust contract of the rebuild: on the same seeded request
    trace, continuous scheduling and the fixed baseline produce the
    same per-request verdict map — every honest request finalizes in
    both, and tampering the same request revokes it in both."""
    from repro.data.synthetic import serving_requests

    def run(scheduling, tamper_rid=None):
        # window wide enough that no session finalizes before the whole
        # trace is served — the tamper must land in-window in BOTH
        # schedules (fixed drains its first batch much earlier)
        trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                            challenge_window=120)
        eng = _engine(trust=trust, scheduling=scheduling)
        from repro.configs import get_config
        cfg = get_config("smollm-360m", smoke=True)
        eng.submit(list(serving_requests(cfg.vocab_size, 5, max_prompt=10,
                                         max_new=5, seed=11)))
        while eng._done.keys() != {0, 1, 2, 3, 4} and eng.step():
            pass
        if tamper_rid is not None:
            rec = eng.records[tamper_rid]
            rec.tokens = [t ^ 1 for t in rec.tokens]
        done = eng.run()
        verdicts = {rid: ("revoked" if eng.records[rid].revoked
                          else "finalized" if rid in done else "open")
                    for rid in eng.records}
        return done, verdicts

    cont_done, cont_v = run("continuous")
    fix_done, fix_v = run("fixed")
    assert cont_done == fix_done                 # same tokens, greedy
    assert cont_v == fix_v == {rid: "finalized" for rid in range(5)}
    # tamper the same session post-run in both schedules: revoked in both
    _, cont_v2 = run("continuous", tamper_rid=2)
    _, fix_v2 = run("fixed", tamper_rid=2)
    assert cont_v2[2] == fix_v2[2] == "revoked"
    assert all(v != "finalized" for rid, v in cont_v2.items()
               if rid == 2)


# ------------------------------------------------------------ KV paging
def _shared_prefix_reqs(shared_len=40, tail_len=6, new=4, vocab=64):
    """Two requests sharing a ``shared_len``-token system prompt with
    distinct tails — the cross-session prefix-reuse workload."""
    rng = np.random.default_rng(7)
    shared = rng.integers(0, vocab, shared_len).astype(np.int32)
    reqs = []
    for rid in range(2):
        tail = rng.integers(0, vocab, tail_len).astype(np.int32)
        reqs.append({"id": rid, "prompt": np.concatenate([shared, tail]),
                     "max_new_tokens": new})
    return reqs


def test_kv_paging_warm_prefix_reuse_bit_identical():
    """Two sessions sharing a system prompt, served one at a time: the
    second admission restores the sealed shared blocks instead of
    recomputing their prefill (warm hits, restored tokens, strictly
    earlier first token), while the token streams stay bit-identical to
    the paging-off oracle."""
    from repro.serve.engine import KVStorageConfig
    reqs = _shared_prefix_reqs()             # 40 shared + 6 tail, 4 new

    base = _engine(batch_slots=1)
    base.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    done_base = base.run()

    eng = _engine(batch_slots=1, kv_storage=KVStorageConfig(block_tokens=8))
    eng.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    done = eng.run()
    assert done == done_base
    rep = eng.obs_report()["kv"]
    # session 0 seals blocks 0..4 of the shared prefix (restorable
    # blocks end strictly inside the 46-token prompt: (46-1)//8 = 5);
    # session 1 restores all 5 — zero prefill recompute for 40 tokens
    assert rep["warm_hits"] == 5
    assert rep["restored_tokens"] == 40
    assert rep["sealed_blocks"] > 0 and rep["sealed_bytes"] > 0
    # the restored prefill shows up as a strictly shorter admission-to-
    # first-token distance than the cold session's
    meta = eng.request_meta
    ttft = {rid: meta[rid]["first_token_tick"] - meta[rid]["admitted_tick"]
            for rid in (0, 1)}
    assert ttft[1] < ttft[0]


def test_kv_paging_concurrent_identical_prompts_dedup_in_store():
    """Two slots running the SAME prompt concurrently seal the same
    prefix CIDs — the second seal of each block is an ExpertStore-level
    no-op (cross-session dedup), and streams still match the oracle."""
    from repro.serve.engine import KVStorageConfig
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, 64, 30).astype(np.int32)
    reqs = [{"id": rid, "prompt": prompt.copy(), "max_new_tokens": 3}
            for rid in range(2)]

    base = _engine()
    base.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    done_base = base.run()
    eng = _engine(kv_storage=KVStorageConfig(block_tokens=8))
    eng.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    assert eng.run() == done_base
    rep = eng.obs_report()["kv"]
    assert rep["dedup_blocks"] > 0
    # dedup'd blocks were never re-uploaded: one store version per
    # UNIQUE block, regardless of how many sessions sealed it
    assert rep["store"]["versions"] == rep["sealed_blocks"]


def test_kv_page_out_then_readmit_resumes_bit_identically():
    """A mid-decode slot paged out to the chunked store (full blocks +
    partial tail) resumes after readmission with the exact same stream
    as the never-paged oracle."""
    from repro.serve.engine import KVStorageConfig
    rng = np.random.default_rng(1)
    reqs = [{"id": 0, "prompt": rng.integers(0, 64, 20).astype(np.int32),
             "max_new_tokens": 12}]

    base = _engine(prefill_chunk=4)
    base.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    done_base = base.run()

    eng = _engine(prefill_chunk=4,
                  kv_storage=KVStorageConfig(block_tokens=8))
    eng.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    steps = 0
    while (not eng.sched.slots[0].decoding
           or len(eng.sched.slots[0].generated) < 4):
        assert eng.step() and steps < 100
        steps += 1
    rid = eng.page_out(0)                    # mid-decode: tail block too
    assert rid == 0 and not eng.sched.slots[0].active
    assert eng.sched.depth() == 1            # requeued at the front
    assert eng.run() == done_base
    rep = eng.obs_report()["kv"]
    assert rep["pageouts"] == 1 and rep["resumes"] == 1
    assert rep["restored_tokens"] > 0
    assert eng.request_meta[0]["preemptions"] == 1


def test_kv_sealing_keeps_tick_commitments_bit_identical():
    """With DISJOINT prompts (nothing to restore), sealing is pure
    side-band: every tick commitment's (tick, root, request_ids) equals
    the paging-off oracle's, kv_root carries the sealed manifests, and
    the verdict maps match — honest sessions finalize in both."""
    from repro.serve.engine import KVStorageConfig
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=4)
    reqs = [_req(0, 20, 3), _req(1, 17, 3)]

    def run(kv):
        eng = _engine(trust=trust,
                      kv_storage=KVStorageConfig(block_tokens=8)
                      if kv else None)
        eng.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
        done = eng.run()
        verdicts = {rid: ("revoked" if eng.records[rid].revoked
                          else "finalized" if rid in done else "open")
                    for rid in eng.records}
        return eng, done, verdicts

    base, done_b, v_b = run(kv=False)
    kv, done_k, v_k = run(kv=True)
    assert done_k == done_b and v_k == v_b
    assert all(v == "finalized" for v in v_k.values())
    assert [(tc.tick, tc.root, tc.request_ids)
            for tc in kv.tick_commitments] == \
        [(tc.tick, tc.root, tc.request_ids) for tc in base.tick_commitments]
    assert all(tc.kv_root == "" for tc in base.tick_commitments)
    assert any(tc.kv_root != "" for tc in kv.tick_commitments)
    # every sealed block's manifest is reachable for DA challenges
    kvbs = kv.kvrt.kv
    assert len(kvbs.manifests(kvbs.sealed_cids())) \
        == kv.obs_report()["kv"]["sealed_blocks"]


def test_kv_paging_verified_warm_reuse_keeps_verdicts():
    """Warm-prefix reuse under trust: restored prefill changes WHEN
    tokens land (earlier), never WHAT is committed — both sessions
    finalize and post-hoc tampering is still caught."""
    from repro.serve.engine import KVStorageConfig
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=4)
    reqs = _shared_prefix_reqs()
    eng = _engine(batch_slots=1, trust=trust,
                  kv_storage=KVStorageConfig(block_tokens=8))
    eng.submit([dict(r, prompt=r["prompt"].copy()) for r in reqs])
    done = eng.run()
    assert set(done) == {0, 1}
    assert eng.obs_report()["kv"]["warm_hits"] > 0
    assert all(rec.finalized and not rec.revoked
               for rec in eng.records.values())
    eng.records[1].tokens = [t ^ 1 for t in eng.records[1].tokens]
    assert eng.audit_session(1)["revoked"]


def test_kv_storage_validation():
    from repro.serve.engine import KVStorageConfig
    with pytest.raises(ValueError, match="block_tokens"):
        _engine(cache_len=8, kv_storage=KVStorageConfig(block_tokens=8))
    with pytest.raises(ValueError, match="block_tokens"):
        _engine(kv_storage=KVStorageConfig(block_tokens=0))
    eng = _engine()
    with pytest.raises(ValueError, match="kv_storage"):
        eng.page_out(0)                      # paging not configured
    kv_eng = _engine(kv_storage=KVStorageConfig(block_tokens=8))
    with pytest.raises(ValueError, match="not active"):
        kv_eng.page_out(0)                   # no running request


def test_engine_continuous_dependent_revocation_chains_through_admission():
    """Continuous admission deliberately widens the dependent-revocation
    blast radius: a request admitted into a freed slot shares decode
    ticks with the still-running stream, so fraud on the long stream
    voids it too (the fixed-policy pair structure is covered in
    tests/test_pipeline.py)."""
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                        challenge_window=80)
    eng = _engine(trust=trust)
    eng.submit([_req(0, 4, 20), _req(1, 4, 2), _req(2, 4, 2)])
    while eng._done.keys() != {0, 1, 2} and eng.step():
        pass
    # request 2 was admitted into request 1's freed slot while 0 ran
    assert eng.records[2].ticks[0] <= eng.records[0].ticks[-1]
    eng.records[0].tokens = [t ^ 1 for t in eng.records[0].tokens]
    rep = eng.audit_session(0)
    assert rep["revoked"]
    assert eng.records[1].revoked and eng.records[2].revoked
    assert eng.run() == {}
