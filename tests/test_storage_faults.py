"""Storage/serving fault-injection suite: replica loss, corruption
(verified refetch), withheld chunks (DA challenge -> slash), retention
GC after window close, manifest tampering, and chunk-for-chunk
round-trip properties of the chunked store."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem
from repro.storage import (ChunkManifest, ChunkUnavailableError, ExpertStore,
                           StorageNetwork, build_manifest, deserialize_tree,
                           serialize_tree)
from repro.trust.da import DataAvailabilityAuditor
from repro.trust.protocol import TrustConfig


def _tree(seed=0, shape=(40, 30)):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=shape).astype(np.float32),
            "b": np.zeros(shape[-1], np.float32)}


def _store(num_nodes=4, replication=2, chunk_bytes=512, seed=0):
    net = StorageNetwork(num_nodes=num_nodes, replication=replication,
                         seed=seed)
    return net, ExpertStore(net, chunk_bytes=chunk_bytes)


# ------------------------------------------------------------ replicas
def test_node_loss_below_replication_factor_survives():
    net, store = _store(num_nodes=4, replication=2)
    tree = _tree()
    man = store.put_version("e", tree, 0)
    holders = net.replicas(man.chunk_cids[0])
    net.drop_node(holders[0])                 # one of two replicas gone
    back = store.fetch("e", 0, tree)
    np.testing.assert_array_equal(back["w"], tree["w"])


def test_node_loss_at_replication_factor_is_unavailable():
    net, store = _store(num_nodes=4, replication=2)
    tree = _tree()
    man = store.put_version("e", tree, 0)
    for node_id in list(net.replicas(man.chunk_cids[0])):
        net.drop_node(node_id)                # every replica gone
    with pytest.raises(ChunkUnavailableError):
        store.fetch("e", 0, tree)


def test_bitflipped_chunk_verified_refetch_from_healthy_replica():
    """A corrupted replica is skipped (its bytes no longer hash to the
    CID) and the chunk is served from a healthy replica — the fetched
    tree is bit-identical and the fault is recorded."""
    net, store = _store(num_nodes=3, replication=3)
    tree = _tree(1)
    man = store.put_version("e", tree, 0)
    bad = man.chunk_cids[2]
    net.corrupt_replica(bad, net.replicas(bad)[0])
    before = len(net.faults)
    back = store.fetch("e", 0, tree)
    np.testing.assert_array_equal(back["w"], tree["w"])
    np.testing.assert_array_equal(back["b"], tree["b"])
    # the randomized scan order may or may not probe the corrupted
    # replica first; fetch repeatedly to observe the fault record
    for _ in range(8):
        store.fetch("e", 0, tree)
    corrupted = [f for f in net.faults[before:] if f.kind == "corrupted"]
    assert corrupted and all(f.cid == bad for f in corrupted)


def test_withheld_everywhere_raises_chunk_unavailable():
    net, store = _store(num_nodes=3, replication=3)
    tree = _tree(2)
    man = store.put_version("e", tree, 0)
    net.withhold(man.chunk_cids[0])           # every replica withholds
    with pytest.raises(ChunkUnavailableError) as ei:
        store.fetch("e", 0, tree)
    assert ei.value.cid == man.chunk_cids[0]


# ---------------------------------------------------- replica scan order
def test_read_load_balances_across_replicas():
    """Regression: ``get`` used to probe nodes in id order, so the first
    healthy node absorbed every read.  The per-request randomized scan
    spreads reads over all replicas."""
    net = StorageNetwork(num_nodes=4, replication=4, seed=0)
    cid = net.put(b"hot object" * 100)
    for _ in range(400):
        net.get(cid)
    loads = net.read_load()
    assert sum(loads) == 400
    assert min(loads) > 0, loads              # nobody starved
    assert max(loads) < 0.6 * 400, loads      # nobody absorbs the tail


def test_scan_order_does_not_perturb_placement():
    """Reads draw from a separate RNG stream than placement: two
    networks that differ only in read count place later objects on the
    same replicas."""
    a = StorageNetwork(num_nodes=5, replication=2, seed=7)
    b = StorageNetwork(num_nodes=5, replication=2, seed=7)
    cid0 = a.put(b"first")
    b.put(b"first")
    for _ in range(17):
        a.get(cid0)                           # a reads, b does not
    ca = a.put(b"second")
    cb = b.put(b"second")
    assert a.replicas(ca) == b.replicas(cb)


# -------------------------------------------------------- DA challenges
def test_withheld_chunk_da_challenge_slashes_storage_node():
    """System-level: a replica node withholding a committed chunk is DA-
    challenged, fails to produce it by the window deadline, and is
    slashed — recorded as a ``da_slash`` block in the ledger."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(300, 784)).astype(np.float32)
    y = rng.integers(0, 10, 300)
    cfg = BMoEConfig(num_experts=6, num_edges=6, top_k=2,
                     framework="optimistic", pow_difficulty=2, seed=0,
                     da_rate=1.0,
                     trust=TrustConfig(audit_rate=0.1, challenge_window=2))
    s = BMoESystem(cfg)
    man = s.expert_store.manifest("expert/0", 0)
    bad_cid = man.chunk_cids[0]
    bad_node = s.storage.replicas(bad_cid)[0]
    s.storage.withhold(bad_cid, bad_node)
    for r in range(4):
        idx = rng.integers(0, len(x), 48)
        s.train_round(x[idx], y[idx])
    s.flush_trust()
    faults = [f for f in s.da.faults if f.kind == "withheld"]
    assert faults and all(f.executor == bad_node for f in faults)
    assert s.da.stakes.stake[bad_node] < s.da.stakes.initial
    blocks = s.ledger.find_all(kind="da_slash")
    assert blocks and all(b.payload["node"] == bad_node for b in blocks)
    assert s.ledger.verify_chain()


def test_transient_withholding_recovers_without_slash():
    """A node that produces the chunk again before its challenge window
    closes satisfies the challenge late — transient unavailability is
    not punished."""
    net, store = _store(num_nodes=3, replication=2, chunk_bytes=256)
    tree = _tree(3)
    man = store.put_version("e", tree, 0)
    cid = man.chunk_cids[0]
    node = net.replicas(cid)[0]
    net.withhold(cid, node)
    da = DataAvailabilityAuditor(net, num_nodes=3, window=3,
                                 sample_rate=1.0, seed=0)
    da.challenge_round(0, {"e": man})
    assert da.pending()
    net.node(node).withheld.discard(cid)      # node recovers in time
    resolved = da.resolve(5)
    assert all(c.status == "satisfied" for c in resolved
               if c.node_id == node)
    assert da.stats["slashed"] == 0
    assert float(da.stakes.stake.min()) == da.stakes.initial


def test_corrupted_replica_da_slash_and_repair():
    """A replica producing bytes that do not hash to the committed CID
    is slashed immediately and repaired by verified refetch."""
    net, store = _store(num_nodes=3, replication=3, chunk_bytes=256)
    tree = _tree(4)
    man = store.put_version("e", tree, 0)
    cid = man.chunk_cids[1]
    node = net.replicas(cid)[0]
    net.corrupt_replica(cid, node)
    da = DataAvailabilityAuditor(net, num_nodes=3, window=2,
                                 sample_rate=1.0, seed=0)
    da.challenge_round(0, {"e": man})
    assert any(f.kind == "corrupted" and f.executor == node
               for f in da.faults)
    assert da.stakes.stake[node] < da.stakes.initial
    # repaired: the node's copy now hashes back to the CID
    from repro.core.ledger import digest_bytes
    assert digest_bytes(net.node(node).objects[cid]) == cid


def test_da_verdicts_deterministic_across_runs():
    def run():
        net, store = _store(num_nodes=4, replication=2, chunk_bytes=256,
                            seed=3)
        man = store.put_version("e", _tree(5), 0)
        for cid in man.chunk_cids[:3]:
            net.withhold(cid, net.replicas(cid)[0])
        da = DataAvailabilityAuditor(net, num_nodes=4, window=1,
                                     sample_rate=0.5, seed=3)
        da.challenge_round(0, {"e": man})
        da.resolve(None)
        return ([(c.challenge_id, c.node_id, c.status, c.cid)
                 for c in da.challenges],
                [(f.executor, f.cid, f.kind) for f in da.faults])
    assert run() == run()


# ------------------------------------------------ retention / discard
def test_superseded_versions_discarded_after_window_close():
    """Optimistic training retains the expert versions each round
    committed against; once every window closes (flush), superseded
    versions are GC'd from the network while the latest stays
    fetchable."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(300, 784)).astype(np.float32)
    y = rng.integers(0, 10, 300)
    cfg = BMoEConfig(num_experts=6, num_edges=6, top_k=2,
                     framework="optimistic", pow_difficulty=2, seed=0,
                     trust=TrustConfig(audit_rate=0.2, challenge_window=2))
    s = BMoESystem(cfg)
    man_v0 = s.expert_store.manifest("expert/0", 0)
    for r in range(5):
        idx = rng.integers(0, len(x), 48)
        s.train_round(x[idx], y[idx])
    s.flush_trust()
    assert not s._audit_cids                   # every retention released
    # v0 was superseded (expert 0 is routed every round at k=2/N=6) and
    # must be gone: manifest object discarded from every node
    assert not s.storage.has(man_v0.manifest_cid)
    # the latest version still serves — chunk-for-chunk
    latest = s.expert_store.fetch("expert/0", s._bank_version,
                                  s._expert_like)
    np.testing.assert_array_equal(
        np.asarray(latest["w1"]), np.asarray(s.experts["w1"][0]))


def test_identical_republish_is_a_noop_and_gc_still_works():
    """Republishing byte-identical content at the same (or a later)
    version tag must not double-count chunk references — superseded
    versions still garbage-collect afterwards — and must not mint a new
    version tag."""
    net, store = _store(chunk_bytes=256)
    t0 = _tree(9)
    m0 = store.put_version("e", t0, 0)
    assert store.put_version("e", t0, 0).manifest_cid == m0.manifest_cid
    assert store.put_version("e", t0, 3).manifest_cid == m0.manifest_cid
    assert store.stats["noop_versions"] == 2
    assert len(store._versions["e"]) == 1      # no new tags minted
    t1 = {"w": t0["w"] + 1.0, "b": t0["b"]}
    m1 = store.put_version("e", t1, 4)         # supersedes: v0 GC'd
    assert not net.has(m0.manifest_cid)
    only_old = set(m0.chunk_cids) - set(m1.chunk_cids)
    assert only_old and not any(net.has(c) for c in only_old)


def test_reoffered_bytes_heal_fully_corrupted_cid():
    """When every replica of a CID has been corrupted (observed by a
    failed read), a later re-upload of the verified bytes must repair
    the copies instead of being dropped as a dedup no-op."""
    net = StorageNetwork(num_nodes=2, replication=2, seed=0)
    data = b"expert chunk bytes" * 20
    cid = net.put(data)
    for node_id in net.replicas(cid):
        net.corrupt_replica(cid, node_id)
    with pytest.raises(KeyError):
        net.get(cid)                           # observes the corruption
    assert net.put(data) == cid                # honest re-offer heals
    assert net.get(cid) == data
    assert net.stats["healed_puts"] == 2


def test_replay_republish_mints_no_unretained_version_tags():
    """A chained-rollback replay full-bank-republishes every replayed
    version tag; experts the replay left unchanged must not accumulate
    new (never-retained, never-GC-able) manifests."""
    rng = np.random.default_rng(6)
    x = rng.normal(size=(400, 784)).astype(np.float32)
    y = rng.integers(0, 10, 400)
    atk = AttackConfig(malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
    cfg = BMoEConfig(num_experts=6, num_edges=6, top_k=2,
                     framework="optimistic", pow_difficulty=2, seed=0,
                     attack=atk,
                     trust=TrustConfig(audit_rate=0.5, challenge_window=2))
    s = BMoESystem(cfg)
    rng2 = np.random.default_rng(7)
    for _ in range(6):
        idx = rng2.integers(0, len(x), 48)
        s.train_round(x[idx], y[idx])
    assert s.ledger.rollbacks()                # replay happened
    s.flush_trust()
    for e in range(6):
        entries = s.expert_store._versions[f"expert/{e}"]
        # after every window closed, only the latest version (plus at
        # most the genesis tag) remains — nothing accumulated
        assert len(entries) <= 2, (e, entries)


def test_unreferenced_old_version_gc_keeps_shared_chunks():
    net, store = _store(chunk_bytes=256)
    t0 = _tree(6)
    m0 = store.put_version("e", t0, 0)
    t1 = {"w": t0["w"].copy(), "b": t0["b"]}
    t1["w"][0, 0] += 1.0                       # one chunk changes
    m1 = store.put_version("e", t1, 1)         # auto-GC drops v0
    assert not net.has(m0.manifest_cid)
    shared = set(m0.chunk_cids) & set(m1.chunk_cids)
    only_old = set(m0.chunk_cids) - set(m1.chunk_cids)
    assert shared and only_old
    assert all(net.has(c) for c in shared)     # still referenced by v1
    assert not any(net.has(c) for c in only_old)
    back = store.fetch("e", 1, t0)
    np.testing.assert_array_equal(back["w"], t1["w"])


# ------------------------------------------------------ manifest checks
def test_tampered_manifest_rejected():
    net, store = _store()
    man = store.put_version("e", _tree(7), 0)
    blob = man.to_json()
    forged = ChunkManifest.from_json(blob.replace(b'"version": 0',
                                                  b'"version": 9'))
    cid = net.put(forged.to_json())
    # a manifest must hash back to the CID that names it
    assert store.manifest_by_cid(cid).version == 9      # self-consistent
    # ...but forged content sitting under the original CID is rejected:
    # the network's CID verification refuses every tampered replica
    # (KeyError), and even bytes smuggled past it fail the manifest's
    # own self-hash check (ValueError)
    store._manifests.pop(man.manifest_cid, None)
    for node in net.nodes:
        if man.manifest_cid in node.objects:
            node.objects[man.manifest_cid] = forged.to_json()
    with pytest.raises((ValueError, KeyError)):
        store.manifest_by_cid(man.manifest_cid)


def test_chunk_cid_mismatch_pinpointed_without_refetching_rest():
    """A single tampered chunk is identified by its own CID (and its
    Merkle path against the manifest root) — the other chunks verify
    independently."""
    tree = _tree(8, shape=(64, 16))
    man, chunks = build_manifest("e", 0, tree, chunk_bytes=256)
    bad = bytearray(chunks[3])
    bad[0] ^= 0xFF
    assert not man.verify_chunk(3, bytes(bad))
    assert man.verify_chunk(3, chunks[3], man.prove_chunk(3))
    for i, c in enumerate(chunks):
        if i != 3:
            assert man.verify_chunk(i, c, man.prove_chunk(i))


def test_treedef_mismatch_raises_clear_error():
    tree = {"a": {"b": [jnp.ones((2, 2)), jnp.zeros(3)]}}
    data = serialize_tree(tree)
    wrong_like = {"a": jnp.ones((2, 2)), "c": jnp.zeros(3)}
    with pytest.raises(ValueError, match="treedef mismatch"):
        deserialize_tree(data, wrong_like)
    net, store = _store()
    store.put_version("e", tree, 0)
    with pytest.raises(ValueError, match="treedef mismatch"):
        store.fetch("e", 0, wrong_like)


# ------------------------------------------------------- round-trip law
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([64, 256, 4096]),
       depth=st.integers(1, 3))
def test_put_get_roundtrips_arbitrary_pytrees_chunk_for_chunk(seed, chunk,
                                                              depth):
    rng = np.random.default_rng(seed)

    def leaf():
        shape = tuple(rng.integers(1, 9, rng.integers(1, 4)))
        dt = rng.choice([np.float32, np.int32, np.float64])
        return (rng.normal(size=shape) * 100).astype(dt)

    def tree(d):
        if d == 0:
            return leaf()
        kinds = rng.integers(0, 3)
        if kinds == 0:
            return [tree(d - 1) for _ in range(rng.integers(1, 3))]
        if kinds == 1:
            return {f"k{i}": tree(d - 1)
                    for i in range(rng.integers(1, 3))}
        return leaf()

    t = {"root": tree(depth)}
    net, store = _store(chunk_bytes=chunk, seed=seed)
    man = store.put_version("obj", t, 0)
    back = store.fetch("obj", 0, t)
    import jax
    la, lb = (jax.tree_util.tree_leaves(t), jax.tree_util.tree_leaves(back))
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # chunk-for-chunk: re-chunking the fetched tree reproduces the
    # manifest exactly (same CIDs, same root)
    man2, _ = build_manifest("obj", 0, back, chunk_bytes=chunk)
    assert man2.chunk_cids == man.chunk_cids
    assert man2.root == man.root


def test_replay_republish_does_not_void_open_inference_audits():
    """A chained rollback republishes the voided version tags — but an
    open inference round that committed against a voided version must
    keep auditing the manifests it RETAINED, not the replacements:
    its honest executor is never falsely convicted (eager backend, the
    path that recomputes from the fetched bytes)."""
    rng = np.random.default_rng(4)
    x = rng.normal(size=(400, 784)).astype(np.float32)
    y = rng.integers(0, 10, 400)
    atk = AttackConfig(malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
    cfg = BMoEConfig(num_experts=6, num_edges=6, top_k=2,
                     framework="optimistic", pow_difficulty=2, seed=0,
                     attack=atk,
                     trust=TrustConfig(audit_rate=0.5, challenge_window=3,
                                       audit_backend="eager"))
    s = BMoESystem(cfg)
    rng2 = np.random.default_rng(5)
    for _ in range(3):                     # edge 2 executes (and cheats)
        idx = rng2.integers(0, len(x), 48)
        s.train_round(x[idx], y[idx])
    # honest inference committed against the (later voided) bank
    s.infer(x[:32], attack=AttackConfig())
    infer_manifests = list(s._infer_audit_cids[0])
    for _ in range(3):                     # windows close: conviction +
        idx = rng2.integers(0, len(x), 48)  # chained rollback + replay
        s.train_round(x[idx], y[idx])
    assert s.ledger.rollbacks()            # the fraud was confirmed
    # the infer round's retained manifests still serve their bytes even
    # where the replay replaced the version tag
    for cid in infer_manifests:
        assert s.storage.has(cid)
    s.flush_trust()                        # drains the inference audit
    assert not any(ev["event"] == "revoke" for ev in s.infer_log)
    assert any(ev["event"] == "finalize" and ev["round"] == 0
               for ev in s.infer_log)
    # every slash belongs to the malicious edge, none to the infer path
    assert {e.edge for e in s.protocol.stakes.events} == {2}


def test_dense_dispatch_systems_share_the_storage_path():
    cfg = BMoEConfig(num_experts=4, num_edges=4, top_k=2, dispatch="dense",
                     framework="bmoe", pow_difficulty=2, seed=0)
    s = BMoESystem(cfg)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 784)).astype(np.float32)
    y = rng.integers(0, 10, 32)
    s.train_round(x, y)
    assert s.ledger.blocks[-1].payload["bank_root"]
    assert s.expert_store.stats["versions"] >= 4


# --------------------------------------------------- read retry budget
def test_transient_withhold_recovers_within_retry_budget():
    """A flaky replica set (every node refusing once) is healed by the
    read retry loop: the fetch succeeds, booking retries + modeled
    backoff seconds instead of surfacing an error."""
    net, store = _store(num_nodes=3, replication=3)
    tree = _tree()
    man = store.put_version("e", tree, 0)
    cid = man.chunk_cids[0]
    net.withhold(cid, transient=1)            # every replica refuses once
    before = dict(net.stats)
    data = net.get(cid)
    assert data is not None
    assert net.stats["retries"] - before["retries"] >= 1
    assert net.stats["modeled_backoff_s"] > before["modeled_backoff_s"]


def test_retry_budget_exhausted_is_hard_data_unavailable():
    """A permanent full withhold burns the whole retry budget and then
    surfaces DataUnavailable (a KeyError, so DA challenges still fire)."""
    from repro.storage import DataUnavailable
    net, store = _store(num_nodes=3, replication=3)
    man = store.put_version("e", _tree(), 0)
    cid = man.chunk_cids[0]
    net.withhold(cid)                         # permanent, every replica
    with pytest.raises(DataUnavailable) as exc:
        net.get(cid)
    assert exc.value.retries == net.retry_budget
    assert isinstance(exc.value, KeyError)
    assert net.stats["retries"] == net.retry_budget
    # the booked backoff is the full exponential schedule
    expect = sum(net.backoff_base_s * 2 ** k
                 for k in range(net.retry_budget))
    assert net.stats["modeled_backoff_s"] == pytest.approx(expect)
    # budget books once per get(): a second attempt doubles the counter
    with pytest.raises(DataUnavailable):
        net.get(cid)
    assert net.stats["retries"] == 2 * net.retry_budget


def test_retry_backoff_is_deterministic():
    """Two identically-seeded networks book identical retry/backoff
    totals — modeled time, not wall clock."""
    def run():
        net, store = _store(num_nodes=3, replication=3, seed=7)
        man = store.put_version("e", _tree(), 0)
        net.withhold(man.chunk_cids[0], transient=2)
        net.get(man.chunk_cids[0])
        return net.stats["retries"], net.stats["modeled_backoff_s"]
    assert run() == run()


# ------------------------------------------------- node drop vs fetch
def test_drop_node_with_repair_restores_replication():
    """Dropping a replica holder mid-run with repair=True re-replicates
    from the surviving copy — a fetch racing the drop still succeeds and
    the object is back at full replication on the remaining nodes."""
    net, store = _store(num_nodes=4, replication=2)
    tree = _tree()
    man = store.put_version("e", tree, 0)
    cid = man.chunk_cids[0]
    holders = net.replicas(cid)
    net.drop_node(holders[0], repair=True)
    assert net.stats["repaired_replicas"] >= 1
    assert len(net.replicas(cid)) == net.replication
    back = store.fetch("e", 0, tree)          # fetch after the drop
    np.testing.assert_array_equal(back["w"], tree["w"])


def test_losing_last_replica_is_a_trust_event_not_a_keyerror():
    """When a drop takes the LAST replica with it the network records a
    "lost" ReplicaFault + lost_objects tick, and later fetches surface a
    typed DataUnavailable naming the loss — not an uncaught KeyError
    from some node's dict."""
    from repro.storage import DataUnavailable
    net, store = _store(num_nodes=4, replication=2)
    man = store.put_version("e", _tree(), 0)
    cid = man.chunk_cids[0]
    for node_id in list(net.replicas(cid)):
        net.drop_node(node_id)                # no repair possible at the end
    lost = [f for f in net.faults if f.kind == "lost" and f.cid == cid]
    assert lost, "last-replica loss must surface a trust event"
    assert net.stats["lost_objects"] >= 1
    with pytest.raises(DataUnavailable) as exc:
        net.get(cid)
    assert "lost" in str(exc.value)
    # the store layer converts it to its own typed unavailability
    with pytest.raises(ChunkUnavailableError):
        store.fetch("e", 0, _tree())
