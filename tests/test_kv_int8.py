"""int8 KV-cache (§Perf iteration 4): quantized decode stays close to the
full-precision forward; cache structure carries scales."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.builder import materialize
from repro.models.transformer import cache_decl, forward_decode, forward_train, model_decl


def test_int8_decode_matches_forward():
    cfg = get_config("qwen2.5-3b", smoke=True)
    key = jax.random.PRNGKey(0)
    params = materialize(model_decl(cfg), key)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab_size)
    full, _ = forward_train(params, toks, cfg, remat=False, q_chunk=8,
                            kv_chunk=8)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    caches = materialize(cache_decl(cfg8, 1, 32), key)
    assert caches["blocks"]["0"]["k"].dtype == jnp.int8
    assert caches["blocks"]["0"]["k_scale"].dtype == jnp.float32
    step = jax.jit(lambda c, t, p: forward_decode(params, c, t, p, cfg8))
    outs = []
    for t in range(24):
        lg, caches = step(caches, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.abs(dec - full).max())
    assert err < 0.15, err
    # quantized cache halves the K/V payload bytes
    kb = caches["blocks"]["0"]["k"]
    assert kb.dtype.itemsize == 1


def test_int8_sealed_kv_blocks_halve_chunk_payload():
    """Paging the quantized cache pays off on the wire: an int8 sealed
    block's chunk payload is less than half the fp32 block's (int8 K/V
    rows plus small f32 scale rows vs f32 rows)."""
    import numpy as np

    from repro.models.transformer import slice_kv_block
    from repro.storage import (KV_GENESIS, ExpertCache, ExpertStore,
                               KVBlockStore, StorageNetwork, prefix_cid)

    cfg = get_config("qwen2.5-3b", smoke=True)
    key = jax.random.PRNGKey(0)
    sizes = {}
    for name, c in (("fp32", cfg),
                    ("int8", dataclasses.replace(cfg,
                                                 kv_cache_dtype="int8"))):
        caches = materialize(cache_decl(c, 1, 32), key)
        block = slice_kv_block(caches, 0, 0, 16)
        net = StorageNetwork(num_nodes=2, replication=1, seed=0)
        store = ExpertStore(net, chunk_bytes=1 << 12)
        kv = KVBlockStore(store, ExpertCache(store, None))
        man = kv.seal(prefix_cid(KV_GENESIS, np.arange(16)), block, 16)
        sizes[name] = man.total_bytes
        assert kv.stats["sealed_bytes"] == man.total_bytes
    assert 2 * sizes["int8"] <= sizes["fp32"]
    # ...but not a free 4x: the f32 scale rows ride along in the block
    assert 4 * sizes["int8"] > sizes["fp32"]


def test_int8_window_cache():
    cfg = dataclasses.replace(get_config("gemma3-27b", smoke=True),
                              kv_cache_dtype="int8")
    key = jax.random.PRNGKey(1)
    params = materialize(model_decl(cfg), key)
    caches = materialize(cache_decl(cfg, 2, 64), key)
    tok = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
    logits, new_caches = forward_decode(params, caches, tok, jnp.int32(40),
                                        cfg)
    assert not bool(jnp.isnan(logits).any())
    assert new_caches["blocks"]["0"]["k"].dtype == jnp.int8
