"""Equivalence and accounting tests for the batched audit engine.

The batched path (``VerifierPool.plan_audits`` / ``audit_batched``, one
grouped recompute call + one fused ``leaf_digest_batch`` pass per round)
must be observationally identical to the eager per-chunk reference
oracle (``audit_one``): same sampled leaves, same lazy coins, identical
Merkle roots, byte-identical leaf digests, and field-identical fraud
proofs — under honest, tampered, and lazy-verifier scenarios, including
the padded-tail leaves of a non-divisible batch.  The one intended
difference is ``recomputed_leaves``: the batched planner dedupes
(expert, leaf) pairs across verifiers, so summed recompute counts real
work (regression-pinned below).
"""
import numpy as np
import pytest

from repro.trust.audit import VerifierPool
from repro.trust.commitments import (chunk_bounds, commit_outputs,
                                     leaf_digest, leaf_digest_batch)


def _batch_fn(honest):
    """BatchRecomputeFn over a dense honest (N, B, C) tensor.  Padded
    tail rows are NaN-poisoned: if any test digest matched one, padding
    would have leaked into a hash."""
    def fn(experts, slices):
        cmax = max(sl.stop - sl.start for sl in slices)
        out = np.full((len(experts), cmax) + honest.shape[2:], np.nan,
                      honest.dtype)
        for s, (e, sl) in enumerate(zip(experts, slices)):
            out[s, :sl.stop - sl.start] = honest[e, sl]
        return out
    return fn


def _assert_proofs_equal(got, want):
    assert len(got) == len(want)
    for p, q in zip(got, want):
        assert (p.round_id, p.executor, p.leaf_index, p.expert,
                p.claimed_digest, p.recomputed_digest, p.verifier) == \
               (q.round_id, q.executor, q.leaf_index, q.expert,
                q.claimed_digest, q.recomputed_digest, q.verifier)
        assert p.path == q.path
        np.testing.assert_array_equal(p.claimed_chunk, q.claimed_chunk)


def _assert_reports_equivalent(batched, eager):
    """Everything identical except the deduped recompute accounting."""
    assert len(batched) == len(eager)
    for b, e in zip(batched, eager):
        assert (b.round_id, b.verifier, b.lazy) == \
               (e.round_id, e.verifier, e.lazy)
        assert b.sampled_leaves == e.sampled_leaves
        _assert_proofs_equal(b.fraud_proofs, e.fraud_proofs)


# ----------------------------------------------------- fused leaf hashing
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int64])
def test_leaf_digest_batch_matches_leaf_digest(dtype):
    rng = np.random.default_rng(0)
    stack = rng.normal(size=(5, 7, 3)).astype(dtype)
    assert leaf_digest_batch(stack) == [leaf_digest(stack[s])
                                        for s in range(5)]
    lengths = [7, 3, 1, 6, 7]
    assert leaf_digest_batch(stack, lengths) == \
        [leaf_digest(stack[s, :n]) for s, n in enumerate(lengths)]


def test_leaf_digest_batch_rejects_bad_input():
    with pytest.raises(ValueError):
        leaf_digest_batch(np.zeros(4))
    with pytest.raises(ValueError):
        leaf_digest_batch(np.zeros((4, 2)), lengths=[1, 2])


@pytest.mark.parametrize("batch,chunks", [(12, 4), (13, 4), (7, 3), (5, 8)])
def test_commit_outputs_root_matches_manual_digests(batch, chunks):
    """commit_outputs' batched hashing reproduces the per-leaf eager
    digests (and so the root) for divisible AND ragged chunkings."""
    rng = np.random.default_rng(1)
    outs = rng.normal(size=(3, batch, 5)).astype(np.float32)
    com = commit_outputs(outs, round_id=0, executor=1,
                         chunks_per_expert=chunks)
    bounds = chunk_bounds(batch, chunks)
    manual = [leaf_digest(outs[e, bounds[c]:bounds[c + 1]])
              for e in range(3) for c in range(len(bounds) - 1)]
    assert com.leaf_digests == manual


# ------------------------------------------------------ plan equivalence
def test_plan_matches_eager_sampling_and_lazy_coins():
    pool = VerifierPool(num_verifiers=4, audit_rate=0.3, lazy_prob=0.5,
                        seed=7)
    for round_id in range(5):
        plan = pool.plan_audits(round_id, num_leaves=40)
        for v in range(4):
            assert plan.sampled[v] == pool.sample_leaves(round_id, v, 40)
            assert plan.lazy[v] == bool(
                pool._rng(round_id, v, salt=1).random() < pool.lazy_prob)
        # unique leaves are exactly the non-lazy union, each owned by its
        # first non-lazy sampler
        union = sorted({leaf for v in range(4) if not plan.lazy[v]
                        for leaf in plan.sampled[v]})
        assert plan.unique_leaves == union
        for leaf, owner in plan.owner.items():
            assert not plan.lazy[owner] and leaf in plan.sampled[owner]
            for v in range(owner):
                assert plan.lazy[v] or leaf not in plan.sampled[v]


# ------------------------------------------------- eager <-> batched
@pytest.mark.parametrize("batch", [16, 13])   # divisible + padded tail
def test_batched_matches_eager_honest(batch):
    rng = np.random.default_rng(2)
    honest = rng.normal(size=(4, batch, 3)).astype(np.float32)
    com = commit_outputs(honest, round_id=3, executor=1, chunks_per_expert=4)
    pool = VerifierPool(num_verifiers=3, audit_rate=0.5, seed=1)
    eager = pool.audit(com, lambda e, sl: honest[e, sl])
    batched = pool.audit_batched(com, _batch_fn(honest))
    _assert_reports_equivalent(batched, eager)
    assert all(r.clean for r in batched)


@pytest.mark.parametrize("batch", [16, 13])
def test_batched_matches_eager_tampered(batch):
    """Corrupted leaves yield identical fraud proofs (index, expert,
    digests, Merkle path, claimed chunk bytes, verifier) on both paths."""
    rng = np.random.default_rng(3)
    honest = rng.normal(size=(4, batch, 3)).astype(np.float32)
    claimed = honest.copy()
    claimed[2] += 1.0                                  # expert 2 corrupted
    claimed[0, -1] += 0.5                              # tail leaf corrupted
    com = commit_outputs(claimed, round_id=9, executor=0,
                         chunks_per_expert=4)
    pool = VerifierPool(num_verifiers=3, audit_rate=1.0, seed=2)
    eager = pool.audit(com, lambda e, sl: honest[e, sl])
    batched = pool.audit_batched(com, _batch_fn(honest))
    _assert_reports_equivalent(batched, eager)
    assert any(r.fraud_proofs for r in batched)
    # the corrupted tail leaf of the ragged batch is among the catches
    tail_leaf = 0 * com.chunks_per_expert + (com.chunks_per_expert - 1)
    assert any(p.leaf_index == tail_leaf
               for r in batched for p in r.fraud_proofs)


def test_batched_lazy_verifiers_do_no_work():
    rng = np.random.default_rng(4)
    honest = rng.normal(size=(2, 8, 3)).astype(np.float32)
    com = commit_outputs(honest + 5.0, round_id=0, executor=0,
                         chunks_per_expert=2)          # everything corrupted
    pool = VerifierPool(num_verifiers=4, audit_rate=1.0, lazy_prob=1.0,
                        seed=0)
    calls = []

    def counting_fn(experts, slices):
        calls.append(len(experts))
        return _batch_fn(honest)(experts, slices)

    reports = pool.audit_batched(com, counting_fn)
    assert calls == []                     # all lazy: recompute never runs
    assert all(r.lazy and r.clean and r.recomputed_leaves == 0
               for r in reports)
    _assert_reports_equivalent(reports,
                               pool.audit(com, lambda e, sl: honest[e, sl]))


def test_batched_is_one_recompute_call():
    rng = np.random.default_rng(5)
    honest = rng.normal(size=(4, 16, 3)).astype(np.float32)
    com = commit_outputs(honest, round_id=1, executor=0, chunks_per_expert=4)
    pool = VerifierPool(num_verifiers=3, audit_rate=1.0, seed=3)
    calls = []

    def counting_fn(experts, slices):
        calls.append(len(experts))
        return _batch_fn(honest)(experts, slices)

    pool.audit_batched(com, counting_fn)
    assert calls == [com.num_leaves]       # one call, fully deduped


# -------------------------------------------------- dedupe accounting
def test_recomputed_leaves_deduped_across_verifiers():
    """Regression (the audit_one duplicate-recompute bug): at
    audit_rate=1.0 every verifier samples every leaf; eager recompute
    cost triples, the batched planner pays each leaf once and credits it
    to the first non-lazy sampler."""
    rng = np.random.default_rng(6)
    honest = rng.normal(size=(3, 12, 2)).astype(np.float32)
    com = commit_outputs(honest, round_id=0, executor=0, chunks_per_expert=3)
    pool = VerifierPool(num_verifiers=3, audit_rate=1.0, seed=4)
    eager = pool.audit(com, lambda e, sl: honest[e, sl])
    batched = pool.audit_batched(com, _batch_fn(honest))
    assert sum(r.recomputed_leaves for r in eager) == 3 * com.num_leaves
    assert sum(r.recomputed_leaves for r in batched) == com.num_leaves
    # verifier 0 samples first, so it owns every leaf here
    assert [r.recomputed_leaves for r in batched] == [com.num_leaves, 0, 0]
    # duplicate sampling still yields every verifier's own fraud proofs
    bad = commit_outputs(honest + 1.0, round_id=0, executor=0,
                         chunks_per_expert=3)
    reports = pool.audit_batched(bad, _batch_fn(honest))
    assert all(len(r.fraud_proofs) == bad.num_leaves for r in reports)


def test_ownership_skips_lazy_verifiers():
    pool = VerifierPool(num_verifiers=2, audit_rate=1.0, lazy_prob=0.5,
                        seed=11)
    # find a round where verifier 0 is lazy and verifier 1 is not
    round_id = next(r for r in range(64)
                    if pool._rng(r, 0, salt=1).random() < 0.5
                    and not pool._rng(r, 1, salt=1).random() < 0.5)
    plan = pool.plan_audits(round_id, num_leaves=10)
    assert plan.lazy[0] and not plan.lazy[1]
    assert plan.unique_leaves == plan.sampled[1]
    assert all(v == 1 for v in plan.owner.values())


# ------------------------------------------------ system-level wiring
def test_bmoe_batched_and_eager_rounds_are_equivalent():
    """End-to-end: optimistic training rounds under attack produce the
    same commit roots, verdicts, rollbacks, and slashing events whether
    audits run eagerly or through the batched engine — and the batched
    engine's verify-compute ledger never exceeds the eager one."""
    from repro.core.attacks import AttackConfig
    from repro.core.bmoe import BMoEConfig, BMoESystem
    from repro.core.reputation import ReputationConfig
    from repro.data.synthetic import FMNIST, make_image_dataset
    from repro.trust.protocol import TrustConfig

    xtr, ytr, _, _ = make_image_dataset(FMNIST, n_train=600, n_test=100,
                                        seed=0)
    xtr = xtr.reshape(len(xtr), -1)
    atk = AttackConfig(malicious_edges=(7, 8, 9), attack_prob=1.0,
                       noise_std=5.0)

    def run(backend):
        cfg = BMoEConfig(
            framework="optimistic", attack=atk, pow_difficulty=2,
            reputation=ReputationConfig(init=0.5, gain=0.01, slash=0.4,
                                        exclusion_threshold=0.2),
            trust=TrustConfig(audit_rate=0.3, challenge_window=2,
                              audit_backend=backend))
        s = BMoESystem(cfg)
        rng = np.random.default_rng(0)
        for _ in range(6):
            idx = rng.integers(0, len(xtr), 48)
            s.train_round(xtr[idx], ytr[idx])
        return s

    eager, batched = run("eager"), run("batched")
    pe = [b.payload for b in eager.ledger.blocks[1:]]
    pb = [b.payload for b in batched.ledger.blocks[1:]]
    for a, b in zip(pe, pb):
        assert a["commit_root"] == b["commit_root"]
        assert a.get("rolled_back") == b.get("rolled_back")
        assert a.get("fraud_proofs") == b.get("fraud_proofs")
        assert a["loss"] == b["loss"]
    assert {ev.edge for ev in eager.protocol.stakes.events} == \
           {ev.edge for ev in batched.protocol.stakes.events}
    assert batched.verify_stats["verify_evals"] <= \
        eager.verify_stats["verify_evals"]


def test_serving_audit_catches_consistent_leaf_rewrite():
    """Regression: rewriting BOTH a session record and its leaf digest
    consistently defeats the digest comparison (recompute matches the
    rewritten leaf) — only the Merkle-path check against the SEALED root
    catches it.  The batched audit_session must keep that check."""
    from repro.serve.engine import _tick_leaf
    from repro.trust.protocol import TrustConfig

    eng = _make_sealed_engine(
        TrustConfig(audit_rate=1.0, num_verifiers=1, challenge_window=3))
    rid = next(iter(eng.records))
    rec = eng.records[rid]
    leaf = len(rec.tokens) // 2
    rec.tokens[leaf] ^= 1                       # rewrite the record...
    rec.leaves[leaf] = _tick_leaf(rid, rec.ticks[leaf],
                                  rec.tokens[leaf])   # ...and its digest
    rep = eng.audit_session(rid)
    assert leaf in rep["mismatches"] and rep["revoked"]
    assert rid not in eng.completed


def _make_sealed_engine(trust):
    from repro.configs import get_config
    from repro.data.synthetic import serving_requests
    from repro.serve.engine import ServingEngine
    from repro.train.loop import init_model

    cfg = get_config("smollm-360m", smoke=True)
    eng = ServingEngine(cfg, init_model(cfg, seed=0), batch_slots=2,
                        cache_len=64, trust=trust)
    eng.submit(list(serving_requests(cfg.vocab_size, 2, max_prompt=6,
                                     max_new=6, seed=3)))
    eng.run()
    return eng


def test_serving_session_commitment_roundtrip():
    """A sealed session's RoundCommitment view reproduces its leaves, so
    the shared batched auditor checks serving sessions too."""
    from repro.serve.engine import SessionRecord, _tick_leaf

    rec = SessionRecord(request_id=5)
    for tick, token in [(3, 11), (4, 7), (6, 2)]:
        rec.append(tick, token)
    rec.seal()
    com = rec.commitment()
    assert com.num_leaves == 3 and com.root == rec.root
    for i in range(3):
        assert com.leaf_digests[i] == rec.leaves[i]
        assert leaf_digest(com.leaf_chunk(i)) == \
            _tick_leaf(5, rec.ticks[i], rec.tokens[i])
