"""Blockchain-layer tests: ledger integrity, PoW, storage CIDs, smart
contracts, and majority-consensus properties (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.consensus import ProofOfWork, majority_tree_vote, majority_vote
from repro.core.contracts import ContractEngine
from repro.core.ledger import Block, Ledger, digest_tree
from repro.core.storage import StorageNetwork, deserialize_tree, serialize_tree


# ------------------------------------------------------------- ledger
def test_ledger_chain_and_tamper_detection():
    led = Ledger()
    pow_ = ProofOfWork(4, difficulty_bits=4)
    for r in range(5):
        led.append(pow_.mine(len(led.blocks), led.head.hash, {"round": r}))
    assert led.verify_chain()
    assert all(pow_.verify(b) for b in led.blocks[1:])
    # tamper with a middle block -> chain invalid (hash link breaks)
    led.blocks[2].payload["round"] = 999
    assert not led.verify_chain()


def test_ledger_rejects_bad_block():
    led = Ledger()
    with pytest.raises(ValueError):
        led.append(Block(index=1, prev_hash="not-the-head", payload={}))


def test_digest_tree_sensitivity():
    import jax.numpy as jnp
    t1 = {"a": jnp.ones((3, 3)), "b": [jnp.zeros(2)]}
    t2 = {"a": jnp.ones((3, 3)), "b": [jnp.zeros(2)]}
    assert digest_tree(t1) == digest_tree(t2)
    t3 = {"a": jnp.ones((3, 3)).at[0, 0].set(1 + 1e-6), "b": [jnp.zeros(2)]}
    assert digest_tree(t1) != digest_tree(t3)


# ---------------------------------------------------------- consensus
@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 12), bad=st.integers(0, 12), seed=st.integers(0, 5))
def test_majority_vote_threshold_property(m, bad, seed):
    """Paper §IV-B: colluding coalition below 50% never wins; above 50%
    always wins (for identical colluding results)."""
    bad = min(bad, m)
    rng = np.random.default_rng(seed)
    honest = rng.normal(size=(4, 4)).astype(np.float32)
    manip = honest + rng.normal(size=(4, 4)).astype(np.float32) * 3
    results = [manip.copy() if i < bad else honest.copy() for i in range(m)]
    v = majority_vote(results)
    honest_wins = np.allclose(results[v.winner], honest)
    if 2 * bad < m:
        assert honest_wins
        assert v.accepted
    elif 2 * bad > m:
        assert not honest_wins


def test_majority_tree_vote():
    import jax.numpy as jnp
    honest = {"w": jnp.ones((4,))}
    bad = {"w": jnp.zeros((4,))}
    v = majority_tree_vote([honest, honest, bad], digest_tree)
    assert v.winner in (0, 1) and v.support == 2 and v.accepted


def test_pow_difficulty_and_power_bias():
    pow_ = ProofOfWork(4, difficulty_bits=6, mining_power=[100, 1, 1, 1],
                       seed=0)
    miners = [pow_.mine(i, "0" * 64, {"i": i}).miner for i in range(20)]
    assert sum(1 for m in miners if m == 0) >= 15  # power-weighted winner


# ------------------------------------------------------------ storage
def test_storage_cid_roundtrip_and_verification():
    import jax.numpy as jnp
    store = StorageNetwork(num_nodes=4, replication=2, seed=0)
    tree = {"w1": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)}
    cid = store.put_tree(tree)
    back = store.get_tree(cid, tree)
    np.testing.assert_array_equal(np.asarray(back["w1"]),
                                  np.asarray(tree["w1"]))
    # content addressing: same content -> same CID
    assert store.put_tree(tree) == cid


def test_storage_detects_corrupted_replica():
    store = StorageNetwork(num_nodes=3, replication=3, seed=0)
    cid = store.put(b"expert-weights-v1")
    store.nodes[0].objects[cid] = b"tampered!"   # corrupt one replica
    assert store.get(cid) == b"expert-weights-v1"  # served from honest node


def test_storage_survives_node_loss():
    store = StorageNetwork(num_nodes=4, replication=4, seed=0)
    cid = store.put(b"data")
    store.drop_node(0)
    assert store.get(cid) == b"data"


def test_serialize_roundtrip_nested():
    import jax.numpy as jnp
    tree = {"a": {"b": [jnp.ones((2, 2)), jnp.zeros(3)]},
            "c": jnp.arange(4)}
    data = serialize_tree(tree)
    back = deserialize_tree(data, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]["b"][0]),
                                  np.ones((2, 2)))


# ----------------------------------------------------------- contracts
def test_contract_engine_fires_on_condition():
    eng = ContractEngine()
    hits = []
    eng.register("on_task", lambda e: e.get("type") == "task_published",
                 lambda e: hits.append(e["round"]))
    eng.emit({"type": "task_published", "round": 1})
    eng.emit({"type": "other", "round": 2})
    eng.emit({"type": "task_published", "round": 3})
    assert hits == [1, 3]
    assert eng.contracts[0].fired == 2
    assert len(eng.log) == 2
