"""Per-architecture smoke tests (assignment deliverable (f)): a REDUCED
variant of each family (<=2 layers, d_model<=512, <=4 experts) runs one
forward AND one train step on CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import encdec
from repro.models.builder import materialize
from repro.models.transformer import cache_decl, forward_decode, forward_train, model_decl
from repro.optim import adamw
from repro.train.step import make_train_step

B, S = 2, 64


def _batch_for(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    elif cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    batch = _batch_for(cfg, key)
    if cfg.is_encoder_decoder:
        params = materialize(encdec.encdec_decl(cfg), key)
        logits, aux = encdec.forward_train(params, batch["frames"],
                                           batch["tokens"], cfg, remat=False)
        exp_seq = S
    else:
        params = materialize(model_decl(cfg), key)
        logits, aux = forward_train(params, batch["tokens"], cfg,
                                    prefix_embeds=batch.get("patches"),
                                    remat=False, q_chunk=32, kv_chunk=32)
        exp_seq = S + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, exp_seq, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    batch = _batch_for(cfg, key)
    from repro.train.loop import init_model
    params = init_model(cfg, seed=0)
    opt_state = adamw.init(params)
    step = jax.jit(make_train_step(cfg, adamw.AdamWConfig(total_steps=10),
                                   remat=False))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, new_params, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "seamless-m4t-medium"])
def test_decode_step_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = materialize(model_decl(cfg), key)
    caches = materialize(cache_decl(cfg, B, 128), key)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_caches = forward_decode(params, caches, tok, jnp.int32(3),
                                        cfg)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert (jax.tree_util.tree_structure(new_caches)
            == jax.tree_util.tree_structure(caches))


def test_encdec_decode():
    cfg = get_config("seamless-m4t-medium", smoke=True)
    key = jax.random.PRNGKey(3)
    params = materialize(encdec.encdec_decl(cfg), key)
    caches = materialize(encdec.encdec_cache_decl(cfg, B, 128, 64), key)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, _ = encdec.forward_decode(params, caches, tok, jnp.int32(3), cfg)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
