"""Open-loop serving benchmark: continuous batching vs the fixed-slot
baseline — the CI gate for the serving-engine rebuild.

An open-loop load generator (arrivals don't wait for completions —
Poisson by default, a bursty built-in or a replayed JSON trace
otherwise; arrival times are in ENGINE TICKS, the one time unit both
schedules share) drives two ``ServingEngine`` instances over the
*same* seeded request trace and arrival schedule:
``scheduling="continuous"``
(per-tick admit/evict + fused chunked prefill) and ``scheduling="fixed"``
(batch-synchronous admission, prompts token-by-token through decode —
the engine this repo shipped before the rebuild).  Both engines are
warmed up first (every pow2 fused-chunk width bucket) so compile time
never lands in the measured window.

Measured per policy, from the engine's own metrics registry:

- **goodput** — tokens/s of SLO-meeting requests (time-to-first-token
  within ``--slo-ticks`` engine ticks of submission) over measured
  serving wall-clock; also raw tokens/s and total engine ticks;
- **token latency** — p50/p99 wall seconds per emitted token
  (``serve.token_latency_s``);
- **slot occupancy** — mean/p50 of the per-tick occupied-slot fraction,
  plus mean time-to-first-token in ticks.

A separate verified phase (trust on, audit_rate=1.0) checks the trust
contract of the rebuild on a smaller trace: per-request verdict maps
must be EQUAL across schedules — every honest request finalizes in
both, tampering the same request post-serve revokes it in both — and
reports the batched-commitment amortization (Merkle appends per tick
vs per-stream leaves).

Writes ``BENCH_serving.json`` and exits non-zero (the CI gate) if
continuous goodput does not beat fixed by ``--min-speedup``, if
latency percentiles are missing, if the two schedules' token streams
differ, or if the verdict maps diverge.

Env: ``REPRO_BENCH_SERVE_REQUESTS`` overrides the measured request
count (default 32; hundreds work — the generator is open-loop).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.obs import Observability
from repro.serve.engine import ServingEngine
from repro.train.loop import init_model
from repro.trust.protocol import TrustConfig

ARCH = "smollm-360m"
MAX_DRIVER_STEPS = 200_000


# ------------------------------------------------------------ workload
def make_requests(num, vocab, *, max_prompt, max_new, seed, id_base=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(num):
        plen = int(rng.integers(4, max_prompt))
        out.append({"id": id_base + i,
                    "prompt": rng.integers(0, vocab, size=plen)
                    .astype(np.int32),
                    "max_new_tokens": int(rng.integers(1, max_new))})
    return out


def arrival_schedule(kind, num, rate, seed, trace_path=None):
    """Request index -> arrival time in ENGINE TICKS.  Ticks are the
    one time unit both schedules share (a fixed-slot step is one tick,
    a fused continuous step is C ticks), so the same schedule applies
    the same load to both.  Open loop: the schedule is fixed up front,
    arrivals never wait for completions."""
    if kind == "trace":
        with open(trace_path) as f:
            steps = [int(e["at_tick"]) for e in json.load(f)][:num]
        if len(steps) < num:
            raise SystemExit(f"trace has {len(steps)} arrivals, need {num}")
        return steps
    if kind == "bursty":
        # deterministic closed-form burst train: 1/4 of the load at once
        # every burst/rate ticks — stresses queue drain + admission
        burst = max(num // 4, 1)
        gap = max(int(burst / max(rate, 1e-9)), 1)
        return [(i // burst) * gap for i in range(num)]
    rng = np.random.default_rng(seed + 101)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=num)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


# -------------------------------------------------------------- driver
def drive(eng, schedule, requests, *, stop_at_done=False):
    """Open-loop drive: submit each arrival once the engine clock
    reaches its tick, step the engine, and fast-forward the clock over
    idle gaps (an idle engine waiting for the next arrival models idle
    wall time, not compute).  Returns macro-steps consumed."""
    order = sorted(range(len(requests)), key=lambda i: schedule[i])
    k = 0
    for i in range(MAX_DRIVER_STEPS):
        batch = []
        while k < len(order) and schedule[order[k]] <= eng.tick:
            batch.append(requests[order[k]])
            k += 1
        if batch:
            eng.submit(batch)
        busy = eng.step()
        draining = (k < len(order) or eng.sched.any_active
                    or eng.sched.depth())
        if not draining and (stop_at_done or not busy):
            return i + 1
        if not busy and not eng.sched.any_active and k < len(order) \
                and not eng.sched.depth():
            eng.tick = max(eng.tick, int(schedule[order[k]]))
    raise RuntimeError("driver did not converge")


def warmup(eng):
    """Compile every fused-step width bucket before the measured window
    (``ServingEngine.warmup``), then reset the engine's metrics so
    compiles never count."""
    eng.warmup()
    eng.obs = Observability()          # fresh registry: measured-only


def measure(policy, cfg, params, requests, schedule, args):
    eng = ServingEngine(cfg, params, batch_slots=args.slots,
                        cache_len=args.cache_len, scheduling=policy,
                        prefill_chunk=args.prefill_chunk)
    warmup(eng)
    base_done = dict(eng._done)
    base_steps = eng.steps
    steps = drive(eng, schedule, requests)
    rep = eng.obs_report()
    done = {rid: toks for rid, toks in eng._done.items()
            if rid not in base_done}
    meta = eng.request_meta
    ttft = {r["id"]: meta[r["id"]]["first_token_tick"]
            - meta[r["id"]]["submitted_tick"]
            for r in requests if meta[r["id"]]["first_token_tick"] >= 0}
    slo_ok = [rid for rid, t in ttft.items() if t <= args.slo_ticks]
    wall = rep["tick_s"]
    tokens = sum(len(v) for v in done.values())
    good_tokens = sum(len(done[rid]) for rid in slo_ok if rid in done)
    lat = rep["token_latency"]
    return {
        "policy": policy,
        "driver_steps": steps,
        "engine_ticks": rep["ticks"],
        "compiled_dispatches": eng.steps - base_steps,
        "wall_s": wall,
        "tokens": tokens,
        "throughput_tok_s": tokens / max(wall, 1e-9),
        "goodput_tok_s": good_tokens / max(wall, 1e-9),
        "slo_met_requests": len(slo_ok),
        "requests": len(done),
        "token_latency_p50_s": lat["p50"],
        "token_latency_p99_s": lat["p99"],
        "ttft_ticks_mean": float(np.mean(list(ttft.values()))) if ttft
        else 0.0,
        "occupancy_mean": rep["occupancy"]["mean"],
        "prefill_s": rep["prefill_s"],
        "decode_s": rep["decode_s"],
    }, done


# ----------------------------------------------------- verified phase
def verdict_run(policy, cfg, params, requests, schedule, args,
                tamper_rid=None):
    trust = TrustConfig(audit_rate=1.0, num_verifiers=1,
                        challenge_window=args.challenge_window)
    eng = ServingEngine(cfg, params, batch_slots=args.slots,
                        cache_len=args.cache_len, scheduling=policy,
                        prefill_chunk=args.prefill_chunk, trust=trust)
    drive(eng, schedule, requests, stop_at_done=True)
    if tamper_rid is not None:
        rec = eng.records[tamper_rid]
        rec.tokens = [t ^ 1 for t in rec.tokens]
    done = eng.run()
    verdicts = {rid: ("revoked" if eng.records[rid].revoked
                      else "finalized" if rid in done else "open")
                for rid in sorted(eng.records)}
    rep = eng.obs_report()
    return verdicts, rep["commit_appends"], rep["commit_leaves"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=int(os.environ.get(
        "REPRO_BENCH_SERVE_REQUESTS", "32")))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=96)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--arrivals", choices=("poisson", "bursty", "trace"),
                    default="poisson")
    ap.add_argument("--trace",
                    help="JSON [{'at_tick': int}, ...] replay")
    ap.add_argument("--rate", type=float, default=0.25,
                    help="mean arrivals per engine tick (open loop)")
    ap.add_argument("--slo-ticks", type=int, default=120,
                    help="TTFT SLO in engine ticks for goodput")
    ap.add_argument("--challenge-window", type=int, default=400)
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="required continuous/fixed goodput ratio")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args()

    cfg = get_config(ARCH, smoke=True)
    params = init_model(cfg, seed=args.seed)
    requests = make_requests(args.requests, cfg.vocab_size,
                             max_prompt=args.max_prompt,
                             max_new=args.max_new, seed=args.seed)
    schedule = arrival_schedule(args.arrivals, args.requests, args.rate,
                                args.seed, args.trace)

    results, outputs = {}, {}
    for policy in ("continuous", "fixed"):
        results[policy], outputs[policy] = measure(
            policy, cfg, params, requests, schedule, args)
        r = results[policy]
        row(f"serve.{policy}", 1e6 * r["wall_s"] / max(r["tokens"], 1),
            f"goodput={r['goodput_tok_s']:.1f}tok/s "
            f"p99={r['token_latency_p99_s'] * 1e3:.2f}ms "
            f"occ={r['occupancy_mean']:.2f}")

    # trust contract: same verdict map under both schedules, honest and
    # tampered, on a smaller verified trace
    vreqs = make_requests(min(args.requests, 8), cfg.vocab_size,
                          max_prompt=24, max_new=6, seed=args.seed + 7,
                          id_base=10_000)
    vsched = arrival_schedule("poisson", len(vreqs), args.rate,
                              args.seed + 7)
    honest, appends, leaves = {}, 0, 0
    tampered = {}
    tamper_rid = vreqs[len(vreqs) // 2]["id"]
    for policy in ("continuous", "fixed"):
        honest[policy], a, l = verdict_run(policy, cfg, params, vreqs,
                                           vsched, args)
        if policy == "continuous":
            appends, leaves = a, l
        tampered[policy], _, _ = verdict_run(policy, cfg, params, vreqs,
                                             vsched, args,
                                             tamper_rid=tamper_rid)

    speedup = results["continuous"]["goodput_tok_s"] \
        / max(results["fixed"]["goodput_tok_s"], 1e-9)
    # per-request verdict contract: honest maps EQUAL across schedules;
    # under tamper the altered session is revoked in both.  The full
    # tampered maps are reported but not compared — dependent-revocation
    # blast radius follows tick overlap, which schedules differently by
    # design (continuous co-batches across admissions).
    verdicts_equal = honest["continuous"] == honest["fixed"]
    streams_equal = outputs["continuous"] == outputs["fixed"]
    all_finalized = all(v == "finalized"
                        for v in honest["continuous"].values())
    tamper_caught = (tampered["continuous"].get(tamper_rid) == "revoked")

    out = {
        "workload": {"arch": ARCH, "requests": args.requests,
                     "slots": args.slots, "cache_len": args.cache_len,
                     "max_prompt": args.max_prompt,
                     "max_new": args.max_new,
                     "prefill_chunk": args.prefill_chunk,
                     "arrivals": args.arrivals, "rate": args.rate,
                     "slo_ticks": args.slo_ticks, "seed": args.seed},
        "continuous": results["continuous"],
        "fixed": results["fixed"],
        "goodput_speedup": speedup,
        # one fused macro-step covers C engine ticks, so continuous makes
        # far fewer compiled dispatches for the same served tokens
        "dispatch_reduction": 1.0
        - results["continuous"]["compiled_dispatches"]
        / max(results["fixed"]["compiled_dispatches"], 1),
        "streams_equal": streams_equal,
        "trust": {
            "verdicts_equal": verdicts_equal,
            "honest_all_finalized": all_finalized,
            "tamper_caught_both": tamper_caught
            and tampered["fixed"].get(tamper_rid) == "revoked",
            "commit_appends": appends,
            "commit_leaves": leaves,
            "amortization": leaves / max(appends, 1),
        },
    }
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)

    row("serve.speedup", 0.0, f"goodput_speedup={speedup:.2f} "
        f"dispatch_reduction={out['dispatch_reduction']:.2f}")
    failures = []
    if speedup < args.min_speedup:
        failures.append(f"goodput speedup {speedup:.3f} < "
                        f"{args.min_speedup} (continuous vs fixed)")
    for policy in ("continuous", "fixed"):
        if results[policy]["token_latency_p99_s"] <= 0:
            failures.append(f"{policy}: missing token latency percentiles")
    if not streams_equal:
        failures.append("token streams differ across schedules")
    if not verdicts_equal:
        failures.append(f"honest verdict maps diverge: {honest}")
    if not all_finalized:
        failures.append(f"honest requests did not finalize: "
                        f"{honest['continuous']}")
    if not out["trust"]["tamper_caught_both"]:
        failures.append("tampered session not revoked in both schedules")
    if failures:
        for msg in failures:
            print(f"[serving-bench] GATE FAILED: {msg}", file=sys.stderr)
        return 1
    print(f"[serving-bench] ok: goodput {speedup:.2f}x, "
          f"{out['dispatch_reduction']:.0%} fewer dispatches, "
          f"amortization {out['trust']['amortization']:.1f} "
          f"leaves/append -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
