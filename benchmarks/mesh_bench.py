"""Mesh-executed B-MoE rounds: the CI gate for the device-mesh claim.

Runs the ``framework="optimistic"`` round loop twice on identical
attacked batches — single-device oracle (``mesh="off"``) vs an 8-edge
device mesh (``mesh="on"``, forced host devices) where each simulated
edge owns an ``E/msize`` expert shard, dispatch crosses the mesh via
all_to_all, each edge hashes only its own buckets into a Merkle subtree
(round root = reduction over shard roots), and audit recompute runs on
the owning shard.  Gated claims:

- **bit-identity** — parameter digests, commitment roots, audit
  verdicts/fraud proofs, rollback count, and inference logits all match
  the oracle exactly (loss is allclose only: its mean reduces a sharded
  output in a different order);
- **dispatch wire bytes independent of E** — the per-device collective
  bytes of the compiled train step at ``num_experts=16`` stay within
  ``--wire-ratio`` (default 1.25x) of the ``num_experts=8`` compile:
  the send buffer is ``~capacity_factor * B * top_k * C`` rows no
  matter how many experts the bank holds;
- **shard-local audits** — with ``audit_rate=1.0`` every edge re-executes
  only its own experts' sampled rows: no shard books more than
  ``total/msize`` rows plus one capacity bucket of padding slack.

Wall-clock per round is reported, not gated (CPU-interpret timing).
Writes ``BENCH_mesh.json``; exits non-zero if any gate fails.

NOTE: must run as its own process (``python -m benchmarks.mesh_bench``)
— the forced-device XLA flag below has to land before jax initializes,
which is why this suite is not in ``benchmarks.run``.
"""
from __future__ import annotations

import os

N_DEVICES = 8
if "jax" not in __import__("sys").modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEVICES}")

import argparse
import json

import jax
import numpy as np

from benchmarks.common import dataset, row, timed
from repro.core.attacks import AttackConfig
from repro.core.bmoe import BMoEConfig, BMoESystem, sparse_capacity
from repro.core.ledger import digest_tree
from repro.core.reputation import ReputationConfig
from repro.launch import hloanalysis
from repro.trust.commitments import MerkleTree
from repro.trust.protocol import TrustConfig

TOP_K = 2
BATCH = 256
CAPACITY_FACTOR = 1.0


def _system(mesh: str, *, num_experts: int = 8,
            attack=AttackConfig()) -> BMoESystem:
    cfg = BMoEConfig(
        framework="optimistic", expert_kind="mlp", num_experts=num_experts,
        num_edges=num_experts, top_k=TOP_K, dispatch="sparse", mesh=mesh,
        capacity_factor=CAPACITY_FACTOR, attack=attack, pow_difficulty=2,
        workload_balance=True,
        reputation=ReputationConfig(init=0.5, gain=0.01, slash=0.4,
                                    exclusion_threshold=0.2),
        trust=TrustConfig(audit_rate=1.0, num_verifiers=2,
                          challenge_window=2, audit_backend="batched"))
    return BMoESystem(cfg)


def _bit_identity(xtr, ytr, xte, rounds: int):
    """Train oracle + mesh side by side; return (identity dict, systems,
    wall-clock per round)."""
    atk = AttackConfig(malicious_edges=(2,), attack_prob=1.0, noise_std=5.0)
    systems = {"oracle": _system("off", attack=atk),
               "mesh": _system("on", attack=atk)}
    walls = {k: 0.0 for k in systems}
    rng = np.random.default_rng(0)
    for idx in [rng.integers(0, len(xtr), BATCH) for _ in range(rounds)]:
        for name, s in systems.items():
            with timed(f"mesh.{name}.train") as t:
                s.train_round(xtr[idx], ytr[idx])
            walls[name] += t.seconds
    for s in systems.values():
        s.flush_trust()
    a, b = systems["oracle"], systems["mesh"]
    la, _, _ = a.infer(xte[:BATCH], commit=False)
    lb, _, _ = b.infer(xte[:BATCH], commit=False)
    com = b.protocol.rounds[0].commitment
    identity = {
        "params": digest_tree(a.experts) == digest_tree(b.experts)
        and digest_tree(a.gate) == digest_tree(b.gate),
        "commit_roots": all(
            a.protocol.rounds[r].commitment.root
            == b.protocol.rounds[r].commitment.root
            for r in a.protocol.rounds),
        "verdicts": all(
            a.protocol.rounds[r].phase is b.protocol.rounds[r].phase
            and [(p.leaf_index, p.expert, p.claimed_digest,
                  p.recomputed_digest) for p in a.protocol.rounds[r].proofs]
            == [(p.leaf_index, p.expert, p.claimed_digest,
                 p.recomputed_digest) for p in b.protocol.rounds[r].proofs]
            for r in a.protocol.rounds),
        "rollbacks": (a.protocol.stats["rolled_back"]
                      == b.protocol.stats["rolled_back"] >= 1),
        "shard_root_reduction": (com.num_shards == b.mesh_shards
                                 and MerkleTree(com.shard_roots).root
                                 == com.root),
        "infer_logits": (np.asarray(la).tobytes()
                         == np.asarray(lb).tobytes()),
    }
    return identity, systems, walls


def _wire_bytes(num_experts: int) -> float:
    """Collective bytes of the compiled mesh train step (same argument
    construction as BMoESystem.train_round)."""
    import jax.numpy as jnp
    s = _system("on", num_experts=num_experts)
    atk = s.cfg.attack
    x = np.zeros((BATCH, 28 * 28), np.float32)
    y = np.zeros((BATCH,), np.int32)
    rkey = jax.random.fold_in(jax.random.PRNGKey(s.cfg.seed + 17), 0)
    mask_e = jnp.zeros(s.cfg.num_edges, jnp.float32)
    gate_bias, active = s._controls()
    bank = s._resolve_bank(x, gate_bias)
    txt = s._train_step.lower(
        s.gate, bank, jnp.asarray(x), jnp.asarray(y), mask_e,
        jax.random.fold_in(rkey, 1), atk.noise_std,
        jnp.asarray(atk.colluding), gate_bias, active,
        jnp.int32(0)).compile().as_text()
    return float(hloanalysis.analyze(txt)["total_collective_bytes"])


def main(rounds: int = 8, json_path: str = "BENCH_mesh.json",
         wire_ratio: float = 1.25, gate: bool = True):
    if jax.device_count() < N_DEVICES:
        raise SystemExit(
            f"mesh bench needs {N_DEVICES} forced host devices, found "
            f"{jax.device_count()} — run as 'python -m "
            f"benchmarks.mesh_bench' in its own process")
    xtr, ytr, xte, _ = dataset("fmnist")
    identity, systems, walls = _bit_identity(xtr, ytr, xte, rounds)
    b = systems["mesh"]
    msize = b.mesh_shards

    # shard-local audit accounting (counters booked by the recompute)
    rows_by_shard = {
        s: b.obs.metrics.value("bmoe.mesh.audit_rows", shard=str(s))
        for s in range(msize)}
    total_rows = sum(rows_by_shard.values())
    cap = sparse_capacity(b.cfg, BATCH)
    audit_local = (total_rows > 0
                   and all(r > 0 for r in rows_by_shard.values())
                   and max(rows_by_shard.values())
                   <= total_rows / msize + cap)

    wire = {str(n): _wire_bytes(n) for n in (8, 16)}
    wire_growth = wire["16"] / max(wire["8"], 1e-12)

    result = {
        "config": {"devices": N_DEVICES, "mesh_shards": msize,
                   "top_k": TOP_K, "batch": BATCH,
                   "capacity_factor": CAPACITY_FACTOR, "capacity": cap,
                   "rounds": rounds, "audit_rate": 1.0},
        "bit_identical": identity,
        "train_s_per_round": {k: walls[k] / rounds for k in walls},
        "mesh_overhead_x": walls["mesh"] / max(walls["oracle"], 1e-12),
        "audit_rows_by_shard": rows_by_shard,
        "audit_rows_total": total_rows,
        "audit_shard_local": audit_local,
        "collective_bytes_per_step": wire,
        "wire_growth_8_to_16_experts": wire_growth,
        "wire_growth_limit": wire_ratio,
    }
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)

    all_identical = all(identity.values())
    rows = [
        row("mesh_train", walls["mesh"] / rounds * 1e6,
            f"oracle_us={walls['oracle'] / rounds * 1e6:.1f};"
            f"shards={msize};bit_identical={all_identical}"),
        row("mesh_claims", 0.0,
            f"wire_growth={wire_growth:.3f}(limit<={wire_ratio});"
            f"audit_rows_max={max(rows_by_shard.values()):.0f}"
            f"_of_{total_rows:.0f};shard_local={audit_local}"),
    ]
    if gate:
        if not all_identical:
            failed = [k for k, v in identity.items() if not v]
            raise SystemExit(f"perf gate: mesh execution diverged from the "
                             f"single-device oracle: {failed}")
        if wire_growth > wire_ratio:
            raise SystemExit(
                f"perf gate: per-device dispatch bytes grew {wire_growth:.2f}x "
                f"from 8 to 16 experts (limit {wire_ratio}x) — dispatch is "
                f"no longer independent of the expert count")
        if not audit_local:
            raise SystemExit(
                f"perf gate: audit recompute not shard-local: "
                f"{rows_by_shard} (total {total_rows}, {msize} shards)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--json", default="BENCH_mesh.json")
    ap.add_argument("--wire-ratio", type=float, default=1.25)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(args.rounds, args.json, args.wire_ratio)
