"""Fig. 4(c): inference accuracy of well-trained B-MoE vs traditional
distributed MoE as the malicious ratio sweeps 0..0.7.

Validates: B-MoE flat below the 50% threshold, collapses above it;
traditional degrades monotonically (paper: B-MoE +66% Fashion-MNIST /
+44% CIFAR-10 below threshold)."""
from __future__ import annotations

from benchmarks.common import ROUNDS, dataset, make_system, row, train_system
from repro.core.attacks import AttackConfig

RATIOS = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7)


def main(kind: str = "fmnist"):
    rows = []
    _, _, xte, yte = dataset(kind)
    systems = {}
    for fw in ("traditional", "bmoe"):
        sys_ = make_system(fw, kind, AttackConfig())
        _, wall = train_system(sys_, kind, ROUNDS)   # trustworthy training
        systems[fw] = (sys_, wall)
    accs = {fw: [] for fw in systems}
    for ratio in RATIOS:
        m = round(ratio * 10)
        atk = AttackConfig(malicious_edges=tuple(range(10 - m, 10)),
                           attack_prob=1.0, noise_std=5.0, colluding=True)
        for fw, (sys_, _) in systems.items():
            accs[fw].append(sys_.evaluate(xte[:800], yte[:800], attack=atk))
    for fw, (sys_, wall) in systems.items():
        us = wall / ROUNDS * 1e6
        pts = ";".join(f"{r}:{a:.3f}" for r, a in zip(RATIOS, accs[fw]))
        rows.append(row(f"fig4c_{kind}_{fw}", us, pts))
    below = accs["bmoe"][4] - accs["traditional"][4]      # ratio 0.4
    flat = abs(accs["bmoe"][4] - accs["bmoe"][0]) < 0.03
    collapse = accs["bmoe"][6] < accs["bmoe"][0] - 0.3    # ratio 0.6
    rows.append(row(f"fig4c_{kind}_claims", 0.0,
                    f"gain_at_r0.4={below:.3f};flat_below_threshold={flat};"
                    f"collapse_above_threshold={collapse}"))
    return rows


if __name__ == "__main__":
    main()
